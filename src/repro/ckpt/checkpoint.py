"""Sharded, mesh-agnostic checkpointing with async writes + elastic restore.

Layout:  <dir>/step_<N>/
  manifest.json      — step, flat key list, shapes/dtypes, mesh shape
  arrays.npz         — one entry per flattened tree leaf (host gathered)

Checkpoints store *logical* arrays (no device layout), so a restore can
reshard onto any mesh — the elastic-scaling path: save on 512 chips,
restore on 256, or on 1 CPU for tests.  Saving runs on a background
thread double-buffered against training (async checkpointing); the
``step_`` directory is renamed into place atomically so a crash never
leaves a half-written checkpoint visible (fault tolerance: restart picks
``latest_step`` and resumes).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

SEP = "::"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(p.key if hasattr(p, "key") else str(p.idx) for p in path)
        a = np.asarray(leaf)
        if a.dtype.name in ("bfloat16", "float16"):
            a = a.astype(np.float32)  # npz-safe; restore recasts to leaf dtype
        out[key] = a
    return out


def _unflatten_into(template, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = SEP.join(p.key if hasattr(p, "key") else str(p.idx) for p in path)
        a = arrays[key]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {a.shape} != {leaf.shape}")
        leaves.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in flat]), leaves


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save ---
    def save(self, step: int, tree, *, blocking: bool = False, extra: dict | None = None):
        """Snapshot to host, then write on a background thread."""
        host = _flatten(tree)  # device->host copy happens here (blocking)
        meta = {
            "step": int(step),
            "keys": sorted(host),
            "extra": extra or {},
            "n_devices": jax.device_count(),
        }
        if self._thread is not None:
            self._thread.join()  # one in flight (double buffer)

        def write():
            tmp = self.dir / f".tmp_step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore ---
    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        )

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, template, *, shardings=None):
        """Load into the template's structure; reshard onto ``shardings``
        (a matching tree of NamedSharding) if given — the elastic path."""
        d = self.dir / f"step_{step}"
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (path, leaf), sh in zip(flat, shard_flat):
            key = SEP.join(p.key if hasattr(p, "key") else str(p.idx) for p in path)
            a = arrays[key].astype(leaf.dtype)
            leaves.append(jax.device_put(a, sh) if sh is not None else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, leaves)
