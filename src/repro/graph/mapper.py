"""End-to-end sequence-to-graph read mapper (paper Figure 6-1, batched).

Seed-and-extend over a tiled graph index, as a three-stage pipeline:

  * **Stage A — seed + tile pre-filter** (`tile_prefilter`): MinSeed
    minimizer seeding on the backbone, then a q-gram Bloom screen over
    each candidate tile (`core/filter` primitives against the index's
    per-tile ``tile_bloom``/``tile_slack``) — one vectorized count, no
    DC launch.  The screen is *sound*: by the q-gram lemma a tile whose
    confirmed q-gram count falls below ``(m-q+1) - q·k - slack`` cannot
    contain a mapping within ``filter_k`` edits, so every pruned slot's
    GenASM-DC distance would have been ``filter_k + 1`` anyway and the
    lexicographic winner is untouched (GAF output stays byte-identical
    with the screen on or off).
  * **Stage B — compacted gather + BitAlign-DC filter**
    (`graph_candidate_stage` with ``pf``/``n_cap``): survivors are
    argsort-compacted into a shared ``[n_cap]``-row buffer (``n_cap`` a
    `tile_rung` high-water bucket chosen on the host), the per-node
    GenASM-DC filter runs over those rows only — empty and pruned slots
    stop burning kernel lanes — and distances scatter back to the dense
    ``[B, max_candidates]`` grid for the unchanged shard-order-free
    winner rule ``min (distance, origin, tile)``.
  * **Stage C — align** (`align_winners`): windowed graph alignment of
    each read's winning window through `repro.align.align_batch`
    (``graph_lax`` / ``graph_pallas``), with failed reads canonicalized
    (``ops``=OP_PAD, ``n_ops``=0) so an all-pruned batch can skip the
    launch entirely (`unmapped_result`) without changing any output.

The candidate stage is written against a :class:`GraphView` — local
tile/backbone slices plus the global offsets of their first rows — so
the whole-graph mapper and the sharded mapper
(`repro.shard.graph_mapper`) run the *same* seeding/screen/filter/
selection code: per-candidate distances, refined anchors, and window
bytes are bit-identical at 1 and N shards.

`map_batch` is **host-orchestrated** (it syncs the survivor count
between stages to pick the rung) — do not wrap it in ``jax.jit``; the
stages are jitted internally and cached per geometry + rung.
"""
from __future__ import annotations

import os
import time
from collections import OrderedDict
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as qfilter
from repro.core.bitvector import WILDCARD
from repro.core.genasm import GenASMConfig
from repro.core.genasm_tb import OP_PAD
from repro.core.mapper import POS_SENTINEL
from repro.core.segram.graph import HOP_LIMIT
from repro.core.segram.minimizer import seed_candidates

from .index import GraphArrays, GraphIndex
from .windowed import bitalign_search, unpack_graph_text

# linear backend names map to their graph twins so ``backend="auto"`` (or
# an engine configured with a linear name) serves the graph workload on
# the matching implementation tier
_GRAPH_TWIN = {"lax": "graph_lax", "ref": "graph_lax",
               "pallas_dc": "graph_pallas", "pallas_dc_v2": "graph_pallas"}


def graph_backend_name(backend: str | None = None) -> str:
    """Resolve a backend name (or None/"auto") to a graph backend."""
    from repro import align as align_dispatch

    name = align_dispatch.resolve_backend(backend).name
    return _GRAPH_TWIN.get(name, name)


class GraphMapResult(NamedTuple):
    """Batched graph-mapping outcome (the GAF-row payload).

    ``position``/``distance`` are ``-1`` for unmapped reads; ``path``
    holds global node ids per CIGAR op (``-1`` for insertions/padding).
    Failed reads are canonical: ``ops`` all OP_PAD, ``n_ops`` 0.
    """

    position: jnp.ndarray  # int32 backbone coord of first aligned node (-1)
    distance: jnp.ndarray  # int32 edit distance (-1 if unmapped)
    ops: jnp.ndarray  # packed CIGAR
    n_ops: jnp.ndarray
    path: jnp.ndarray  # [B, cap] int32 global node ids per op (-1 for I/pad)
    failed: jnp.ndarray


class GraphView(NamedTuple):
    """One shard's (or the whole graph's) view of a tiled graph index.

    Local array slices plus the global coordinate of each slice's first
    row; the whole-graph view has all offsets 0.  ``idx_positions`` stay
    *global* backbone coordinates in every view — merging per-shard
    candidates then needs no translation step.
    """

    tile_gtext: jnp.ndarray  # [Ct, tile_len] uint32 packed local tiles
    tile_valid: jnp.ndarray  # [Ct] int32 valid node count per local tile
    tile_base: jnp.ndarray  # int32 global tile id of local tile row 0
    node_of_backbone: jnp.ndarray  # [Lb] int32 local backbone→node slice
    nb_offset: jnp.ndarray  # int32 global backbone coord of slice row 0
    backbone: jnp.ndarray  # [Nb] int32 local node→backbone slice
    node_base: jnp.ndarray  # int32 global node id of backbone slice row 0
    idx_hashes: jnp.ndarray  # [M] uint32 sorted minimizer hashes
    idx_positions: jnp.ndarray  # [M] int32 GLOBAL backbone positions
    tile_bloom: jnp.ndarray  # [Ct, BLOOM_WORDS] uint32 per-tile q-gram Bloom
    tile_slack: jnp.ndarray  # [Ct] int32 per-tile q-gram-lemma slack


def whole_graph_view(garr: GraphArrays) -> GraphView:
    """The trivial single-shard view: full arrays, zero offsets."""
    zero = jnp.int32(0)
    return GraphView(
        tile_gtext=garr.tile_gtext, tile_valid=garr.tile_valid,
        tile_base=zero, node_of_backbone=garr.node_of_backbone,
        nb_offset=zero, backbone=garr.backbone, node_base=zero,
        idx_hashes=garr.idx_hashes, idx_positions=garr.idx_positions,
        tile_bloom=garr.tile_bloom, tile_slack=garr.tile_slack)


class CandidateStageResult(NamedTuple):
    """Per-read winner of one view's seeding + GenASM-DC filter stage.

    Everything downstream alignment needs travels with the winner, so
    the align stage never touches the (possibly remote) graph arrays:
    ``gwin`` is the packed ``[B, t_cap]`` graph text window, ``bwin``
    the backbone coordinate of each window node (``-1`` on alt nodes).
    """

    distance: jnp.ndarray  # [B] int32 filter distance (filter_k+1 = none)
    origin: jnp.ndarray  # [B] int32 global node id of window node 0
    tile: jnp.ndarray  # [B] int32 global winning tile id
    gwin: jnp.ndarray  # [B, t_cap] uint32 packed graph text window
    bwin: jnp.ndarray  # [B, t_cap] int32 backbone coord per window node
    t_len: jnp.ndarray  # [B] int32 valid window length
    prefilter_ok: jnp.ndarray  # [B] bool


class TilePrefilterResult(NamedTuple):
    """Stage-A output: seeds plus the per-slot tile-screen verdict."""

    starts: jnp.ndarray  # [B, C] int32 candidate backbone starts
    votes: jnp.ndarray  # [B, C] int32 seed votes (0 = dead slot)
    keep: jnp.ndarray  # [B, C] bool live & screen-pass (survivors)
    n_keep: jnp.ndarray  # [B] int32 survivors per read
    n_live: jnp.ndarray  # [B] int32 live (seeded) slots per read


def tile_rung(n: int, cap: int) -> int:
    """High-water bucket for the compacted DC row count.

    The smallest power of two ≥ max(n, 8), clamped to the dense slot
    count ``cap`` — the (read-length, tile-count) bucket ladder's second
    axis.  0 survivors → rung 0 (callers short-circuit).
    """
    if n <= 0:
        return 0
    r = 8
    while r < n:
        r *= 2
    return min(r, cap)


def _seed(view: GraphView, reads, *, max_candidates: int, minimizer_w: int,
          minimizer_k: int):
    """MinSeed over the view's minimizer table: [B, C] starts + votes."""
    seed_fn = partial(seed_candidates, w=minimizer_w, k=minimizer_k,
                      max_candidates=max_candidates)
    return jax.vmap(
        lambda r: seed_fn(r, view.idx_hashes, view.idx_positions))(reads)


def _tiles_of_starts(view: GraphView, starts, *, tile_stride: int,
                     n_tiles: int, backbone_len: int):
    """Candidate backbone starts → (global tile id, local tile row)."""
    sb = jnp.clip(starts - HOP_LIMIT, 0, backbone_len - 1)
    nb_len = view.node_of_backbone.shape[0]
    node = view.node_of_backbone[
        jnp.clip(sb - view.nb_offset, 0, nb_len - 1)]  # [B, C] global ids
    tile_g = jnp.clip(node // tile_stride, 0, n_tiles - 1)
    n_local_tiles = view.tile_gtext.shape[0]
    tile_local = jnp.clip(tile_g - view.tile_base, 0, n_local_tiles - 1)
    return tile_g, tile_local


def _filter_pattern(reads, read_lens, filter_bits: int):
    """Wildcard-masked [B, fb] filter pattern + clamped lengths."""
    fb = filter_bits
    fpat = jnp.where(
        jnp.arange(fb)[None, :] < jnp.minimum(read_lens, fb)[:, None],
        reads[:, :fb], WILDCARD).astype(jnp.int8)
    return fpat, jnp.minimum(read_lens, fb)


def tile_prefilter(
    view: GraphView,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    n_tiles: int,
    backbone_len: int,
    filter_bits: int,
    filter_k: int,
    max_candidates: int,
    minimizer_w: int,
    minimizer_k: int,
    prefilter: bool = True,
) -> TilePrefilterResult:
    """Stage A: seed, then screen each candidate tile without any DC.

    A slot survives iff it is live (has seed votes) and its tile's Bloom
    filter confirms at least ``(m-q+1) - q·filter_k - tile_slack`` of
    the read's q-grams (`core/filter.qgram_min_hits`) — the q-gram-lemma
    bound under which a ≤ ``filter_k`` mapping could exist.  With
    ``prefilter=False`` the screen is skipped (survivor = live), which
    still compacts away dead slots downstream.
    """
    read_lens = read_lens.astype(jnp.int32)
    starts, votes = _seed(view, reads, max_candidates=max_candidates,
                          minimizer_w=minimizer_w, minimizer_k=minimizer_k)
    live = votes > 0
    if prefilter:
        _, tile_local = _tiles_of_starts(
            view, starts, tile_stride=tile_stride, n_tiles=n_tiles,
            backbone_len=backbone_len)
        fpat, flens = _filter_pattern(reads, read_lens, filter_bits)
        codes = jax.vmap(qfilter.qgram_codes)(fpat)  # [B, fb-q+1]
        b, c = votes.shape
        p = codes.shape[-1]
        n_pos = jnp.maximum(flens - (qfilter.QGRAM_Q - 1), 0)  # [B]
        pos_ok = jnp.arange(p)[None, :] < n_pos[:, None]
        hits = qfilter.qgram_hits(
            jnp.broadcast_to(codes[:, None, :], (b, c, p)),
            jnp.broadcast_to(pos_ok[:, None, :], (b, c, p)),
            view.tile_bloom[tile_local])  # [B, C]
        need = qfilter.qgram_min_hits(n_pos[:, None], filter_k,
                                      view.tile_slack[tile_local])
        keep = live & (hits >= need)
    else:
        keep = live
    return TilePrefilterResult(
        starts=starts, votes=votes, keep=keep,
        n_keep=jnp.sum(keep, axis=-1, dtype=jnp.int32),
        n_live=jnp.sum(live, axis=-1, dtype=jnp.int32))


def _filter_dists(wins_flat, fpat_flat, flens_flat, *, m_bits: int, k: int,
                  use_kernel: bool, block_bt: int | None, interpret: bool):
    """[BC, tile_len] per-node distances, kernel or pure-lax path."""
    bases, succ = unpack_graph_text(wins_flat)
    if use_kernel:
        from repro.align.batched import _pad_to_block
        from repro.kernels.bitalign import bitalign_dc_batch

        bc = wins_flat.shape[0]
        bt = min(block_bt or 128, max(8, bc))
        dists, _ = bitalign_dc_batch(
            _pad_to_block(bases, bt, 4), _pad_to_block(succ, bt, 0),
            _pad_to_block(fpat_flat, bt, WILDCARD),
            _pad_to_block(flens_flat, bt, m_bits),
            m_bits=m_bits, k=k, block_bt=bt, interpret=interpret)
        return dists[:bc]
    f = partial(bitalign_search, m_bits=m_bits, k=k)
    return jax.vmap(f)(bases, succ, fpat_flat, flens_flat)


def graph_candidate_stage(
    view: GraphView,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    n_tiles: int,
    backbone_len: int,
    n_nodes: int,
    t_cap: int,
    filter_bits: int,
    filter_k: int,
    max_candidates: int,
    minimizer_w: int,
    minimizer_k: int,
    use_kernel: bool = False,
    block_bt: int | None = None,
    interpret: bool = True,
    pf: TilePrefilterResult | None = None,
    n_cap: int | None = None,
) -> CandidateStageResult:
    """Seed, gather, filter, and select one view's best candidate per read.

    ``reads`` is ``[B, p_cap] int8`` with ``read_lens [B] int32`` valid
    lengths; ``n_tiles``/``backbone_len``/``n_nodes`` are the *global*
    graph sizes (the view's local arrays may be smaller slices).  The
    per-read winner minimizes ``(filter distance, origin node, tile)``
    lexicographically, so merging the winners of disjoint views
    reproduces the whole-graph winner exactly.

    With ``pf`` (a `tile_prefilter` result) the DC filter only scores
    surviving slots; with ``n_cap`` additionally set (a `tile_rung`
    bucket) survivors are compacted into an ``[n_cap]``-row buffer so
    pruned and dead slots launch no DC lanes at all.  Both modes are
    bitwise-identical to the dense legacy path (``pf=None``) on every
    mapped read: pruned slots take the exact ``(filter_k+1, off=0)``
    values the dense scan computes for them.
    """
    del n_nodes  # global sizing is carried by the caller's geometry checks
    b = reads.shape[0]
    c = max_candidates
    _, tile_len = view.tile_gtext.shape
    search_span = tile_len - t_cap
    read_lens = read_lens.astype(jnp.int32)

    if pf is None:
        starts, votes = _seed(view, reads, max_candidates=c,
                              minimizer_w=minimizer_w,
                              minimizer_k=minimizer_k)
        keep = votes > 0
    else:
        starts, votes, keep = pf.starts, pf.votes, pf.keep
    tile_g, tile_local = _tiles_of_starts(
        view, starts, tile_stride=tile_stride, n_tiles=n_tiles,
        backbone_len=backbone_len)
    fpat, flens = _filter_pattern(reads, read_lens, filter_bits)
    dc = partial(_filter_dists, m_bits=filter_bits, k=filter_k,
                 use_kernel=use_kernel, block_bt=block_bt,
                 interpret=interpret)
    span_ok = jnp.arange(tile_len) < search_span

    if n_cap is None:
        # --- dense: one gather + one DC launch over every slot
        wins = view.tile_gtext[tile_local]  # [B, C, tile_len]
        dists = dc(wins.reshape(b * c, tile_len),
                   jnp.repeat(fpat, c, axis=0),
                   jnp.repeat(flens, c)).reshape(b, c, tile_len)
        # anchors past the search span could not fit an alignment window
        dists = jnp.where(span_ok[None, None, :], dists, filter_k + 1)
        d_c = jnp.min(dists, axis=-1).astype(jnp.int32)
        off_c = jnp.argmin(dists, axis=-1).astype(jnp.int32)
        d_c = jnp.where(keep, d_c, filter_k + 1)
    else:
        # --- ragged: compact survivors into [n_cap] rows, DC those only,
        # scatter back to the dense grid.  Non-survivor slots take the
        # (filter_k+1, off=0) values the dense scan computes for them:
        # dead slots are masked there, and screen-pruned slots provably
        # have every in-span distance at filter_k+1 (argmin 0).
        bc = b * c
        kf = keep.reshape(bc)
        order = jnp.argsort(
            jnp.where(kf, 0, bc).astype(jnp.int32)
            + jnp.arange(bc, dtype=jnp.int32))
        slots = order[:n_cap]  # survivors first, in slot order; distinct
        n_tot = jnp.sum(kf, dtype=jnp.int32)
        rowmask = jnp.arange(n_cap) < n_tot
        ridx = slots // c  # read of each compacted row
        wins_r = view.tile_gtext[tile_local.reshape(bc)[slots]]
        dists = dc(wins_r, fpat[ridx], flens[ridx])  # [n_cap, tile_len]
        dists = jnp.where(span_ok[None, :], dists, filter_k + 1)
        d_r = jnp.min(dists, axis=-1).astype(jnp.int32)
        off_r = jnp.argmin(dists, axis=-1).astype(jnp.int32)
        d_c = jnp.full((bc,), filter_k + 1, jnp.int32).at[slots].set(
            jnp.where(rowmask, d_r, filter_k + 1)).reshape(b, c)
        off_c = jnp.zeros((bc,), jnp.int32).at[slots].set(
            jnp.where(rowmask, off_r, 0)).reshape(b, c)

    live = votes > 0
    origin_c = jnp.where(live, tile_g * tile_stride + off_c, POS_SENTINEL)
    tile_m = jnp.where(live, tile_g, POS_SENTINEL)

    # --- lexicographic winner per read: min (distance, origin, tile)
    dm = jnp.min(d_c, axis=-1, keepdims=True)
    om = jnp.where(d_c == dm, origin_c, POS_SENTINEL)
    omin = jnp.min(om, axis=-1, keepdims=True)
    tm = jnp.where(om == omin, tile_m, POS_SENTINEL)
    ci = jnp.argmin(tm, axis=-1)  # [B]

    rows = jnp.arange(b)
    d_best = d_c[rows, ci]
    origin = origin_c[rows, ci]
    tile_best = tile_g[rows, ci]
    off = off_c[rows, ci]
    prefilter_ok = d_best <= filter_k

    # --- slice the anchored alignment window out of the winning tile
    wrow = view.tile_gtext[tile_local[rows, ci]]
    gwin = jax.vmap(
        lambda wbuf, o: jax.lax.dynamic_slice(wbuf, (o,), (t_cap,)))(
        wrow, off)
    t_len = jnp.clip(view.tile_valid[tile_local[rows, ci]] - off, 0, t_cap)

    # backbone coordinate of every window node, shipped with the window
    # so the align stage needs no graph arrays (clip mirrors the
    # whole-graph gather: nodes past the graph end read backbone[n-1])
    bb_len = view.backbone.shape[0]
    widx = origin[:, None] + jnp.arange(t_cap)[None, :]
    bwin = view.backbone[jnp.clip(widx - view.node_base, 0, bb_len - 1)]
    return CandidateStageResult(
        distance=d_best.astype(jnp.int32), origin=origin,
        tile=jnp.where(live[rows, ci], tile_best, POS_SENTINEL),
        gwin=gwin, bwin=bwin.astype(jnp.int32),
        t_len=t_len.astype(jnp.int32), prefilter_ok=prefilter_ok)


def align_winners(
    stage: CandidateStageResult,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    cfg: GenASMConfig,
    p_cap: int,
    backend: str,
    block_bt: int | None = None,
) -> GraphMapResult:
    """Align the per-read winning windows and translate paths to GAF terms.

    ``stage`` is a (possibly merged) :class:`CandidateStageResult`;
    windows are ``[B, t_cap]`` packed graph text and ``bwin`` carries
    the backbone coordinates, so this runs without the graph index —
    the "single batched align_batch call" of the sharded design.

    Failed reads come out canonical (``ops`` all OP_PAD, ``n_ops`` 0, and
    position/distance/path already ``-1``): different executions may feed
    different garbage windows for reads with no surviving candidate, and
    canonicalizing here is what keeps prefilter on/off — and the
    zero-survivor `unmapped_result` short-circuit — bitwise identical.
    """
    from repro import align as align_dispatch

    read_lens = read_lens.astype(jnp.int32)
    t_cap = stage.gwin.shape[-1]
    pat = jnp.where(jnp.arange(p_cap)[None, :] < read_lens[:, None],
                    reads[:, :p_cap], WILDCARD).astype(jnp.int8)
    res = align_dispatch.align_batch(
        stage.gwin, pat, read_lens, stage.t_len, cfg=cfg, backend=backend,
        p_cap=p_cap, block_bt=block_bt)

    # window-relative node offsets -> global path -> backbone position
    rows = jnp.arange(stage.gwin.shape[0])
    live = res.nodes >= 0
    path = jnp.where(live, res.nodes + stage.origin[:, None], -1)
    bpath = jnp.where(
        live,
        jnp.take_along_axis(stage.bwin, jnp.clip(res.nodes, 0, t_cap - 1),
                            axis=-1), -1)
    first = jnp.argmax(bpath >= 0, axis=-1)  # first backbone node on path
    pos = bpath[rows, first]
    failed = res.failed | (~stage.prefilter_ok)
    return GraphMapResult(
        position=jnp.where(failed, -1, pos).astype(jnp.int32),
        distance=jnp.where(failed, -1, res.distance),
        ops=jnp.where(failed[:, None], jnp.asarray(OP_PAD, res.ops.dtype),
                      res.ops),
        n_ops=jnp.where(failed, 0, res.n_ops),
        path=jnp.where(failed[:, None], -1, path),
        failed=failed,
    )


def unmapped_result(b: int, *, cfg: GenASMConfig, p_cap: int
                    ) -> GraphMapResult:
    """The canonical all-failed batch: what `align_winners` emits for a
    failed read, at the ops/path widths an align launch would produce —
    the zero-survivor short-circuit returns this without any DC/align."""
    cap = cfg.ops_cap(p_cap)
    return GraphMapResult(
        position=jnp.full((b,), -1, jnp.int32),
        distance=jnp.full((b,), -1, jnp.int32),
        ops=jnp.full((b, cap), OP_PAD, jnp.int8),
        n_ops=jnp.zeros((b,), jnp.int32),
        path=jnp.full((b, cap), -1, jnp.int32),
        failed=jnp.ones((b,), bool))


def _env_prefilter(prefilter: bool | None) -> bool:
    """None → the REPRO_GRAPH_PREFILTER env default (on unless "0")."""
    if prefilter is None:
        return os.environ.get("REPRO_GRAPH_PREFILTER", "1") != "0"
    return bool(prefilter)


class GraphMapExecutor:
    """Host-orchestrated three-stage graph mapper for one static geometry.

    Stage A (jitted once) seeds and screens — no DC.  A host sync on the
    survivor counts picks the `tile_rung`; stage B (jitted once per
    rung) compacts survivors, runs BitAlign-DC over ``n_cap`` rows only,
    and selects winners; stage C (jitted once) aligns them.  An
    all-pruned batch skips B and C entirely (`unmapped_result`).

    ``trace_hook`` (if given) is called with a hashable stage key at
    trace time — ``("prefilter",)``, ``(n_cap,)`` per rung, and
    ``("align",)`` — so tests can assert one compile per ladder rung.
    ``last_stats`` holds the previous call's pruning/occupancy counters
    (the serve engine forwards them into its metrics registry).
    """

    def __init__(self, *, tile_stride: int,
                 cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 max_candidates: int = 4,
                 minimizer_w: int = 10,
                 minimizer_k: int = 15,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 prefilter: bool | None = None,
                 trace_hook=None):
        from repro import align as align_dispatch

        if filter_bits % 32:
            raise ValueError(f"filter_bits must be a multiple of 32, got "
                             f"{filter_bits}")
        self.backend = graph_backend_name(backend)
        use_kernel = align_dispatch.get_backend(self.backend).uses_pallas
        interpret = align_dispatch.needs_interpret()
        self.cfg = cfg
        self.p_cap = p_cap
        self.t_cap = p_cap + 2 * cfg.w
        self.tile_stride = tile_stride
        self.max_candidates = max_candidates
        self.prefilter = _env_prefilter(prefilter)
        user_hook = trace_hook or (lambda key: None)
        self._compiled: set = set()  # stage keys that have traced

        def hook(key):
            self._compiled.add(key)
            user_hook(key)

        self._hook = hook
        fbits = min(filter_bits, p_cap)
        self._pf_kw = dict(
            tile_stride=tile_stride, filter_bits=fbits, filter_k=filter_k,
            max_candidates=max_candidates, minimizer_w=minimizer_w,
            minimizer_k=minimizer_k, prefilter=self.prefilter)
        self._stage_kw = dict(
            tile_stride=tile_stride, t_cap=self.t_cap, filter_bits=fbits,
            filter_k=filter_k, max_candidates=max_candidates,
            minimizer_w=minimizer_w, minimizer_k=minimizer_k,
            use_kernel=use_kernel, block_bt=block_bt, interpret=interpret)

        def pf_fn(garr, reads, lens):
            self._hook(("prefilter",))
            return tile_prefilter(
                whole_graph_view(garr), reads, lens,
                n_tiles=garr.tile_gtext.shape[0],
                backbone_len=garr.node_of_backbone.shape[0], **self._pf_kw)

        self._pf = jax.jit(pf_fn)
        self._stages: dict[int, object] = {}

        def align_fn(st, reads, lens):
            self._hook(("align",))
            return align_winners(st, reads, lens, cfg=cfg, p_cap=p_cap,
                                 backend=self.backend, block_bt=block_bt)

        self._align = jax.jit(align_fn)
        self.last_stats: dict = {}
        # (stage, t0, t1, attrs) monotonic windows from the last call —
        # the serve engine replays them as child spans of its flush span
        self.last_times: list[tuple[str, float, float, dict]] = []

    def _stage(self, n_cap: int):
        fn = self._stages.get(n_cap)
        if fn is None:
            def stage_fn(garr, reads, lens, pf, _n=n_cap):
                self._hook((_n,))
                return graph_candidate_stage(
                    whole_graph_view(garr), reads, lens,
                    n_tiles=garr.tile_gtext.shape[0],
                    backbone_len=garr.node_of_backbone.shape[0],
                    n_nodes=garr.bases.shape[0], pf=pf, n_cap=_n,
                    **self._stage_kw)

            fn = self._stages[n_cap] = jax.jit(stage_fn)
        return fn

    def _check_geometry(self, garr: GraphArrays) -> None:
        tile_len = int(garr.tile_gtext.shape[1])
        span = tile_len - self.t_cap
        if span < self.tile_stride:
            raise ValueError(
                f"tile_len {tile_len} leaves a {span}-node anchor search "
                f"span < tile_stride {self.tile_stride} at p_cap "
                f"{self.p_cap}; rebuild the index with window >= "
                f"{self.t_cap}")

    def __call__(self, garr: GraphArrays, reads, read_lens) -> GraphMapResult:
        self._check_geometry(garr)
        reads = jnp.asarray(reads)
        lens = jnp.asarray(read_lens, jnp.int32)
        b = reads.shape[0]
        slots = b * self.max_candidates
        c_pf = ("prefilter",) not in self._compiled
        t0 = time.monotonic()
        pf = self._pf(garr, reads, lens)
        n_keep = np.asarray(pf.n_keep)  # host sync ends the prefilter stage
        t1 = time.monotonic()
        total = int(n_keep.sum())
        live = int(np.asarray(pf.n_live).sum())
        n_cap = tile_rung(total, slots)
        self.last_stats = dict(
            candidate_slots=slots, tiles_live=live, tiles_kept=total,
            tiles_pruned=live - total, dc_rows=n_cap, dc_rows_dense=slots,
            reads_zero_survivor=int((n_keep == 0).sum()))
        self.last_times = [("prefilter", t0, t1, {"compile": c_pf})]
        if total == 0:
            return unmapped_result(b, cfg=self.cfg, p_cap=self.p_cap)
        c_dc = (n_cap,) not in self._compiled
        c_al = ("align",) not in self._compiled
        t2 = time.monotonic()
        st = self._stage(n_cap)(garr, reads, lens, pf)
        jax.block_until_ready(st)
        t3 = time.monotonic()
        res = self._align(st, reads, lens)
        jax.block_until_ready(res)
        t4 = time.monotonic()
        self.last_times += [
            ("dc_filter", t2, t3, {"compile": c_dc, "dc_rows": n_cap}),
            ("align", t3, t4, {"compile": c_al})]
        return res


# bounded LRU over map_batch's statics: refresh()/sweep loops must not
# leak compiled stage ladders
_EXECUTORS: OrderedDict[tuple, GraphMapExecutor] = OrderedDict()
_EXECUTOR_CACHE_CAP = 8


def get_map_executor(**kw) -> GraphMapExecutor:
    """Cached :class:`GraphMapExecutor` per static-parameter set."""
    kw["prefilter"] = _env_prefilter(kw.get("prefilter"))
    key = tuple(sorted(kw.items()))
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = GraphMapExecutor(**kw)
        _EXECUTORS[key] = ex
        while len(_EXECUTORS) > _EXECUTOR_CACHE_CAP:
            _EXECUTORS.popitem(last=False)
    else:
        _EXECUTORS.move_to_end(key)
    return ex


def map_batch(
    garr: GraphArrays,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
    backend: str | None = None,
    block_bt: int | None = None,
    prefilter: bool | None = None,
) -> GraphMapResult:
    """Map a read batch against the tiled graph index.

    ``garr`` is the device half of a `GraphIndex` whose ``tile_stride``
    the caller passes statically (it shapes the tile→node arithmetic).
    ``backend`` resolves through `repro.align` with linear names mapped
    to their graph twins.  ``prefilter`` toggles the q-gram tile screen
    (None → the ``REPRO_GRAPH_PREFILTER`` env default, on); results are
    bitwise identical either way — the screen only removes tiles that
    lose the lexicographic merge regardless.

    Host-orchestrated (three jitted stages around a survivor-count
    sync): call it eagerly, do **not** wrap it in ``jax.jit``.
    """
    ex = get_map_executor(
        tile_stride=tile_stride, cfg=cfg, p_cap=p_cap,
        filter_bits=filter_bits, filter_k=filter_k,
        max_candidates=max_candidates, minimizer_w=minimizer_w,
        minimizer_k=minimizer_k, backend=backend, block_bt=block_bt,
        prefilter=prefilter)
    return ex(garr, reads, read_lens)


def map_batch_index(gidx: GraphIndex, reads, read_lens, **kw
                    ) -> GraphMapResult:
    """`map_batch` with the geometry pulled off a host `GraphIndex`."""
    kw.setdefault("minimizer_w", gidx.minimizer_w)
    kw.setdefault("minimizer_k", gidx.minimizer_k)
    return map_batch(gidx.arrays, reads, read_lens,
                     tile_stride=gidx.tile_stride, **kw)
