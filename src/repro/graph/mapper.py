"""End-to-end sequence-to-graph read mapper (paper Figure 6-1, batched).

Seed-and-extend over a tiled graph index: MinSeed minimizer seeding on
the backbone → **one** batched candidate-window gather
(``tile_gtext[tile_ids]``) → **one** ``[B · max_candidates]`` BitAlign-DC
filter launch that scores *and* anchor-refines every candidate window
(per-node distances, argmin = refined start node) → windowed graph
alignment of each read's best window through `repro.align.align_batch`
(``graph_lax`` / ``graph_pallas``).  Contrast `core/segram/segram.py`'s
offline toy, which vmaps a per-candidate whole-window scan inside every
read — here the candidate axis is folded into the batch, so the kernel
sees one launch per stage instead of ``B × max_candidates`` traces.

The candidate stage (:func:`graph_candidate_stage`) is written against a
:class:`GraphView` — local tile/backbone slices plus the global offsets
of their first rows — so the whole-graph mapper and the sharded mapper
(`repro.shard.graph_mapper`) run the *same* seeding/filter/selection
code: per-candidate distances, refined anchors, and window bytes are
bit-identical at 1 and N shards, and the winner is chosen by the
shard-order-independent lexicographic rule ``min (distance, origin,
tile)`` in global coordinates.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bitvector import WILDCARD
from repro.core.genasm import GenASMConfig
from repro.core.mapper import POS_SENTINEL
from repro.core.segram.graph import HOP_LIMIT
from repro.core.segram.minimizer import seed_candidates

from .index import GraphArrays, GraphIndex
from .windowed import bitalign_search, unpack_graph_text

# linear backend names map to their graph twins so ``backend="auto"`` (or
# an engine configured with a linear name) serves the graph workload on
# the matching implementation tier
_GRAPH_TWIN = {"lax": "graph_lax", "ref": "graph_lax",
               "pallas_dc": "graph_pallas", "pallas_dc_v2": "graph_pallas"}


def graph_backend_name(backend: str | None = None) -> str:
    """Resolve a backend name (or None/"auto") to a graph backend."""
    from repro import align as align_dispatch

    name = align_dispatch.resolve_backend(backend).name
    return _GRAPH_TWIN.get(name, name)


class GraphMapResult(NamedTuple):
    """Batched graph-mapping outcome (the GAF-row payload).

    ``position``/``distance`` are ``-1`` for unmapped reads; ``path``
    holds global node ids per CIGAR op (``-1`` for insertions/padding).
    """

    position: jnp.ndarray  # int32 backbone coord of first aligned node (-1)
    distance: jnp.ndarray  # int32 edit distance (-1 if unmapped)
    ops: jnp.ndarray  # packed CIGAR
    n_ops: jnp.ndarray
    path: jnp.ndarray  # [B, cap] int32 global node ids per op (-1 for I/pad)
    failed: jnp.ndarray


class GraphView(NamedTuple):
    """One shard's (or the whole graph's) view of a tiled graph index.

    Local array slices plus the global coordinate of each slice's first
    row; the whole-graph view has all offsets 0.  ``idx_positions`` stay
    *global* backbone coordinates in every view — merging per-shard
    candidates then needs no translation step.
    """

    tile_gtext: jnp.ndarray  # [Ct, tile_len] uint32 packed local tiles
    tile_valid: jnp.ndarray  # [Ct] int32 valid node count per local tile
    tile_base: jnp.ndarray  # int32 global tile id of local tile row 0
    node_of_backbone: jnp.ndarray  # [Lb] int32 local backbone→node slice
    nb_offset: jnp.ndarray  # int32 global backbone coord of slice row 0
    backbone: jnp.ndarray  # [Nb] int32 local node→backbone slice
    node_base: jnp.ndarray  # int32 global node id of backbone slice row 0
    idx_hashes: jnp.ndarray  # [M] uint32 sorted minimizer hashes
    idx_positions: jnp.ndarray  # [M] int32 GLOBAL backbone positions


def whole_graph_view(garr: GraphArrays) -> GraphView:
    """The trivial single-shard view: full arrays, zero offsets."""
    zero = jnp.int32(0)
    return GraphView(
        tile_gtext=garr.tile_gtext, tile_valid=garr.tile_valid,
        tile_base=zero, node_of_backbone=garr.node_of_backbone,
        nb_offset=zero, backbone=garr.backbone, node_base=zero,
        idx_hashes=garr.idx_hashes, idx_positions=garr.idx_positions)


class CandidateStageResult(NamedTuple):
    """Per-read winner of one view's seeding + GenASM-DC filter stage.

    Everything downstream alignment needs travels with the winner, so
    the align stage never touches the (possibly remote) graph arrays:
    ``gwin`` is the packed ``[B, t_cap]`` graph text window, ``bwin``
    the backbone coordinate of each window node (``-1`` on alt nodes).
    """

    distance: jnp.ndarray  # [B] int32 filter distance (filter_k+1 = none)
    origin: jnp.ndarray  # [B] int32 global node id of window node 0
    tile: jnp.ndarray  # [B] int32 global winning tile id
    gwin: jnp.ndarray  # [B, t_cap] uint32 packed graph text window
    bwin: jnp.ndarray  # [B, t_cap] int32 backbone coord per window node
    t_len: jnp.ndarray  # [B] int32 valid window length
    prefilter_ok: jnp.ndarray  # [B] bool


def _filter_dists(wins_flat, fpat_flat, flens_flat, *, m_bits: int, k: int,
                  use_kernel: bool, block_bt: int | None, interpret: bool):
    """[BC, tile_len] per-node distances, kernel or pure-lax path."""
    bases, succ = unpack_graph_text(wins_flat)
    if use_kernel:
        from repro.align.batched import _pad_to_block
        from repro.kernels.bitalign import bitalign_dc_batch

        bc = wins_flat.shape[0]
        bt = min(block_bt or 128, max(8, bc))
        dists, _ = bitalign_dc_batch(
            _pad_to_block(bases, bt, 4), _pad_to_block(succ, bt, 0),
            _pad_to_block(fpat_flat, bt, WILDCARD),
            _pad_to_block(flens_flat, bt, m_bits),
            m_bits=m_bits, k=k, block_bt=bt, interpret=interpret)
        return dists[:bc]
    f = partial(bitalign_search, m_bits=m_bits, k=k)
    return jax.vmap(f)(bases, succ, fpat_flat, flens_flat)


def graph_candidate_stage(
    view: GraphView,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    n_tiles: int,
    backbone_len: int,
    n_nodes: int,
    t_cap: int,
    filter_bits: int,
    filter_k: int,
    max_candidates: int,
    minimizer_w: int,
    minimizer_k: int,
    use_kernel: bool = False,
    block_bt: int | None = None,
    interpret: bool = True,
) -> CandidateStageResult:
    """Seed, gather, filter, and select one view's best candidate per read.

    ``reads`` is ``[B, p_cap] int8`` with ``read_lens [B] int32`` valid
    lengths; ``n_tiles``/``backbone_len``/``n_nodes`` are the *global*
    graph sizes (the view's local arrays may be smaller slices).  The
    per-read winner minimizes ``(filter distance, origin node, tile)``
    lexicographically, so merging the winners of disjoint views
    reproduces the whole-graph winner exactly.
    """
    b = reads.shape[0]
    c = max_candidates
    n_local_tiles, tile_len = view.tile_gtext.shape
    search_span = tile_len - t_cap
    read_lens = read_lens.astype(jnp.int32)

    # --- seed on the backbone minimizer table (global positions)
    seed_fn = partial(seed_candidates, w=minimizer_w, k=minimizer_k,
                      max_candidates=c)
    starts, votes = jax.vmap(
        lambda r: seed_fn(r, view.idx_hashes, view.idx_positions))(reads)

    # backbone coordinate -> node id, with margin for leading variation
    sb = jnp.clip(starts - HOP_LIMIT, 0, backbone_len - 1)
    nb_len = view.node_of_backbone.shape[0]
    node = view.node_of_backbone[
        jnp.clip(sb - view.nb_offset, 0, nb_len - 1)]  # [B, C] global ids
    tile_g = jnp.clip(node // tile_stride, 0, n_tiles - 1)
    tile_local = jnp.clip(tile_g - view.tile_base, 0, n_local_tiles - 1)

    # --- one gather: every candidate window for the whole batch
    wins = view.tile_gtext[tile_local]  # [B, C, tile_len]

    # --- one filter launch over the flattened candidate axis
    fb = filter_bits
    fpat = jnp.where(
        jnp.arange(fb)[None, :] < jnp.minimum(read_lens, fb)[:, None],
        reads[:, :fb], WILDCARD).astype(jnp.int8)
    flens = jnp.minimum(read_lens, fb)
    dists = _filter_dists(
        wins.reshape(b * c, tile_len),
        jnp.repeat(fpat, c, axis=0), jnp.repeat(flens, c),
        m_bits=fb, k=filter_k, use_kernel=use_kernel, block_bt=block_bt,
        interpret=interpret).reshape(b, c, tile_len)
    # anchors past the search span could not fit a full alignment window
    dists = jnp.where(jnp.arange(tile_len)[None, None, :] < search_span,
                      dists, filter_k + 1)
    d_c = jnp.min(dists, axis=-1)  # [B, C]
    off_c = jnp.argmin(dists, axis=-1).astype(jnp.int32)
    live = votes > 0
    d_c = jnp.where(live, d_c, filter_k + 1)
    origin_c = jnp.where(live, tile_g * tile_stride + off_c, POS_SENTINEL)
    tile_m = jnp.where(live, tile_g, POS_SENTINEL)

    # --- lexicographic winner per read: min (distance, origin, tile)
    dm = jnp.min(d_c, axis=-1, keepdims=True)
    om = jnp.where(d_c == dm, origin_c, POS_SENTINEL)
    omin = jnp.min(om, axis=-1, keepdims=True)
    tm = jnp.where(om == omin, tile_m, POS_SENTINEL)
    ci = jnp.argmin(tm, axis=-1)  # [B]

    rows = jnp.arange(b)
    d_best = d_c[rows, ci]
    origin = origin_c[rows, ci]
    tile_best = tile_g[rows, ci]
    off = off_c[rows, ci]
    prefilter_ok = d_best <= filter_k

    # --- slice the anchored alignment window out of the winning tile
    gwin = jax.vmap(
        lambda wbuf, o: jax.lax.dynamic_slice(wbuf, (o,), (t_cap,)))(
        wins[rows, ci], off)
    t_len = jnp.clip(view.tile_valid[tile_local[rows, ci]] - off, 0, t_cap)

    # backbone coordinate of every window node, shipped with the window
    # so the align stage needs no graph arrays (clip mirrors the
    # whole-graph gather: nodes past the graph end read backbone[n-1])
    bb_len = view.backbone.shape[0]
    widx = origin[:, None] + jnp.arange(t_cap)[None, :]
    bwin = view.backbone[jnp.clip(widx - view.node_base, 0, bb_len - 1)]
    return CandidateStageResult(
        distance=d_best.astype(jnp.int32), origin=origin,
        tile=jnp.where(live[rows, ci], tile_best, POS_SENTINEL),
        gwin=gwin, bwin=bwin.astype(jnp.int32),
        t_len=t_len.astype(jnp.int32), prefilter_ok=prefilter_ok)


def align_winners(
    stage: CandidateStageResult,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    cfg: GenASMConfig,
    p_cap: int,
    backend: str,
    block_bt: int | None = None,
) -> GraphMapResult:
    """Align the per-read winning windows and translate paths to GAF terms.

    ``stage`` is a (possibly merged) :class:`CandidateStageResult`;
    windows are ``[B, t_cap]`` packed graph text and ``bwin`` carries
    the backbone coordinates, so this runs without the graph index —
    the "single batched align_batch call" of the sharded design.
    """
    from repro import align as align_dispatch

    read_lens = read_lens.astype(jnp.int32)
    t_cap = stage.gwin.shape[-1]
    pat = jnp.where(jnp.arange(p_cap)[None, :] < read_lens[:, None],
                    reads[:, :p_cap], WILDCARD).astype(jnp.int8)
    res = align_dispatch.align_batch(
        stage.gwin, pat, read_lens, stage.t_len, cfg=cfg, backend=backend,
        p_cap=p_cap, block_bt=block_bt)

    # window-relative node offsets -> global path -> backbone position
    rows = jnp.arange(stage.gwin.shape[0])
    live = res.nodes >= 0
    path = jnp.where(live, res.nodes + stage.origin[:, None], -1)
    bpath = jnp.where(
        live,
        jnp.take_along_axis(stage.bwin, jnp.clip(res.nodes, 0, t_cap - 1),
                            axis=-1), -1)
    first = jnp.argmax(bpath >= 0, axis=-1)  # first backbone node on path
    pos = bpath[rows, first]
    failed = res.failed | (~stage.prefilter_ok)
    return GraphMapResult(
        position=jnp.where(failed, -1, pos).astype(jnp.int32),
        distance=jnp.where(failed, -1, res.distance),
        ops=res.ops,
        n_ops=res.n_ops,
        path=jnp.where(failed[:, None], -1, path),
        failed=failed,
    )


def map_batch(
    garr: GraphArrays,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
    backend: str | None = None,
    block_bt: int | None = None,
) -> GraphMapResult:
    """Map a read batch against the tiled graph index.

    ``garr`` is the device half of a `GraphIndex` whose ``tile_stride``
    the caller passes statically (it shapes the tile→node arithmetic).
    ``backend`` resolves through `repro.align` with linear names mapped
    to their graph twins.
    """
    from repro import align as align_dispatch

    be_name = graph_backend_name(backend)
    use_kernel = align_dispatch.get_backend(be_name).uses_pallas
    interpret = align_dispatch.needs_interpret()

    n_tiles, tile_len = garr.tile_gtext.shape
    t_cap = p_cap + 2 * cfg.w
    search_span = tile_len - t_cap
    if search_span < tile_stride:
        raise ValueError(
            f"tile_len {tile_len} leaves a {search_span}-node anchor search "
            f"span < tile_stride {tile_stride} at p_cap {p_cap}; rebuild the "
            f"index with window >= {t_cap}")
    if filter_bits % 32:
        raise ValueError(f"filter_bits must be a multiple of 32, got "
                         f"{filter_bits}")

    stage = graph_candidate_stage(
        whole_graph_view(garr), reads, read_lens,
        tile_stride=tile_stride, n_tiles=n_tiles,
        backbone_len=garr.node_of_backbone.shape[0],
        n_nodes=garr.bases.shape[0], t_cap=t_cap,
        filter_bits=min(filter_bits, p_cap), filter_k=filter_k,
        max_candidates=max_candidates, minimizer_w=minimizer_w,
        minimizer_k=minimizer_k, use_kernel=use_kernel, block_bt=block_bt,
        interpret=interpret)
    return align_winners(stage, reads, read_lens, cfg=cfg, p_cap=p_cap,
                         backend=be_name, block_bt=block_bt)


def map_batch_index(gidx: GraphIndex, reads, read_lens, **kw
                    ) -> GraphMapResult:
    """`map_batch` with the geometry pulled off a host `GraphIndex`."""
    kw.setdefault("minimizer_w", gidx.minimizer_w)
    kw.setdefault("minimizer_k", gidx.minimizer_k)
    return map_batch(gidx.arrays, reads, read_lens,
                     tile_stride=gidx.tile_stride, **kw)
