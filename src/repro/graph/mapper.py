"""End-to-end sequence-to-graph read mapper (paper Figure 6-1, batched).

Seed-and-extend over a tiled graph index: MinSeed minimizer seeding on
the backbone → **one** batched candidate-window gather
(``tile_gtext[tile_ids]``) → **one** ``[B · max_candidates]`` BitAlign-DC
filter launch that scores *and* anchor-refines every candidate window
(per-node distances, argmin = refined start node) → windowed graph
alignment of each read's best window through `repro.align.align_batch`
(``graph_lax`` / ``graph_pallas``).  Contrast `core/segram/segram.py`'s
offline toy, which vmaps a per-candidate whole-window scan inside every
read — here the candidate axis is folded into the batch, so the kernel
sees one launch per stage instead of ``B × max_candidates`` traces.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bitvector import WILDCARD
from repro.core.genasm import GenASMConfig
from repro.core.segram.graph import HOP_LIMIT
from repro.core.segram.minimizer import seed_candidates

from .index import GraphArrays, GraphIndex
from .windowed import bitalign_search, unpack_graph_text

# linear backend names map to their graph twins so ``backend="auto"`` (or
# an engine configured with a linear name) serves the graph workload on
# the matching implementation tier
_GRAPH_TWIN = {"lax": "graph_lax", "ref": "graph_lax",
               "pallas_dc": "graph_pallas", "pallas_dc_v2": "graph_pallas"}


def graph_backend_name(backend: str | None = None) -> str:
    """Resolve a backend name (or None/"auto") to a graph backend."""
    from repro import align as align_dispatch

    name = align_dispatch.resolve_backend(backend).name
    return _GRAPH_TWIN.get(name, name)


class GraphMapResult(NamedTuple):
    position: jnp.ndarray  # int32 backbone coord of first aligned node (-1)
    distance: jnp.ndarray  # int32 edit distance (-1 if unmapped)
    ops: jnp.ndarray  # packed CIGAR
    n_ops: jnp.ndarray
    path: jnp.ndarray  # [B, cap] int32 global node ids per op (-1 for I/pad)
    failed: jnp.ndarray


def _filter_dists(wins_flat, fpat_flat, flens_flat, *, m_bits: int, k: int,
                  use_kernel: bool, block_bt: int | None, interpret: bool):
    """[BC, tile_len] per-node distances, kernel or pure-lax path."""
    bases, succ = unpack_graph_text(wins_flat)
    if use_kernel:
        from repro.align.batched import _pad_to_block
        from repro.kernels.bitalign import bitalign_dc_batch

        bc = wins_flat.shape[0]
        bt = min(block_bt or 128, max(8, bc))
        dists, _ = bitalign_dc_batch(
            _pad_to_block(bases, bt, 4), _pad_to_block(succ, bt, 0),
            _pad_to_block(fpat_flat, bt, WILDCARD),
            _pad_to_block(flens_flat, bt, m_bits),
            m_bits=m_bits, k=k, block_bt=bt, interpret=interpret)
        return dists[:bc]
    f = partial(bitalign_search, m_bits=m_bits, k=k)
    return jax.vmap(f)(bases, succ, fpat_flat, flens_flat)


def map_batch(
    garr: GraphArrays,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    tile_stride: int,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
    backend: str | None = None,
    block_bt: int | None = None,
) -> GraphMapResult:
    """Map a read batch against the tiled graph index.

    ``garr`` is the device half of a `GraphIndex` whose ``tile_stride``
    the caller passes statically (it shapes the tile→node arithmetic).
    ``backend`` resolves through `repro.align` with linear names mapped
    to their graph twins.
    """
    from repro import align as align_dispatch

    be_name = graph_backend_name(backend)
    use_kernel = align_dispatch.get_backend(be_name).uses_pallas
    interpret = align_dispatch.needs_interpret()

    b = reads.shape[0]
    c = max_candidates
    n = garr.bases.shape[0]
    big_l = garr.node_of_backbone.shape[0]
    n_tiles, tile_len = garr.tile_gtext.shape
    t_cap = p_cap + 2 * cfg.w
    search_span = tile_len - t_cap
    if search_span < tile_stride:
        raise ValueError(
            f"tile_len {tile_len} leaves a {search_span}-node anchor search "
            f"span < tile_stride {tile_stride} at p_cap {p_cap}; rebuild the "
            f"index with window >= {t_cap}")
    if filter_bits % 32:
        raise ValueError(f"filter_bits must be a multiple of 32, got "
                         f"{filter_bits}")
    read_lens = read_lens.astype(jnp.int32)

    # --- seed on the backbone minimizer table
    seed_fn = partial(seed_candidates, w=minimizer_w, k=minimizer_k,
                      max_candidates=c)
    starts, votes = jax.vmap(
        lambda r: seed_fn(r, garr.idx_hashes, garr.idx_positions))(reads)

    # backbone coordinate -> node id, with margin for leading variation
    sb = jnp.clip(starts - HOP_LIMIT, 0, big_l - 1)
    node = garr.node_of_backbone[sb]  # [B, C]
    tile = jnp.clip(node // tile_stride, 0, n_tiles - 1)

    # --- one gather: every candidate window for the whole batch
    wins = garr.tile_gtext[tile]  # [B, C, tile_len]

    # --- one filter launch over the flattened candidate axis
    fb = min(filter_bits, p_cap)
    fpat = jnp.where(
        jnp.arange(fb)[None, :] < jnp.minimum(read_lens, fb)[:, None],
        reads[:, :fb], WILDCARD).astype(jnp.int8)
    flens = jnp.minimum(read_lens, fb)
    dists = _filter_dists(
        wins.reshape(b * c, tile_len),
        jnp.repeat(fpat, c, axis=0), jnp.repeat(flens, c),
        m_bits=fb, k=filter_k, use_kernel=use_kernel, block_bt=block_bt,
        interpret=interpret).reshape(b, c, tile_len)
    # anchors past the search span could not fit a full alignment window
    dists = jnp.where(jnp.arange(tile_len)[None, None, :] < search_span,
                      dists, filter_k + 1)
    d_c = jnp.min(dists, axis=-1)  # [B, C]
    off_c = jnp.argmin(dists, axis=-1).astype(jnp.int32)
    d_c = jnp.where(votes > 0, d_c, filter_k + 1)

    rows = jnp.arange(b)
    ci = jnp.argmin(d_c, axis=-1)  # best candidate per read
    prefilter_ok = d_c[rows, ci] <= filter_k
    off = off_c[rows, ci]  # refined anchor offset inside the tile
    tile_b = tile[rows, ci]

    # --- slice the anchored alignment window out of the winning tile
    gwin = jax.vmap(
        lambda wbuf, o: jax.lax.dynamic_slice(wbuf, (o,), (t_cap,)))(
        wins[rows, ci], off)
    t_len = jnp.clip(garr.tile_valid[tile_b] - off, 0, t_cap)

    pat = jnp.where(jnp.arange(p_cap)[None, :] < read_lens[:, None],
                    reads[:, :p_cap], WILDCARD).astype(jnp.int8)
    res = align_dispatch.align_batch(
        gwin, pat, read_lens, t_len, cfg=cfg, backend=be_name, p_cap=p_cap,
        block_bt=block_bt)

    # --- window-relative node offsets -> global path -> backbone position
    origin = tile_b * tile_stride + off  # global node id of window node 0
    path = jnp.where(res.nodes >= 0, res.nodes + origin[:, None], -1)
    bpath = jnp.where(path >= 0, garr.backbone[jnp.clip(path, 0, n - 1)], -1)
    first = jnp.argmax(bpath >= 0, axis=-1)  # first backbone node on the path
    pos = bpath[rows, first]
    failed = res.failed | (~prefilter_ok)
    return GraphMapResult(
        position=jnp.where(failed, -1, pos).astype(jnp.int32),
        distance=jnp.where(failed, -1, res.distance),
        ops=res.ops,
        n_ops=res.n_ops,
        path=jnp.where(failed[:, None], -1, path),
        failed=failed,
    )


def map_batch_index(gidx: GraphIndex, reads, read_lens, **kw
                    ) -> GraphMapResult:
    """`map_batch` with the geometry pulled off a host `GraphIndex`."""
    kw.setdefault("minimizer_w", gidx.minimizer_w)
    kw.setdefault("minimizer_k", gidx.minimizer_k)
    return map_batch(gidx.arrays, reads, read_lens,
                     tile_stride=gidx.tile_stride, **kw)
