"""The sequence-to-graph alignment backends (registered on import).

Two entries in the `repro.align` registry, sharing the uniform dispatch
signature:

  * ``graph_lax``    — `windowed.graph_align` vmapped (pure-`lax` BitAlign
    DC + graph TB inside the shared window loop)
  * ``graph_pallas`` — batched window loop driving the Pallas BitAlign DC
    kernel (`repro.kernels.bitalign`): the batch advances through its
    window steps together, one ``[B, w]`` kernel launch per step, with
    the graph traceback vmapped over the kernel's R-only store — the
    same inverted-loop strategy as `repro.align.batched`.

``texts`` may be **packed graph text** (uint32, see `windowed`) or plain
int8 linear text — the latter is packed as a hop-0 chain, which is what
lets the linear conformance suite (and the ``REPRO_ALIGN_BACKEND``
matrix) drive the graph backends with unchanged inputs and expect
bit-identical results against ``lax``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.align.api import register_backend
from repro.align.batched import _pad_to_block
from repro.core.bitvector import pattern_bitmasks
from repro.core.genasm import AlignResult, GenASMConfig, pad_pattern, \
    window_commit
from repro.core.genasm_tb import OP_PAD

from .windowed import (_graph_buf_cap, _scatter_windows, graph_align,
                       pack_linear_text, pad_graph_text, unpack_graph_text,
                       window_tb_graph)


def as_graph_text(texts: jnp.ndarray) -> jnp.ndarray:
    """Accept packed graph text (uint32) or plain int8 text (chain-packed)."""
    texts = jnp.asarray(texts)
    if texts.dtype == jnp.uint32:
        return texts
    return pack_linear_text(texts)


def _graph_lax_fn(texts, patterns, p_lens, t_lens, *, cfg: GenASMConfig,
                  p_cap: int, emit_cigar: bool, block_bt: int,
                  interpret: bool):
    del block_bt, interpret  # no kernel underneath
    f = partial(graph_align, cfg=cfg, p_cap=p_cap, emit_cigar=emit_cigar)
    return jax.vmap(f)(as_graph_text(texts), patterns,
                       jnp.asarray(p_lens, jnp.int32),
                       jnp.asarray(t_lens, jnp.int32))


@partial(jax.jit, static_argnames=("cfg", "p_cap", "emit_cigar", "block_bt",
                                   "interpret"))
def batched_graph_align(
    texts: jnp.ndarray,
    patterns: jnp.ndarray,
    p_lens: jnp.ndarray,
    t_lens: jnp.ndarray,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int | None = None,
    emit_cigar: bool = True,
    block_bt: int = 128,
    interpret: bool = True,
) -> AlignResult:
    """Windowed BitAlign over a batch, DC on the Pallas kernel."""
    from repro.kernels.bitalign import bitalign_dc_batch

    if p_cap is None:
        p_cap = int(patterns.shape[-1])
    n_win = cfg.n_windows(p_cap)
    max_steps = 2 * cfg.commit  # ops emitted per window; cap = cfg.ops_cap
    w, o, k = cfg.w, cfg.o, cfg.k
    b = texts.shape[0]
    p_lens = p_lens.astype(jnp.int32)
    t_lens = t_lens.astype(jnp.int32)
    bt = min(block_bt, max(8, b))
    pad_b = b + (-b) % bt

    gtexts = as_graph_text(texts)
    pats = jax.vmap(lambda p, pl: pad_pattern(p, pl, p_cap, cfg))(
        patterns, p_lens)
    gbufs = jax.vmap(
        lambda t, tl: pad_graph_text(t, tl, _graph_buf_cap(p_cap, cfg), cfg))(
        gtexts, t_lens)

    slice_w = jax.vmap(lambda buf, i: lax.dynamic_slice(buf, (i,), (w,)))
    tb_fn = jax.vmap(
        partial(window_tb_graph, w=w, o=o, k=k, affine=cfg.affine))
    full_w = jnp.full((pad_b,), w, jnp.int32)  # no tail mask: full windows

    def window_step(carry, _):
        cur_p, cur_t = carry[0], carry[1]
        sub_p = slice_w(pats, cur_p)  # [B, w]
        sub_g = slice_w(gbufs, cur_t)
        bases, succ = unpack_graph_text(sub_g)
        d_all, r_all = bitalign_dc_batch(
            _pad_to_block(bases, bt, 4), _pad_to_block(succ, bt, 0),
            _pad_to_block(sub_p, bt, 4), full_w,
            m_bits=w, k=k, block_bt=bt, interpret=interpret)
        d_min = d_all[:b, 0]  # anchored at window node 0
        store = r_all[:b]  # [B, w, k+1, nw]
        cap_p = jnp.minimum(jnp.int32(cfg.commit), p_lens - cur_p)
        pm = jax.vmap(lambda p: pattern_bitmasks(p, w))(sub_p)
        pc, tc, err, ops, n_ops, nodes, stuck = tb_fn(
            store, succ, bases, pm, jnp.minimum(d_min, k), cap_p)
        new_carry, n_emit = window_commit(
            carry, d_min=d_min, pc=pc, tc=tc, err=err, n_ops=n_ops,
            stuck=stuck, p_len=p_lens, k=k)
        nodes = jnp.where(nodes >= 0, nodes + cur_t[:, None], -1)
        return new_carry, (ops, nodes, n_emit)

    zeros = jnp.zeros((b,), jnp.int32)
    init = (zeros, zeros, zeros, jnp.zeros((b,), bool), p_lens <= 0)
    (fin_p, fin_t, dist, failed, done), (ops_w, nodes_w, n_ops_w) = lax.scan(
        window_step, init, None, length=n_win)
    failed = failed | (~done)
    ops_w = jnp.swapaxes(ops_w, 0, 1)  # [B, n_win, max_steps]
    nodes_w = jnp.swapaxes(nodes_w, 0, 1)
    n_ops_w = jnp.swapaxes(n_ops_w, 0, 1)  # [B, n_win]

    cap = cfg.ops_cap(p_cap)
    if emit_cigar:
        out_ops = jax.vmap(
            lambda v, n: _scatter_windows(v, n, cap, OP_PAD, jnp.int8))(
            ops_w, n_ops_w)
        out_nodes = jax.vmap(
            lambda v, n: _scatter_windows(v, n, cap, -1, jnp.int32))(
            nodes_w, n_ops_w)
    else:
        out_ops = jnp.full((b, 1), OP_PAD, jnp.int8)
        out_nodes = None
    n_total = jnp.sum(n_ops_w, axis=-1)

    return AlignResult(
        distance=jnp.where(failed, jnp.int32(-1), dist),
        ops=out_ops,
        n_ops=n_total,
        text_consumed=fin_t,
        failed=failed,
        nodes=out_nodes,
    )


def _graph_pallas_fn(texts, patterns, p_lens, t_lens, *, cfg: GenASMConfig,
                     p_cap: int, emit_cigar: bool, block_bt: int,
                     interpret: bool):
    return batched_graph_align(
        texts, patterns, p_lens, t_lens, cfg=cfg, p_cap=p_cap,
        emit_cigar=emit_cigar, block_bt=block_bt, interpret=interpret)


register_backend(
    "graph_lax", _graph_lax_fn,
    description="pure-jax.lax windowed BitAlign (sequence-to-graph; accepts "
                "packed graph text or plain int8 text as a chain)")
register_backend(
    "graph_pallas", _graph_pallas_fn, uses_pallas=True,
    description="Pallas BitAlign DC kernel in the batched window loop "
                "(R-only TB store, graph traceback on host lanes)")
