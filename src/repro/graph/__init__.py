"""repro.graph — sequence-to-graph mapping as a first-class workload.

DESIGN.md §10: windowed BitAlign sharing the linear aligner's window
loop (`windowed`), the ``graph_lax``/``graph_pallas`` entries in the
`repro.align` registry (`backends`), the tiled graph-reference index
with epoch hooks (`index`), and the batched graph mapper (`mapper`).
"""
from .backends import as_graph_text, batched_graph_align  # noqa: F401
from .index import (EpochedGraphIndex, GraphArrays, GraphIndex,  # noqa: F401
                    build_epoched_graph_index, build_graph_index,
                    load_graph_index, save_graph_index)
from .mapper import (GraphMapExecutor, GraphMapResult,  # noqa: F401
                     graph_backend_name, map_batch, map_batch_index,
                     tile_prefilter, tile_rung, unmapped_result)
from .windowed import (bitalign_search, graph_align,  # noqa: F401
                       pack_graph_text, pack_linear_text, unpack_graph_text)
