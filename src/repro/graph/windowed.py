"""Windowed BitAlign: sequence-to-graph alignment as chained DC+TB windows.

The paper's BitAlign (§6.7) is GenASM's divide-and-conquer dataflow with
one generalization: scanning the linearized subgraph in reverse
topological order, the "previous text character" status bitvectors are
the AND-combination of every successor's bitvectors within the hopBits
window (Figure 6-9).  This module runs that generalized DC inside the
*same* window loop as the linear aligner — `core/genasm.window_commit`
is shared, the traceback mirrors `core/genasm_tb.window_tb_r` bit for
bit — so on a degenerate (pure-backbone) graph the emitted distances,
CIGARs and text advances are **bit-identical** to the `lax` backend.
That equivalence is the graph conformance suite's anchor.

Graph windows travel through the uniform dispatch signature as **packed
graph text**: one uint32 per node, base id in the low 8 bits and the
window-masked hopBits in bits 8..8+HOP_LIMIT (19 bits used, so the
packing stays inside JAX's default 32-bit world).  ``pack_linear_text``
packs a plain int8 text as a hop-0 chain, which is how the graph
backends accept the linear conformance inputs unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bitvector import (SENTINEL, get_bit, msb, n_words, ones,
                                  pattern_bitmasks, shl1)
from repro.core.genasm import (AlignResult, GenASMConfig, pad_pattern,
                               window_commit)
from repro.core.genasm_tb import OP_D, OP_I, OP_M, OP_PAD, OP_X
from repro.core.segram.graph import HOP_LIMIT

_HOP_MASK = (1 << HOP_LIMIT) - 1
# sentinel pad node: matches nothing, chains to its neighbour (hop 0) so a
# packed linear text and the linear aligner's sentinel tail agree bitwise
SENT_NODE = (1 << 8) | SENTINEL


def pack_graph_text(bases: jnp.ndarray, succ_bits: jnp.ndarray) -> jnp.ndarray:
    """[..., n] (int8 bases, uint32 hopBits) -> packed uint32 graph text."""
    b = jnp.asarray(bases).astype(jnp.uint32) & jnp.uint32(0xFF)
    s = jnp.asarray(succ_bits).astype(jnp.uint32) & jnp.uint32(_HOP_MASK)
    return (s << 8) | b


def pack_linear_text(text: jnp.ndarray) -> jnp.ndarray:
    """Pack a plain int8 text as a hop-0 chain graph."""
    text = jnp.asarray(text)
    return pack_graph_text(text, jnp.ones(text.shape, jnp.uint32))


def unpack_graph_text(gtext: jnp.ndarray):
    """Packed uint32 graph text -> (bases int8, succ_bits uint32)."""
    base = (gtext & jnp.uint32(0xFF)).astype(jnp.int8)
    succ = (gtext >> 8) & jnp.uint32(_HOP_MASK)
    return base, succ


def pad_graph_text(gtext: jnp.ndarray, t_len, cap: int, cfg: GenASMConfig):
    """Pad/trim a packed graph-text buffer to ``cap + w`` with sentinel
    chain nodes after ``t_len`` (the graph twin of `genasm.pad_text`)."""
    size = cap + cfg.w
    buf = jnp.full((size,), SENT_NODE, jnp.uint32)
    buf = lax.dynamic_update_slice(buf, jnp.asarray(gtext, jnp.uint32)[:size],
                                   (0,))
    idx = jnp.arange(size)
    return jnp.where(idx < t_len, buf, jnp.uint32(SENT_NODE))


def _graph_buf_cap(p_cap: int, cfg: GenASMConfig) -> int:
    # a window's node advance can overshoot the linear commit by up to one
    # hop, so the buffer carries HOP_LIMIT extra nodes per window
    return p_cap + cfg.n_windows(p_cap) * (cfg.commit + HOP_LIMIT)


@partial(jax.jit, static_argnames=("w", "k"))
def window_dc_graph(bases: jnp.ndarray, succ: jnp.ndarray,
                    sub_pattern: jnp.ndarray, *, w: int, k: int):
    """BitAlign DC over one ``w``-node subgraph window (R-only store).

    ``bases``/``succ``: [w] window nodes (hops past the window end fall on
    the all-ones boundary via the hop ring buffer, no masking needed).
    Returns ``(d_min int32, store [w, k+1, nw] uint32)`` — ``d_min`` is
    anchored at node 0, ``store[i]`` the status rows R of node ``i``.
    On a hop-0 chain this equals `core/genasm_dc.window_dc_r` bitwise.
    """
    nw = n_words(w)
    pm = pattern_bitmasks(sub_pattern, w)
    H = HOP_LIMIT
    boundary = ones((k + 1, nw))

    def step(hist, inputs):
        # hist: [H, k+1, nw] — hist[h] = R of node i + 1 + h
        base, sb = inputs
        hop_ok = ((sb >> jnp.arange(H, dtype=jnp.uint32)) & 1).astype(bool)
        masked = jnp.where(hop_ok[:, None, None], hist, boundary[None])
        comb = masked[0]
        for h in range(1, H):
            comb = comb & masked[h]  # [k+1, nw]; all-ones when no successor
        cur_pm = pm[base]
        R0 = shl1(comb[0]) | cur_pm
        rows = [R0]
        for d in range(1, k + 1):
            D = comb[d - 1]
            S = shl1(comb[d - 1])
            I = shl1(rows[d - 1])
            M = shl1(comb[d]) | cur_pm
            rows.append(D & S & I & M)
        R = jnp.stack(rows)  # [k+1, nw]
        return jnp.concatenate([R[None], hist[:-1]], axis=0), R

    hist0 = jnp.broadcast_to(boundary, (H, k + 1, nw))
    _, rows_rev = lax.scan(
        step, hist0, (bases[::-1].astype(jnp.int32), succ[::-1]))
    store = rows_rev[::-1]  # [w, k+1, nw], indexed by node position
    m = msb(store[0])
    found = m == 0
    d_min = jnp.where(jnp.any(found), jnp.argmax(found), k + 1).astype(jnp.int32)
    return d_min, store


@partial(jax.jit, static_argnames=("m_bits", "k"))
def bitalign_search(bases: jnp.ndarray, succ: jnp.ndarray,
                    pattern: jnp.ndarray, p_len, *, m_bits: int, k: int):
    """Distances-only whole-pattern BitAlign over a subgraph window.

    The graph mapper's pre-alignment filter: ``dists[i]`` is the minimum
    ``d ≤ k`` aligning the full (tail-masked) pattern to a path starting
    at node ``i`` (``k + 1`` when none) — one pass both *filters* a
    candidate window and *refines* its anchor node (argmin), exactly how
    the linear mapper uses `genasm_dc.bitap_search`.  Bitwise identical
    to the dists output of `repro.kernels.bitalign.bitalign_dc_batch`
    (the tail handling mirrors the kernel), which the graph conformance
    suite pins — the mapper may take either path per backend.
    """
    from repro.core.segram.bitalign import _tail_mask

    nw = n_words(m_bits)
    pm = pattern_bitmasks(pattern, m_bits)
    H = HOP_LIMIT
    tail = _tail_mask(p_len, m_bits)  # [nw]
    tail_rows = jnp.broadcast_to(tail, (k + 1, nw))

    def step(hist, inputs):
        base, sb = inputs
        hop_ok = ((sb >> jnp.arange(H, dtype=jnp.uint32)) & 1).astype(bool)
        masked = jnp.where(hop_ok[:, None, None], hist, tail_rows[None])
        comb = masked[0]
        for h in range(1, H):
            comb = comb & masked[h]
        cur_pm = pm[base]
        rows = [(shl1(comb[0]) | cur_pm) & tail]
        for d in range(1, k + 1):
            D = comb[d - 1]
            S = shl1(comb[d - 1])
            I = shl1(rows[d - 1])
            M = shl1(comb[d]) | cur_pm
            rows.append(D & S & I & M & tail)
        R = jnp.stack(rows)
        m = msb(R)
        found = m == 0
        d_i = jnp.where(jnp.any(found), jnp.argmax(found), k + 1
                        ).astype(jnp.int32)
        return jnp.concatenate([R[None], hist[:-1]], axis=0), d_i

    hist0 = jnp.broadcast_to(tail_rows, (H, k + 1, nw))
    _, dists_rev = lax.scan(
        step, hist0, (bases[::-1].astype(jnp.int32), succ[::-1]))
    return dists_rev[::-1]


@partial(jax.jit, static_argnames=("w", "o", "k", "affine"))
def window_tb_graph(store: jnp.ndarray, succ: jnp.ndarray, bases: jnp.ndarray,
                    pm: jnp.ndarray, d_start, cap_p, *, w: int, o: int,
                    k: int, affine: bool = True):
    """Graph traceback over one window's R-only store.

    The check-vector derivation mirrors `genasm_tb.window_tb_r` with the
    single-successor row replaced by the hop combine: an op that consumes
    a node is valid iff *some* in-window successor's R continues the
    0-chain, and the successor actually taken (lowest qualifying hop) is
    how the walk advances through the linearization — that choice is the
    node path GAF reports.

    Returns ``(pc, tc, err_used, ops [2*(w-o)] int8, n_ops,
    nodes [2*(w-o)] int32 window-local node per op (-1 for I), stuck)``.
    ``tc`` is the node advance for the next window (hops included).
    """
    max_steps = 2 * (w - o)
    cap_t = jnp.int32(w - o)
    cap_p = jnp.asarray(cap_p, jnp.int32)
    H = HOP_LIMIT
    hop_rng = jnp.arange(H)
    no_hops = jnp.zeros((H,), bool)

    def succ_rows(ti, de):
        """[H, nw] successor R rows (all-ones past the window boundary)."""
        pos = jnp.clip(ti + 1 + hop_rng, 0, w - 1)
        rows = store[pos, de]
        in_w = (ti + 1 + hop_rng) < w
        return jnp.where(in_w[:, None], rows, jnp.uint32(0xFFFFFFFF))

    def body(_, st):
        patternI, textI, curError, prev_op, pc, tc, n_ops, ops, nodes, stuck = st
        active = (pc < cap_p) & (tc < cap_t) & (patternI >= 0) & (~stuck)
        ti = jnp.clip(textI, 0, w - 1)
        de = jnp.clip(curError, 0, k)
        dem1 = jnp.clip(curError - 1, 0, k)
        pi = jnp.clip(patternI, 0, w - 1)
        pim1 = jnp.maximum(pi - 1, 0)
        at0 = pi == 0  # shl1's shifted-in 0: the check bit is always clear

        smask = ((succ[ti] >> hop_rng.astype(jnp.uint32)) & 1).astype(bool)
        rows_d = succ_rows(ti, de)
        rows_dm1 = succ_rows(ti, dem1)

        def bits0(rows, b):
            return jax.vmap(lambda v: get_bit(v, b))(rows) == 0

        m_hops = smask & (at0 | bits0(rows_d, pim1))
        s_hops = smask & (at0 | bits0(rows_dm1, pim1))
        d_hops = smask & bits0(rows_dm1, pi)

        pm_bit = get_bit(pm[bases[ti]], pi) == 0
        mbit = pm_bit & (at0 | jnp.any(m_hops))
        sbit = at0 | jnp.any(s_hops)
        ibit = jnp.where(at0, True, get_bit(store[ti, dem1], pim1) == 0)
        dbit = jnp.any(d_hops)

        has_err = curError > 0
        m_ok = mbit
        s_ok = sbit & has_err
        i_ok = ibit & has_err
        d_ok = dbit & has_err

        if affine:
            cands = jnp.stack([
                i_ok & (prev_op == OP_I), d_ok & (prev_op == OP_D),
                m_ok, s_ok, i_ok, d_ok,
            ])
            codes = jnp.array([OP_I, OP_D, OP_M, OP_X, OP_I, OP_D], jnp.int32)
            hopsets = jnp.stack([no_hops, d_hops, m_hops, s_hops, no_hops,
                                 d_hops])
        else:
            cands = jnp.stack([m_ok, s_ok, i_ok, d_ok])
            codes = jnp.array([OP_M, OP_X, OP_I, OP_D], jnp.int32)
            hopsets = jnp.stack([m_hops, s_hops, no_hops, d_hops])

        any_ok = jnp.any(cands)
        sel = jnp.argmax(cands)
        op = codes[sel]
        new_stuck = stuck | (active & ~any_ok)
        take = active & any_ok

        consume_p = take & ((op == OP_M) | (op == OP_X) | (op == OP_I))
        consume_t = take & ((op == OP_M) | (op == OP_X) | (op == OP_D))
        err_dec = take & (op != OP_M)
        # lowest qualifying hop; falls back to hop 0 (the chain neighbour)
        # when the walk ends on this op and no successor constraint applies
        h_star = jnp.argmax(hopsets[sel]).astype(jnp.int32)
        adv = jnp.where(consume_t, 1 + h_star, 0)

        ops = ops.at[n_ops].set(jnp.where(take, op.astype(jnp.int8), ops[n_ops]))
        nodes = nodes.at[n_ops].set(
            jnp.where(consume_t, ti, jnp.where(take, -1, nodes[n_ops])))
        return (
            patternI - consume_p.astype(jnp.int32),
            textI + adv,
            curError - err_dec.astype(jnp.int32),
            jnp.where(take, op, prev_op),
            pc + consume_p.astype(jnp.int32),
            tc + adv,
            n_ops + take.astype(jnp.int32),
            ops,
            nodes,
            new_stuck,
        )

    st0 = (
        jnp.int32(w - 1),  # patternI: MSB = pattern[0]
        jnp.int32(0),  # textI (window-local node)
        d_start.astype(jnp.int32),
        jnp.int32(OP_PAD),  # prev_op
        jnp.int32(0),  # pc
        jnp.int32(0),  # tc
        jnp.int32(0),  # n_ops
        jnp.full((max_steps,), OP_PAD, jnp.int8),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.asarray(False),
    )
    _, _, curError, _, pc, tc, n_ops, ops, nodes, stuck = lax.fori_loop(
        0, max_steps, body, st0)
    err_used = d_start.astype(jnp.int32) - curError
    return pc, tc, err_used, ops, n_ops, nodes, stuck


def _scatter_windows(vals_w, n_ops_w, cap: int, fill, dtype):
    """Concatenate per-window op-aligned buffers into one [cap] buffer."""
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(n_ops_w)[:-1]])
    max_steps = vals_w.shape[-1]
    step_idx = jnp.arange(max_steps)[None, :]
    valid = step_idx < n_ops_w[:, None]
    pos = jnp.where(valid, offsets[:, None] + step_idx, cap)
    out = jnp.full((cap,), fill, dtype)
    return out.at[pos.reshape(-1)].set(vals_w.reshape(-1), mode="drop")


@partial(jax.jit, static_argnames=("cfg", "p_cap", "emit_cigar"))
def graph_align(
    gtext: jnp.ndarray,
    pattern: jnp.ndarray,
    p_len: jnp.ndarray,
    t_len: jnp.ndarray,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int | None = None,
    emit_cigar: bool = True,
) -> AlignResult:
    """Align ``pattern[:p_len]`` to the packed subgraph ``gtext[:t_len]``,
    anchored at node 0 (the graph twin of `core/genasm.align`).

    Semi-global: the pattern must be fully consumed, trailing graph is
    free.  ``AlignResult.nodes`` carries the window-relative node offset
    each op consumed (-1 for insertions) — the path GAF reports.
    """
    if p_cap is None:
        p_cap = int(pattern.shape[-1])
    n_win = cfg.n_windows(p_cap)
    max_steps = 2 * cfg.commit
    w, o, k = cfg.w, cfg.o, cfg.k

    pat = pad_pattern(pattern, p_len, p_cap, cfg)
    gbuf = pad_graph_text(gtext, t_len, _graph_buf_cap(p_cap, cfg), cfg)

    def window_step(carry, _):
        cur_p, cur_t = carry[0], carry[1]
        sub_p = lax.dynamic_slice(pat, (cur_p,), (w,))
        sub_g = lax.dynamic_slice(gbuf, (cur_t,), (w,))
        bases, succ = unpack_graph_text(sub_g)
        d_min, store = window_dc_graph(bases, succ, sub_p, w=w, k=k)
        cap_p = jnp.minimum(jnp.int32(cfg.commit), p_len - cur_p)
        pm = pattern_bitmasks(sub_p, w)
        pc, tc, err, ops, n_ops, nodes, stuck = window_tb_graph(
            store, succ, bases, pm, jnp.minimum(d_min, k), cap_p,
            w=w, o=o, k=k, affine=cfg.affine)
        new_carry, n_emit = window_commit(
            carry, d_min=d_min, pc=pc, tc=tc, err=err, n_ops=n_ops,
            stuck=stuck, p_len=p_len, k=k)
        nodes = jnp.where(nodes >= 0, nodes + cur_t, -1)
        return new_carry, (ops, nodes, n_emit)

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.asarray(False),
            p_len <= 0)
    (fin_p, fin_t, dist, failed, done), (ops_w, nodes_w, n_ops_w) = lax.scan(
        window_step, init, None, length=n_win)
    failed = failed | (~done)

    if emit_cigar:
        cap = n_win * max_steps
        out_ops = _scatter_windows(ops_w, n_ops_w, cap, OP_PAD, jnp.int8)
        out_nodes = _scatter_windows(nodes_w, n_ops_w, cap, -1, jnp.int32)
    else:
        out_ops = jnp.full((1,), OP_PAD, jnp.int8)
        out_nodes = None
    n_total = jnp.sum(n_ops_w)

    return AlignResult(
        distance=jnp.where(failed, jnp.int32(-1), dist),
        ops=out_ops,
        n_ops=n_total,
        text_consumed=fin_t,
        failed=failed,
        nodes=out_nodes,
    )
