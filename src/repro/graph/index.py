"""Tiled graph-reference index: graphs far longer than one BitAlign window.

The whole linearized graph lives on device once, *plus* a tiled view:
overlapping fixed-size tiles at ``tile_stride`` node pitch, each packed
as graph text (`windowed.pack_graph_text`) with its hopBits cut at the
tile boundary by the one shared masking rule
(`core/segram/graph.hop_boundary_mask`).  A candidate backbone position
maps to a tile via ``node // tile_stride`` — no per-read dynamic slicing
of the full graph; the mapper's candidate windows are **one gather**
``tile_gtext[tile_ids]`` per batch, which is what turns per-read
per-candidate scans into a single ``[B, max_candidates]`` BitAlign-DC
launch per step.

Tile geometry: ``tile_len = tile_stride + margin + window``.  A
candidate's anchor is refined inside ``[0, tile_stride + margin)`` (the
first ``tile_stride`` nodes own the tile, ``margin`` absorbs seed
quantization + leading-variation drift), and ``window`` nodes of
alignment text always remain past any refined anchor.  Edges whose hop
would exceed ``HOP_LIMIT`` keep raising in `build_graph` — the tiling
re-chunks *windows*, not edges, so the invariant the BitAlign PE design
relies on (Figure 6-8's bounded hop queue) holds per tile by
construction.

``EpochedGraphIndex`` mirrors `core/minimizer_index.EpochedIndex`: the
serve engine keys its result cache and compiled executors on the epoch,
so hot-swapping a rebuilt graph atomically invalidates both.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from pathlib import Path
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitvector import SENTINEL
from repro.core.filter import QGRAM_Q, qgram_bloom
from repro.core.segram.graph import (GenomeGraph, Variant, build_graph,
                                     hop_boundary_mask)
from repro.core.segram.minimizer import build_index

from .windowed import pack_graph_text

DEFAULT_WINDOW = 256
DEFAULT_STRIDE = 64
DEFAULT_MARGIN = 64


class GraphArrays(NamedTuple):
    """Device half of the index (a jit-traceable pytree)."""

    bases: jnp.ndarray  # [N] int8 linearized graph
    succ_bits: jnp.ndarray  # [N] uint32 hopBits
    backbone: jnp.ndarray  # [N] int32 backbone coord (-1 for alt nodes)
    node_of_backbone: jnp.ndarray  # [L] int32
    tile_gtext: jnp.ndarray  # [C, tile_len] uint32 packed tiles
    tile_valid: jnp.ndarray  # [C] int32 valid node count per tile
    idx_hashes: jnp.ndarray  # [M] uint32 sorted backbone minimizers
    idx_positions: jnp.ndarray  # [M] int32
    tile_bloom: jnp.ndarray  # [C, BLOOM_WORDS] uint32 per-tile q-gram Bloom
    tile_slack: jnp.ndarray  # [C] int32 (q-1)·(hop>1 edges) screen slack


@dataclass
class GraphIndex:
    """Host handle: device arrays + the static geometry the mapper needs."""

    arrays: GraphArrays
    ref: np.ndarray  # host reference copy (GAF tlen, refresh)
    tile_len: int
    tile_stride: int
    minimizer_w: int
    minimizer_k: int
    window: int = DEFAULT_WINDOW  # recorded so refresh() reproduces geometry
    margin: int = DEFAULT_MARGIN

    @property
    def n_nodes(self) -> int:
        return int(self.arrays.bases.shape[0])

    @property
    def n_tiles(self) -> int:
        return int(self.arrays.tile_gtext.shape[0])

    @property
    def ref_len(self) -> int:
        return int(len(self.ref))


def _build_tiles(bases: jnp.ndarray, succ: jnp.ndarray, *, tile_len: int,
                 tile_stride: int):
    n = bases.shape[0]
    c = max(1, -(-int(n) // tile_stride))
    starts = jnp.arange(c) * tile_stride
    idx = starts[:, None] + jnp.arange(tile_len)[None, :]
    inb = idx < n
    idxc = jnp.clip(idx, 0, n - 1)
    tb = jnp.where(inb, bases[idxc], SENTINEL).astype(jnp.int8)
    ts = jnp.where(inb, succ[idxc], jnp.uint32(0))
    valid = jnp.clip(n - starts, 0, tile_len).astype(jnp.int32)
    mask = jax.vmap(lambda v: hop_boundary_mask(tile_len, v))(valid)
    ts_m = ts & mask
    # tile pre-filter payload: a Bloom filter over the tile's q-grams and
    # the q-gram-lemma slack for alt paths — a matching path may spell up
    # to q-1 q-grams across each hop>1 edge (bits 1.. of the masked
    # hopBits) that are not substrings of the linearization
    bloom = jax.vmap(qgram_bloom)(tb, valid)
    in_valid = jnp.arange(tile_len)[None, :] < valid[:, None]
    hop_edges = jnp.where(in_valid, jax.lax.population_count(ts_m >> 1), 0)
    slack = ((QGRAM_Q - 1) *
             jnp.sum(hop_edges, axis=-1)).astype(jnp.int32)
    return pack_graph_text(tb, ts_m), valid, bloom, slack


def build_graph_index(
    ref: np.ndarray,
    variants: Sequence[Variant] = (),
    *,
    w: int = 10,
    k: int = 15,
    freq_frac: float = 0.0002,
    window: int = DEFAULT_WINDOW,
    tile_stride: int = DEFAULT_STRIDE,
    margin: int = DEFAULT_MARGIN,
    graph: GenomeGraph | None = None,
) -> GraphIndex:
    """Offline pre-processing (paper §6.5): graph + minimizers + tiles.

    ``window`` must cover the largest alignment text cap the mapper will
    slice (``p_cap + 2·cfg.w``); `repro.graph.mapper.map_batch` checks.
    """
    g = graph if graph is not None else build_graph(ref, list(variants))
    idx = build_index(ref, w=w, k=k, freq_frac=freq_frac)
    bases = jnp.asarray(g.bases)
    succ = jnp.asarray(g.succ_bits)
    tile_len = tile_stride + margin + window
    tiles, valid, bloom, slack = _build_tiles(bases, succ, tile_len=tile_len,
                                              tile_stride=tile_stride)
    arrays = GraphArrays(
        bases=bases,
        succ_bits=succ,
        backbone=jnp.asarray(g.backbone),
        node_of_backbone=jnp.asarray(g.node_of_backbone),
        tile_gtext=tiles,
        tile_valid=valid,
        idx_hashes=jnp.asarray(idx.hashes),
        idx_positions=jnp.asarray(idx.positions),
        tile_bloom=bloom,
        tile_slack=slack,
    )
    return GraphIndex(arrays=arrays, ref=np.asarray(ref, np.int8),
                      tile_len=tile_len, tile_stride=tile_stride,
                      minimizer_w=w, minimizer_k=k, window=window,
                      margin=margin)


class EpochedGraphIndex:
    """Epoch-stamped handle around a ``GraphIndex`` (serving hot swap).

    ``refresh()`` rebuilds from a new reference and/or variant list and
    bumps ``epoch``; the serve engine's result cache keys on the epoch so
    every result mapped against the old graph is atomically invalidated,
    and its compiled executors re-trace on the new tile shapes.
    """

    def __init__(self, index: GraphIndex, *, variants: Sequence[Variant] = (),
                 epoch: int = 0, **build_kw):
        self._lock = threading.Lock()
        self._index = index
        self._variants = tuple(variants)
        self.epoch = epoch
        kw = dict(w=index.minimizer_w, k=index.minimizer_k,
                  tile_stride=index.tile_stride, window=index.window,
                  margin=index.margin)
        kw.update(build_kw)  # explicit build kwargs win
        self._build_kw = kw

    @property
    def index(self) -> GraphIndex:
        return self._index

    def current(self) -> tuple[GraphIndex, int]:
        """Consistent (index, epoch) pair for one mapping batch."""
        with self._lock:
            return self._index, self.epoch

    def refresh(self, ref: np.ndarray,
                variants: Sequence[Variant] | None = None, **build_kw) -> int:
        """Rebuild from a new reference/variant set; returns the new epoch."""
        kw = {**self._build_kw, **build_kw}
        vs = self._variants if variants is None else tuple(variants)
        new = build_graph_index(ref, vs, **kw)
        with self._lock:
            self._index = new
            self._variants = vs
            self._build_kw = kw
            self.epoch += 1
            return self.epoch


def build_epoched_graph_index(ref: np.ndarray,
                              variants: Sequence[Variant] = (),
                              **build_kw) -> EpochedGraphIndex:
    """Build a graph index wrapped in an epoch-stamped serving handle."""
    return EpochedGraphIndex(build_graph_index(ref, variants, **build_kw),
                             variants=variants, **build_kw)


def save_graph_index(path: str | Path, gidx: GraphIndex) -> None:
    """Persist to npz (tiles are re-derived on load, not stored)."""
    a = gidx.arrays
    np.savez_compressed(
        path,
        bases=np.asarray(a.bases),
        succ_bits=np.asarray(a.succ_bits),
        backbone=np.asarray(a.backbone),
        node_of_backbone=np.asarray(a.node_of_backbone),
        idx_hashes=np.asarray(a.idx_hashes),
        idx_positions=np.asarray(a.idx_positions),
        ref=np.asarray(gidx.ref),
        meta=np.asarray([gidx.tile_len, gidx.tile_stride, gidx.minimizer_w,
                         gidx.minimizer_k, gidx.window, gidx.margin],
                        np.int64),
    )


def load_graph_index(path: str | Path) -> GraphIndex:
    with np.load(path) as z:
        tile_len, tile_stride, w, k, window, margin = (
            int(x) for x in z["meta"])
        bases = jnp.asarray(z["bases"])
        succ = jnp.asarray(z["succ_bits"])
        tiles, valid, bloom, slack = _build_tiles(
            bases, succ, tile_len=tile_len, tile_stride=tile_stride)
        arrays = GraphArrays(
            bases=bases,
            succ_bits=succ,
            backbone=jnp.asarray(z["backbone"]),
            node_of_backbone=jnp.asarray(z["node_of_backbone"]),
            tile_gtext=tiles,
            tile_valid=valid,
            idx_hashes=jnp.asarray(z["idx_hashes"]),
            idx_positions=jnp.asarray(z["idx_positions"]),
            tile_bloom=bloom,
            tile_slack=slack,
        )
        return GraphIndex(arrays=arrays, ref=z["ref"].astype(np.int8),
                          tile_len=tile_len, tile_stride=tile_stride,
                          minimizer_w=w, minimizer_k=k, window=window,
                          margin=margin)
