"""GQA attention: blockwise (flash-style) training/prefill + cached decode.

Blockwise attention scans KV blocks with an online-softmax accumulator so
the [S, S] score matrix is never materialized — required for 32k prefill
to compile within HBM, and the natural TPU formulation (MXU does the
[blk_q, d]×[d, blk_k] tiles; XLA fuses the rescale).  Supports causal
masking, sliding windows (mixtral), logit softcap, and non-causal
(encoder) mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, EMBED, HEADS, KV_HEADS, dense_init, apply_rope

NEG_INF = -1e30


def attn_init(cfg, key, d_model=None, cross=False):
    d = d_model or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd)),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd)),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd)),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), in_axis=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), jnp.float32)
    return p


ATTN_AXES = {
    "wq": (EMBED, HEADS, None),
    "wk": (EMBED, KV_HEADS, None),
    "wv": (EMBED, KV_HEADS, None),
    "wo": (HEADS, None, EMBED),
    "bq": (HEADS, None),
    "bk": (KV_HEADS, None),
    "bv": (KV_HEADS, None),
}


def _qkv(cfg, p, x, positions, rope=True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0,
                        sliding_window: int | None = None,
                        softcap: float | None = None,
                        blk_q: int = 512):
    """Chunked attention: q blocks × full KV, rematerialized per block.

    The [S, S] score matrix never exists — each q block computes its
    [blk_q, Sk] rows, softmaxes, and contracts with V; ``jax.checkpoint``
    around the block makes the backward recompute those rows instead of
    saving them (the flash-attention trade expressed at the XLA level —
    the VJP of a hand-rolled online-softmax scan would otherwise stash
    every KV-step carry, which is *worse* than S² memory).

    q: [B, Sq, H, Dh]; k/v: [B, Sk, Hkv, Dh].  Returns [B, Sq, H, Dh].
    """
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    # §Perf iteration #11 (two attempts, see EXPERIMENTS.md): padding sq up to
    # a blk_q multiple was REFUTED (pad+slice copies cost more than ragged
    # blocks: internvl2 prefill 53.3 → 68.7 GB).  Adopted: largest *divisor*
    # of sq ≤ blk_q, preferring multiples of 128 (MXU-aligned lanes) — for
    # the VLM's 33 024-long sequence this picks 384, not 258.
    blk_q = min(blk_q, sq)
    aligned = [d for d in range(blk_q, 127, -128) if sq % d == 0]
    if aligned:
        blk_q = aligned[0]
    else:
        while sq % blk_q:
            blk_q -= 1
    nq = sq // blk_q
    scale = 1.0 / np.sqrt(dh)
    qb = q.reshape(b, nq, blk_q, hkv, g, dh)
    k_pos = jnp.arange(sk)

    @partial(jax.checkpoint, prevent_cse=False)
    def q_block(qq, qp):
        # qq: [B, blk_q, hkv, g, dh]; qp: [blk_q] positions
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qq, k).astype(jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((blk_q, sk), bool)
        if causal:
            mask &= qp[:, None] >= k_pos[None, :]
        if sliding_window is not None:
            mask &= qp[:, None] - k_pos[None, :] < sliding_window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return out  # [B, blk_q, hkv, g, dh]

    q_pos = (q_offset + jnp.arange(sq)).reshape(nq, blk_q)
    if nq == 1:
        out = q_block(qb[:, 0], q_pos[0])[:, None]
    else:
        out = jax.lax.map(
            lambda args: q_block(*args), (qb.swapaxes(0, 1), q_pos)
        ).swapaxes(0, 1)  # [B, nq, blk_q, hkv, g, dh]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attention(cfg, p, x, positions, *, causal=True, decode_cache=None):
    """Full attention layer (projections + blockwise core)."""
    q, k, v = _qkv(cfg, p, x, positions)
    out = blockwise_attention(
        q, k, v, causal=causal, sliding_window=cfg.sliding_window,
        softcap=cfg.attn_logit_softcap,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_attention(cfg, p, x, memory, mem_positions):
    """Encoder-decoder cross attention (no rope on encoder memory)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    out = blockwise_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def decode_attention(cfg, p, x, cache_k, cache_v, cache_pos, cache_len):
    """Single-token decode against a KV cache.

    x: [B, 1, D]; cache_k/v: [B, S, Hkv, Dh]; cache_pos: [S] int32 the
    absolute position stored in each cache slot (-1 = empty; ring layout
    for sliding windows); cache_len: scalar current position.
    Returns (out [B, 1, D], new_k [B, 1, Hkv, Dh], new_v).
    """
    dt = x.dtype
    b, s, hkv, dh = cache_k.shape
    pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))[:, None]
    q, k, v = _qkv(cfg, p, x, pos)
    h = cfg.n_heads
    g = h // hkv
    scale = 1.0 / np.sqrt(dh)
    qh = q.reshape(b, hkv, g, dh)
    valid = (cache_pos[None, :] >= 0) & (cache_pos[None, :] < pos)
    if cfg.sliding_window is not None:
        valid &= (pos - cache_pos[None, :]) <= cfg.sliding_window
    sc = jnp.einsum("bhgd,bshd->bhgs", qh, cache_k).astype(jnp.float32) * scale
    s_self = jnp.einsum("bhgd,bhd->bhg", qh, k[:, 0]).astype(jnp.float32) * scale
    if cfg.attn_logit_softcap:
        sc = cfg.attn_logit_softcap * jnp.tanh(sc / cfg.attn_logit_softcap)
        s_self = cfg.attn_logit_softcap * jnp.tanh(s_self / cfg.attn_logit_softcap)
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    full = jnp.concatenate([sc, s_self[..., None]], axis=-1)
    w = jax.nn.softmax(full, axis=-1).astype(dt)
    out = jnp.einsum("bhgs,bshd->bhgd", w[..., :-1], cache_v) + \
        w[..., -1][..., None] * v[:, 0][:, :, None, :]
    out = out.reshape(b, 1, h, dh)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), p["wo"].astype(dt))
    return y, k, v
