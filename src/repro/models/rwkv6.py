"""RWKV-6 "Finch" time-mix with data-dependent decay (arXiv:2404.05892).

The WKV state is a per-head [dh, dh] matrix updated with a per-channel,
*data-dependent* decay — a linear scan with compact carried state, run
chunk-sequentially like the Mamba block.  Channel-mix is the RWKV gated
MLP.  Attention-free: decode carries (last-token shift, WKV state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, EMBED, HEADS, MLP, dense_init

LORA_R = 64


def rwkv_init(cfg, key):
    d = cfg.d_model
    h = cfg.n_heads if cfg.n_heads > 0 else d // 64
    dh = d // h
    ks = jax.random.split(key, 12)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),  # token-shift mixes (r,k,v,w,g)
        "wr": dense_init(ks[0], (d, d)),
        "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)),
        "wg": dense_init(ks[3], (d, d)),
        "wo": dense_init(ks[4], (d, d)),
        "w0": jnp.full((d,), -6.0, jnp.float32),  # decay bias
        "w_lora_a": dense_init(ks[5], (d, LORA_R)),
        "w_lora_b": dense_init(ks[6], (LORA_R, d)) * 0.1,
        "u": jnp.zeros((h, dh), jnp.float32),  # bonus (first-occurrence) term
        "ln_x": jnp.ones((d,), jnp.float32),
    }


RWKV_AXES = {
    "mu": (None, EMBED),
    "wr": (EMBED, MLP), "wk": (EMBED, MLP), "wv": (EMBED, MLP),
    "wg": (EMBED, MLP), "wo": (MLP, EMBED),
    "w0": (EMBED,), "w_lora_a": (EMBED, None), "w_lora_b": (None, EMBED),
    "u": (HEADS, None), "ln_x": (EMBED,),
}


def _time_shift(x, last=None):
    """x: [B, L, D] -> previous-token x (zeros or `last` at position 0)."""
    b, L, d = x.shape
    first = jnp.zeros((b, 1, d), x.dtype) if last is None else last[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, w, u, chunk: int):
    """WKV linear attention with per-step decay.

    r/k/v: [B, L, H, dh]; w: [B, L, H, dh] decay in (0, 1); u: [H, dh].
    state S: [B, H, dh(k), dh(v)];  y_t = r_t · (S + u∘k_t ⊗ v_t);
    S ← diag(w_t) S + k_t ⊗ v_t.   Chunk-sequential outer scan.
    """
    b, L, h, dh = r.shape
    nc = max(L // chunk, 1)
    c = L // nc

    def chunk_step(S, inp):
        rc, kc, vc, wc = inp  # [b, c, h, dh]
        # within-chunk: sequential scan (c small); exact semantics
        def t_step(S_, x):
            r_, k_, v_, w_ = x  # [b, h, dh]
            y = jnp.einsum("bhk,bhkv->bhv", r_, S_) + \
                jnp.einsum("bhk,bhk,bhv->bhv", r_, u[None], v_)
            S_new = S_ * w_[..., None] + k_[..., None] * v_[:, :, None, :]
            return S_new, y

        S, ys = jax.lax.scan(
            t_step, S,
            (rc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             wc.swapaxes(0, 1)),
        )
        return S, ys.swapaxes(0, 1)  # [b, c, h, dh]

    rc = r.reshape(b, nc, c, h, dh).swapaxes(0, 1)
    kc = k.reshape(b, nc, c, h, dh).swapaxes(0, 1)
    vc = v.reshape(b, nc, c, h, dh).swapaxes(0, 1)
    wc = w.reshape(b, nc, c, h, dh).swapaxes(0, 1)
    S0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    S, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    return ys.swapaxes(0, 1).reshape(b, L, h, dh), S


def rwkv_apply(cfg, p, x, *, state=None, return_state=False):
    """Time-mix block.  x: [B, L, D]."""
    dt_ = x.dtype
    b, L, d = x.shape
    h = cfg.n_heads
    dh = d // h
    last = None if state is None else state["shift"]
    xprev = _time_shift(x, last)
    mu = p["mu"].astype(dt_)
    mix = lambda i: x * mu[i] + xprev * (1 - mu[i])
    r = (mix(0) @ p["wr"].astype(dt_)).reshape(b, L, h, dh).astype(jnp.float32)
    k = (mix(1) @ p["wk"].astype(dt_)).reshape(b, L, h, dh).astype(jnp.float32)
    v = (mix(2) @ p["wv"].astype(dt_)).reshape(b, L, h, dh).astype(jnp.float32)
    # data-dependent decay (the Finch contribution)
    wx = mix(3).astype(jnp.float32)
    dd = jnp.tanh(wx @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(p["w0"] + dd)).reshape(b, L, h, dh)
    g = jax.nn.silu(mix(4) @ p["wg"].astype(dt_))

    if state is None:
        y, S = _wkv_chunked(r, k, v, w, p["u"], chunk=min(cfg.mamba.chunk if cfg.mamba
                                                          else 128, L))
    else:
        S0 = state["wkv"]
        y0 = jnp.einsum("blhk,bhkv->blhv", r, S0) + \
            jnp.einsum("blhk,hk,blhv->blhv", r, p["u"], v)
        S = S0 * w[:, 0][..., None] + k[:, 0][..., None] * v[:, 0][:, :, None, :]
        y = y0
    y = y.reshape(b, L, d).astype(dt_)
    # group norm over heads (ln_x)
    yh = y.reshape(b, L, h, dh).astype(jnp.float32)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, L, d) * p["ln_x"]).astype(dt_) * g
    out = y @ p["wo"].astype(dt_)
    if return_state:
        return out, {"shift": x[:, -1], "wkv": S}
    return out


def rwkv_channel_mix_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, jnp.float32),
        "wk": dense_init(ks[0], (d, f)),
        "wv": dense_init(ks[1], (f, d)),
        "wr": dense_init(ks[2], (d, d)),
    }


RWKV_CM_AXES = {"mu": (None, EMBED), "wk": (EMBED, MLP), "wv": (MLP, EMBED),
                "wr": (EMBED, MLP)}


def rwkv_channel_mix(cfg, p, x, *, state=None, return_state=False):
    dt_ = x.dtype
    last = None if state is None else state["shift"]
    xprev = _time_shift(x, last)
    mu = p["mu"].astype(dt_)
    xk = x * mu[0] + xprev * (1 - mu[0])
    xr = x * mu[1] + xprev * (1 - mu[1])
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt_)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dt_)) * (kk @ p["wv"].astype(dt_))
    if return_state:
        return out, {"shift": x[:, -1]}
    return out


def rwkv_decode_init(cfg, batch):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "tm": {"shift": jnp.zeros((batch, d), COMPUTE_DTYPE),
               "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, d), COMPUTE_DTYPE)},
    }
