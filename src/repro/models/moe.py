"""Mixture-of-Experts MLP with capacity-based dispatch (EP over "model").

Top-k routing in fp32, capacity factor token dropping, auxiliary
load-balance loss (Switch-style).  Experts are sharded over the "model"
axis (expert parallelism); the [tokens]→[experts, capacity] gather and
its inverse lower to all_to_all under GSPMD when the token batch is
data-sharded and the expert axis is model-sharded — the standard EP
collective pattern (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, EMBED, EXPERT, MLP, dense_init


def moe_init(cfg, key):
    m = cfg.moe
    e, d, f = m.n_experts, cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1),
        "wg": dense_init(ks[2], (e, d, f), in_axis=1),
        "wo": dense_init(ks[3], (e, f, d), in_axis=1),
    }
    if cfg.act != "silu_glu":
        del p["wg"]
    return p


MOE_AXES = {
    "router": (EMBED, None),
    "wi": (EXPERT, EMBED, MLP),
    "wg": (EXPERT, EMBED, MLP),
    "wo": (EXPERT, MLP, EMBED),
}


MOE_CHUNK_TOKENS = 16_384  # dispatch-group size (perf iteration #2, §Perf)


def _constrain(x, mesh, want):
    if mesh is None:
        return x
    from repro.dist.sharding import _fit
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _fit(mesh, x.shape, want)))


def _moe_chunk(cfg, p, xt, mesh):
    """Route + dispatch + expert-compute + combine for one token chunk.

    xt: [T, D].  Returns ([T, D], aux scalar).  Dispatch buffers are
    [E, cap, D] with E sharded over "model" (expert parallelism) — under
    GSPMD the token gather/scatter becomes the EP all_to_all.
    """
    m = cfg.moe
    t, d = xt.shape
    e, k = m.n_experts, m.top_k
    cap = max(int(np.ceil(t / e * m.capacity_factor * k)), k)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)  # [T, k]
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(tope, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = m.router_aux_coef * e * jnp.sum(me * ce)

    flat_e = tope.reshape(-1)  # [T*k] expert ids, token-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [T*k, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos_in_e * onehot, axis=-1)
    keep = pos < cap

    tok_id = jnp.repeat(jnp.arange(t), k)
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # drop -> OOB
    disp = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].add(xt[tok_id])
    disp = disp[:-1].reshape(e, cap, d)
    disp = _constrain(disp, mesh, ("model", None, None))

    dt = xt.dtype
    if cfg.act == "silu_glu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, p["wg"].astype(dt)))
        h = h * jnp.einsum("ecd,edf->ecf", disp, p["wi"].astype(dt))
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", disp, p["wi"].astype(dt))))
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))  # [E, cap, D]
    eo = _constrain(eo, mesh, ("model", None, None))

    eo_flat = jnp.concatenate([eo.reshape(e * cap, d), jnp.zeros((1, d), dt)])
    gathered = eo_flat[slot]  # [T*k, D] (dropped -> zeros row)
    w = (topw.reshape(-1) * keep).astype(dt)
    out = jnp.zeros((t, d), dt).at[tok_id].add(gathered * w[:, None])
    return out, aux


def moe_apply(cfg, p, x, mesh=None):
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    Tokens are dispatched in fixed-size chunks through a rematerialized
    ``lax.scan`` — the live dispatch set is [E, cap_chunk, D] instead of
    the full batch's (perf iteration #2: 604 GB → bounded; §Perf).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    n_chunks = max(t // MOE_CHUNK_TOKENS, 1)
    if t % n_chunks:
        n_chunks = 1  # irregular sizes: single chunk (smoke tests)
    if n_chunks == 1:
        out, aux = _moe_chunk(cfg, p, xt, mesh)
        return out.reshape(b, s, d), aux

    xc = xt.reshape(n_chunks, t // n_chunks, d)

    @partial(jax.checkpoint, prevent_cse=False)
    def body(acc, xchunk):
        out, aux = _moe_chunk(cfg, p, xchunk, mesh)
        return acc + aux, out

    aux, outs = jax.lax.scan(body, jnp.float32(0), xc)
    return outs.reshape(b, s, d), aux / n_chunks
