"""Config → model builder: init, loss, prefill, decode for every family.

Families: decoder-only (dense/MoE/hybrid/SSM), encoder-decoder (seamless),
VLM (prefix embeddings).  Used by the trainer, the server, the smoke
tests, and the multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, transformer
from .frontends import frontend_embed_shape
from .layers import COMPUTE_DTYPE, chunked_logits_xent


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.enc_layers > 0


def init(cfg: ModelConfig, key):
    if is_encdec(cfg):
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss_fn(cfg: ModelConfig, params, batch, *, remat: bool = True, mesh=None,
            sp: bool = False):
    """batch: dict(tokens, targets, mask [, frames | prefix_embeds])."""
    if is_encdec(cfg):
        hidden, aux = encdec.forward(cfg, params, batch["tokens"], batch["frames"],
                                     remat=remat)
    elif cfg.frontend == "vision_stub":
        hidden, aux = transformer.forward(
            cfg, params, batch["tokens"], prefix_embeds=batch["prefix_embeds"],
            remat=remat, mesh=mesh, sp=sp)
        hidden = hidden[:, batch["prefix_embeds"].shape[1]:]  # loss on text only
    else:
        hidden, aux = transformer.forward(cfg, params, batch["tokens"], remat=remat,
                                          mesh=mesh, sp=sp)
    emb = (params["embed"]["tokens"] if cfg.tie_embeddings
           else params["lm_head"]["w"].T)
    xent, acc = chunked_logits_xent(hidden, emb, batch["targets"], batch["mask"])
    return xent + aux, {"xent": xent, "aux": aux, "acc": acc}


def prefill_fn(cfg: ModelConfig, params, batch):
    """Prefill: hidden-states forward; returns last-position logits."""
    if is_encdec(cfg):
        memory = encdec.encode(cfg, params, batch["frames"], remat=True)
        hidden = encdec.decode(cfg, params, batch["tokens"], memory, remat=True)
    elif cfg.frontend == "vision_stub":
        hidden, _ = transformer.forward(cfg, params, batch["tokens"],
                                        prefix_embeds=batch["prefix_embeds"])
    else:
        hidden, _ = transformer.forward(cfg, params, batch["tokens"])
    return transformer.logits_head(cfg, params, hidden[:, -1:])[:, -1]


def decode_state_init(cfg: ModelConfig, batch: int, max_len: int):
    if is_encdec(cfg):
        return encdec.decode_state_init(cfg, batch, max_len)
    return transformer.decode_state_init(cfg, batch, max_len)


def decode_fn(cfg: ModelConfig, params, state, batch, pos):
    """One token for the whole batch against the decode state."""
    if is_encdec(cfg):
        return encdec.decode_step(cfg, params, state, batch["tokens"], pos,
                                  batch["memory"])
    return transformer.decode_step(cfg, params, state, batch["tokens"], pos)


# --------------------------------------------------------------- batches ---

def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, for_dryrun: bool = True
                ) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    For ``decode`` shapes the KV/SSM state is part of the input specs (the
    serve_step signature), per the assignment: decode lowers ``serve_step``
    with a cache of ``seq_len``, not ``train_step``.
    """
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    fd = cfg.frontend_dim or cfg.d_model

    if shape.kind == "train":
        specs = {
            "tokens": sd((B, S), jnp.int32),
            "targets": sd((B, S), jnp.int32),
            "mask": sd((B, S), jnp.float32),
        }
        if is_encdec(cfg):
            specs["frames"] = sd((B, S, fd), jnp.float32)
        elif cfg.frontend == "vision_stub":
            specs["prefix_embeds"] = sd((B, cfg.frontend_len or 256, fd), jnp.float32)
        return {"batch": specs}

    if shape.kind == "prefill":
        specs = {"tokens": sd((B, S), jnp.int32)}
        if is_encdec(cfg):
            specs["frames"] = sd((B, S, fd), jnp.float32)
        elif cfg.frontend == "vision_stub":
            specs["prefix_embeds"] = sd((B, cfg.frontend_len or 256, fd), jnp.float32)
        return {"batch": specs}

    # decode: one new token against a seq_len cache
    state = jax.eval_shape(lambda: decode_state_init(cfg, B, S))
    specs = {"tokens": sd((B, 1), jnp.int32)}
    if is_encdec(cfg):
        mem_len = cfg.frontend_len or 4096
        specs["memory"] = sd((B, mem_len, cfg.d_model), COMPUTE_DTYPE)
    return {"state": state, "batch": specs}


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def conc(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, cfg.vocab, size=s.shape), s.dtype)
        return jnp.asarray(rng.normal(0, 0.02, size=s.shape), s.dtype)

    out = jax.tree.map(conc, specs)
    if "mask" in out.get("batch", {}):
        out["batch"]["mask"] = jnp.ones_like(out["batch"]["mask"])
    return out
