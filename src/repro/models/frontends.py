"""Modality frontend STUBS (per assignment spec).

``[audio]``/``[vlm]`` architectures specify the transformer backbone only;
the frontend is a stub whose output embeddings arrive precomputed via
``input_specs()``.  These helpers size those embeddings and synthesize
random ones for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def frontend_embed_shape(cfg, batch: int, length: int | None = None):
    fd = cfg.frontend_dim or cfg.d_model
    return (batch, length if length is not None else cfg.frontend_len, fd)


def synth_frontend_embeds(cfg, batch: int, length: int | None = None, seed: int = 0):
    shape = frontend_embed_shape(cfg, batch, length)
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * 0.02
