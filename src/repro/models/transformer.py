"""Decoder-only LM assembled from a ModelConfig.

Layers are grouped into the config's repeating *pattern* (e.g. Jamba's
[mamba×4, attn, mamba×3]); parameters are stacked over pattern groups and
executed with ``lax.scan`` + remat — compact HLO for 96-layer models and
layer-boundary activation checkpointing for the memory plan (DESIGN.md §5).

Three entry points: ``forward`` (train/prefill hidden states), ``prefill``
(hidden states + per-layer decode state), ``decode_step`` (one token).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import moe as moe_mod
from . import rwkv6 as rk
from .layers import (COMPUTE_DTYPE, EMBED, VOCAB, apply_norm, dense_init,
                     embed_init, make_norm, mlp_apply, mlp_init)


def _slot_init(cfg, key, slot: int, kind: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": make_norm(cfg, k1, cfg.d_model)}
    if kind == "attn":
        p["attn"] = attn.attn_init(cfg, k2)
    elif kind == "mamba":
        p["mamba"] = mb.mamba_init(cfg, k2)
    elif kind == "rwkv":
        p["rwkv"] = rk.rwkv_init(cfg, k2)
    p["norm2"] = make_norm(cfg, k3, cfg.d_model)
    if kind == "rwkv":
        p["cmix"] = rk.rwkv_channel_mix_init(cfg, k4)
    elif slot in cfg.moe_slots:
        p["moe"] = moe_mod.moe_init(cfg, k4)
    else:
        p["mlp"] = mlp_init(cfg, k4)
    return p


def init_block(cfg, key):
    keys = jax.random.split(key, len(cfg.pattern))
    return {
        f"slot{i}": _slot_init(cfg, keys[i], i, kind)
        for i, kind in enumerate(cfg.pattern)
    }


def init_params(cfg, key):
    kb, ke, kn, kh = jax.random.split(key, 4)
    bkeys = jax.random.split(kb, cfg.n_blocks)
    blocks = jax.vmap(lambda k: init_block(cfg, k))(bkeys)
    params = {
        "blocks": blocks,
        "embed": embed_init(cfg, ke),
        "final_norm": make_norm(cfg, kn, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(kh, (cfg.d_model, cfg.padded_vocab))}
    if cfg.frontend != "none":
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = {"w": dense_init(kh, (fd, cfg.d_model))}
    return params


def _slot_apply(cfg, p, x, positions, slot: int, kind: str, aux_acc, mesh=None):
    h = apply_norm(cfg, p["norm1"], x)
    if kind == "attn":
        a = attn.attention(cfg, p["attn"], h, positions)
    elif kind == "mamba":
        a = mb.mamba_apply(cfg, p["mamba"], h)
    else:
        a = rk.rwkv_apply(cfg, p["rwkv"], h)
    if cfg.parallel_block:
        # command-r style: MLP on the same normed input, single residual add
        if kind == "rwkv":
            m = rk.rwkv_channel_mix(cfg, p["cmix"], h)
        elif "moe" in p:
            m, aux = moe_mod.moe_apply(cfg, p["moe"], h, mesh)
            aux_acc = aux_acc + aux
        else:
            m = mlp_apply(cfg, p["mlp"], h)
        return x + a + m, aux_acc
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    if kind == "rwkv":
        m = rk.rwkv_channel_mix(cfg, p["cmix"], h2)
    elif "moe" in p:
        m, aux = moe_mod.moe_apply(cfg, p["moe"], h2, mesh)
        aux_acc = aux_acc + aux
    else:
        m = mlp_apply(cfg, p["mlp"], h2)
    return x + m, aux_acc


NESTED_SLOT_REMAT = False  # §Perf iteration #4: hypothesis REFUTED — nested
# per-slot checkpoints inside the block scan *increased* jamba train_4k temp
# memory 63→72.6 GB/device (the slot-boundary saves stack up against the
# block-level recompute buffers); kept as an opt-in knob for reference.


def block_apply(cfg, bp, x, positions, mesh=None):
    aux = jnp.float32(0)
    nested = NESTED_SLOT_REMAT and len(cfg.pattern) > 1
    for i, kind in enumerate(cfg.pattern):
        fn = partial(_slot_apply, cfg, bp[f"slot{i}"], slot=i, kind=kind,
                     mesh=mesh)
        apply = lambda xx, aa: fn(xx, positions, aux_acc=aa)
        if nested:
            apply = jax.checkpoint(apply, prevent_cse=False)
        x, aux = apply(x, aux)
    return x, aux


@partial(jax.jit, static_argnames=("cfg", "remat", "mesh", "sp"))
def forward(cfg, params, tokens, *, prefix_embeds=None, remat: bool = True,
            mesh=None, sp: bool = False):
    """tokens: [B, S] int32 -> hidden [B, S(+P), D], aux loss.

    ``mesh``/``sp``: when set, the residual stream at every layer boundary
    is sharding-constrained (batch over dp; with ``sp`` the *sequence* over
    "model" — sequence parallelism, which is what bounds the remat storage
    of 96-layer models to ~1 GB/device; DESIGN.md §5).
    """
    from repro.dist.sharding import constrain_activations

    x = params["embed"]["tokens"].astype(COMPUTE_DTYPE)[tokens]
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(COMPUTE_DTYPE) @ params["frontend_proj"]["w"].astype(
            COMPUTE_DTYPE)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, bp):
        carry = constrain_activations(carry, mesh, seq_axis=sp)
        y, aux = block_apply(cfg, bp, carry, positions, mesh)
        return y, aux

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["blocks"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, jnp.sum(auxs)


def logits_head(cfg, params, x):
    w = (params["embed"]["tokens"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------- decode ---

KV_INT8 = False  # §Perf iteration #12: int8 KV cache (per-position/head
# symmetric scales) — halves the decode memory term, the dominant roofline
# term of every decode_32k/long_500k cell.  Measured in EXPERIMENTS.md §Perf.


def decode_state_init(cfg, batch: int, max_len: int):
    """Per-block per-slot decode state, stacked over blocks."""
    def one_slot(kind):
        if kind == "attn":
            s = max_len if cfg.sliding_window is None else min(
                max_len, cfg.sliding_window)
            if KV_INT8:
                return {
                    "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), jnp.int8),
                    "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, s, cfg.n_kv_heads), jnp.bfloat16),
                    "v_scale": jnp.zeros((batch, s, cfg.n_kv_heads), jnp.bfloat16),
                    "pos": jnp.full((s,), -1, jnp.int32),
                }
            return {
                "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
                "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
                "pos": jnp.full((s,), -1, jnp.int32),
            }
        if kind == "mamba":
            return mb.mamba_decode_init(cfg, batch)
        return rk.rwkv_decode_init(cfg, batch)

    block = {f"slot{i}": one_slot(kind) for i, kind in enumerate(cfg.pattern)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_blocks,) + a.shape).copy(), block
    )


def _slot_decode(cfg, p, st, x, pos, kind):
    h = apply_norm(cfg, p["norm1"], x)
    if kind == "attn":
        s_max = st["k"].shape[1]
        write = jnp.minimum(pos, s_max - 1)
        if cfg.sliding_window is not None:
            write = pos % s_max  # ring layout; cache "pos" keeps absolutes
        if "k_scale" in st:  # int8 KV cache (§Perf #12)
            ck = st["k"].astype(COMPUTE_DTYPE) * st["k_scale"][..., None]
            cv = st["v"].astype(COMPUTE_DTYPE) * st["v_scale"][..., None]
        else:
            ck, cv = st["k"], st["v"]
        a, k_new, v_new = attn.decode_attention(cfg, p["attn"], h, ck, cv,
                                                st["pos"], pos)
        if "k_scale" in st:
            def quant(x):  # [B, 1, Hkv, dh] -> int8 + per-head scale
                s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
                s = jnp.maximum(s, 1e-8)
                q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                             -127, 127).astype(jnp.int8)
                return q, s.astype(jnp.bfloat16)
            kq, ks = quant(k_new)
            vq, vs = quant(v_new)
            st = {
                "k": jax.lax.dynamic_update_slice(st["k"], kq, (0, write, 0, 0)),
                "v": jax.lax.dynamic_update_slice(st["v"], vq, (0, write, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    st["k_scale"], ks, (0, write, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    st["v_scale"], vs, (0, write, 0)),
                "pos": jax.lax.dynamic_update_slice(
                    st["pos"], jnp.asarray(pos, jnp.int32)[None], (write,)),
            }
        else:
            st = {
                "k": jax.lax.dynamic_update_slice(st["k"], k_new, (0, write, 0, 0)),
                "v": jax.lax.dynamic_update_slice(st["v"], v_new, (0, write, 0, 0)),
                "pos": jax.lax.dynamic_update_slice(
                    st["pos"], jnp.asarray(pos, jnp.int32)[None], (write,)),
            }
    elif kind == "mamba":
        a, st = mb.mamba_decode(cfg, p["mamba"], h, st)
    else:
        a, tm = rk.rwkv_apply(cfg, p["rwkv"], h, state=st["tm"], return_state=True)
        st = {"tm": tm, "cm": st["cm"]}
    x = x + a
    h2 = apply_norm(cfg, p["norm2"], x)
    if kind == "rwkv":
        m, cm = rk.rwkv_channel_mix(cfg, p["cmix"], h2, state=st["cm"],
                                    return_state=True)
        st = {"tm": st["tm"], "cm": cm}
    elif "moe" in p:
        m, _ = moe_mod.moe_apply(cfg, p["moe"], h2)
    else:
        m = mlp_apply(cfg, p["mlp"], h2)
    return x + m, st


@partial(jax.jit, static_argnames=("cfg",))
def decode_step(cfg, params, state, tokens, pos):
    """One decode step.  tokens: [B, 1] int32; pos: scalar cache length.

    Returns (logits [B, vocab] fp32, new_state).
    """
    x = params["embed"]["tokens"].astype(COMPUTE_DTYPE)[tokens]

    def body(carry, scanned):
        bp, st = scanned
        y = carry
        new_st = {}
        for i, kind in enumerate(cfg.pattern):
            y, new_st[f"slot{i}"] = _slot_decode(
                cfg, bp[f"slot{i}"], st[f"slot{i}"], y, pos, kind)
        return y, new_st

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state))
    x = apply_norm(cfg, params["final_norm"], x)
    return logits_head(cfg, params, x)[:, -1], new_state
