"""Encoder-decoder backbone (seamless-m4t).  Audio frontend is a STUB:
``input_specs`` feeds precomputed frame embeddings straight to the encoder
(per the assignment spec)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import (COMPUTE_DTYPE, apply_norm, dense_init, embed_init,
                     make_norm, mlp_apply, mlp_init)


def _enc_layer_init(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "norm1": make_norm(cfg, k1, cfg.d_model),
        "attn": attn.attn_init(cfg, k2),
        "norm2": make_norm(cfg, k3, cfg.d_model),
        "mlp": mlp_init(cfg, k4),
    }


def _dec_layer_init(cfg, key):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "norm1": make_norm(cfg, k1, cfg.d_model),
        "attn": attn.attn_init(cfg, k2),
        "norm_x": make_norm(cfg, k3, cfg.d_model),
        "xattn": attn.attn_init(cfg, k4),
        "norm2": make_norm(cfg, k5, cfg.d_model),
        "mlp": mlp_init(cfg, k6),
    }


def init_params(cfg, key):
    ke, kd, kt, kn, kf = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    fd = cfg.frontend_dim or cfg.d_model
    return {
        "enc_blocks": jax.vmap(lambda k: _enc_layer_init(cfg, k))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_layer_init(cfg, k))(dec_keys),
        "embed": embed_init(cfg, kt),
        "enc_norm": make_norm(cfg, kn, cfg.d_model),
        "final_norm": make_norm(cfg, kn, cfg.d_model),
        "frontend_proj": {"w": dense_init(kf, (fd, cfg.d_model))},
    }


def encode(cfg, params, frames, *, remat=True):
    """frames: [B, S_enc, frontend_dim] stub embeddings -> memory [B, S_enc, D]."""
    x = frames.astype(COMPUTE_DTYPE) @ params["frontend_proj"]["w"].astype(COMPUTE_DTYPE)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        h = apply_norm(cfg, lp["norm1"], carry)
        a = attn.attention(cfg, lp["attn"], h, pos, causal=False)
        y = carry + a
        h2 = apply_norm(cfg, lp["norm2"], y)
        return y + mlp_apply(cfg, lp["mlp"], h2), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_norm"], x)


def decode(cfg, params, tokens, memory, *, remat=True):
    """tokens: [B, S_dec]; memory: [B, S_enc, D] -> hidden [B, S_dec, D]."""
    x = params["embed"]["tokens"].astype(COMPUTE_DTYPE)[tokens]
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32), memory.shape[:2])

    def body(carry, lp):
        h = apply_norm(cfg, lp["norm1"], carry)
        y = carry + attn.attention(cfg, lp["attn"], h, pos, causal=True)
        hx = apply_norm(cfg, lp["norm_x"], y)
        y = y + attn.cross_attention(cfg, lp["xattn"], hx, memory, mem_pos)
        h2 = apply_norm(cfg, lp["norm2"], y)
        return y + mlp_apply(cfg, lp["mlp"], h2), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return apply_norm(cfg, params["final_norm"], x)


def forward(cfg, params, tokens, frames, *, remat=True):
    memory = encode(cfg, params, frames, remat=remat)
    return decode(cfg, params, tokens, memory, remat=remat), jnp.float32(0)


def decode_state_init(cfg, batch: int, max_len: int):
    mk = lambda: {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), COMPUTE_DTYPE),
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), mk()
    )


def decode_step(cfg, params, state, tokens, pos, memory):
    """One decoder token; cross-attends the (precomputed) encoder memory."""
    from .transformer import logits_head

    x = params["embed"]["tokens"].astype(COMPUTE_DTYPE)[tokens]
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32), memory.shape[:2])

    def body(carry, scanned):
        lp, st = scanned
        h = apply_norm(cfg, lp["norm1"], carry)
        a, k_new, v_new = attn.decode_attention(
            cfg, lp["attn"], h, st["k"], st["v"], st["pos"], pos)
        y = carry + a
        new_st = {
            "k": jax.lax.dynamic_update_slice(st["k"], k_new, (0, pos, 0, 0)),
            "v": jax.lax.dynamic_update_slice(st["v"], v_new, (0, pos, 0, 0)),
            "pos": jax.lax.dynamic_update_slice(
                st["pos"], jnp.asarray(pos, jnp.int32)[None], (pos,)),
        }
        hx = apply_norm(cfg, lp["norm_x"], y)
        y = y + attn.cross_attention(cfg, lp["xattn"], hx, memory, mem_pos)
        h2 = apply_norm(cfg, lp["norm2"], y)
        return y + mlp_apply(cfg, lp["mlp"], h2), new_st

    x, new_state = jax.lax.scan(body, x, (params["dec_blocks"], state))
    x = apply_norm(cfg, params["final_norm"], x)
    return logits_head(cfg, params, x)[:, -1], new_state
