"""Shared model layers: norms, RoPE, MLPs, embeddings.

Params are plain pytrees (dicts of arrays); every param has a parallel
*logical axis* annotation used by ``repro.dist.sharding`` to resolve
PartitionSpecs.  Compute dtype is bf16, params fp32 (cast at use).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16

# logical axis names (resolved to mesh axes in dist/sharding.py)
EMBED, MLP, HEADS, KV_HEADS, QKV, VOCAB, EXPERT, CONV, STATE, NONE = (
    "embed", "mlp", "heads", "kv_heads", "qkv", "vocab", "expert", "conv",
    "state", None,
)


def dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    scale = 1.0 / np.sqrt(fan_in)
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def make_norm(cfg, key, d):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, Dh]; positions: [..., S] int32.  Half-rotation RoPE."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)  # [dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_init(cfg, key, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu_glu":
        return {
            "wi": dense_init(ks[0], (d, f)),
            "wg": dense_init(ks[1], (d, f)),
            "wo": dense_init(ks[2], (f, d)),
        }
    return {"wi": dense_init(ks[0], (d, f)), "wo": dense_init(ks[2], (f, d))}


MLP_AXES = {
    "wi": (EMBED, MLP),
    "wg": (EMBED, MLP),
    "wo": (MLP, EMBED),
}


def mlp_apply(cfg, p, x):
    dt = x.dtype
    if cfg.act == "silu_glu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"].astype(dt)))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    return h @ p["wo"].astype(dt)


def embed_init(cfg, key):
    return {"tokens": jax.random.normal(key, (cfg.padded_vocab, cfg.d_model)) * 0.02}


def chunked_logits_xent(x, emb, targets, mask, *, chunk: int = 512):
    """Cross-entropy against tied/untied vocab projection, seq-chunked.

    Avoids materializing [B, S, V] logits: scans over sequence chunks,
    computing logsumexp and the target logit per chunk in fp32.
    ``x``: [B, S, D]; ``emb``: [V, D]; ``targets``/``mask``: [B, S].
    """
    b, s, d = x.shape
    n_chunks = max(s // chunk, 1)
    c = s // n_chunks
    xs = x.reshape(b, n_chunks, c, d).swapaxes(0, 1)
    ts = targets.reshape(b, n_chunks, c).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, c).swapaxes(0, 1)
    et = emb.astype(COMPUTE_DTYPE).T

    def body(carry, inp):
        xc, tc, mc = inp
        logits = (xc @ et).astype(jnp.float32)  # [B, c, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - tgt) * mc)
        acc = jnp.sum((jnp.argmax(logits, -1) == tc) * mc)
        return (carry[0] + loss, carry[1] + acc), None

    (loss, acc), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xs, ts, ms))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return loss / denom, acc / denom
