"""Mamba (S6 selective scan) block for the Jamba hybrid architecture.

Chunked scan: within a chunk the diagonal SSM recurrence runs as an
associative scan (parallel, MXU-friendly cumulative products), and chunk
boundary states are carried by an outer ``lax.scan`` — the same
sequential-with-carry pattern as the GenASM-DC kernel grid.  Decode keeps
(conv window, h state) per layer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, EMBED, MLP, STATE, dense_init


def mamba_init(cfg, key):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    ks = jax.random.split(key, 7)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": jax.random.normal(ks[1], (mc.d_conv, di)) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * mc.d_state)),
        "dt_proj": dense_init(ks[3], (dt_rank, di)),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, mc.d_state)
        ).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


MAMBA_AXES = {
    "in_proj": (EMBED, MLP),
    "conv_w": (None, MLP),
    "conv_b": (MLP,),
    "x_proj": (MLP, None),
    "dt_proj": (None, MLP),
    "dt_bias": (MLP,),
    "A_log": (MLP, None),
    "D": (MLP,),
    "out_proj": (MLP, EMBED),
}


def _ssm_chunked(u, dt, B, C, A, chunk: int):
    """Diagonal SSM over time, chunked associative scan.

    u/dt: [b, L, di]; B/C: [b, L, n]; A: [di, n].  Returns y [b, L, di].
    """
    b, L, di = u.shape
    n = B.shape[-1]
    nc = max(L // chunk, 1)
    c = L // nc

    # NOTE (perf iteration #1, EXPERIMENTS.md §Perf): dA/dBu are [b, c, di, n]
    # per *chunk*, computed inside the scan body — materializing them for the
    # full L up front is b·L·di·n·4 B (568 GB/device for jamba train_4k).
    u_c = u.reshape(b, nc, c, di).swapaxes(0, 1)
    dt_c = dt.reshape(b, nc, c, di).swapaxes(0, 1)
    B_c = B.reshape(b, nc, c, n).swapaxes(0, 1)
    C_c = C.reshape(b, nc, c, n).swapaxes(0, 1)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(h, inp):
        uc, dtc, bc, cc = inp  # [b, c, di] ×2, [b, c, n] ×2 (bf16 storage)
        uc, dtc = uc.astype(jnp.float32), dtc.astype(jnp.float32)
        bc, cc = bc.astype(jnp.float32), cc.astype(jnp.float32)
        da = jnp.exp(dtc[..., None] * A)  # [b, c, di, n]
        dbu = (dtc * uc)[..., None] * bc[:, :, None, :]
        a_acc, b_acc = jax.lax.associative_scan(assoc, (da, dbu), axis=1)
        h_t = a_acc * h[:, None] + b_acc  # [b, c, di, n]
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cc)
        return h_t[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (u_c, dt_c, B_c, C_c))
    return ys.swapaxes(0, 1).reshape(b, L, di)


def mamba_apply(cfg, p, x):
    """x: [B, L, D] -> [B, L, D]."""
    mc = cfg.mamba
    dt_ = x.dtype
    b, L, d = x.shape
    di = mc.expand * d
    xz = x @ p["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)  # [B, L, di]

    # depthwise causal conv1d
    w = p["conv_w"].astype(dt_)
    pad = jnp.zeros((b, mc.d_conv - 1, di), dt_)
    xp = jnp.concatenate([pad, xs], axis=1)
    conv = sum(
        xp[:, i: i + L] * w[i] for i in range(mc.d_conv)
    ) + p["conv_b"].astype(dt_)
    xs = jax.nn.silu(conv)

    proj = xs @ p["x_proj"].astype(dt_)
    dt_rank = p["dt_proj"].shape[0]
    dt_x, Bx, Cx = jnp.split(proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    delta = jax.nn.softplus(
        dt_x @ p["dt_proj"].astype(dt_) + p["dt_bias"].astype(dt_)
    ).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])  # [di, n]
    # store scan inputs in bf16 (perf iteration #4: halves the full-L SSM
    # input residency); the chunk body upcasts to f32 for the recurrence.
    y = _ssm_chunked(xs.astype(jnp.bfloat16), delta.astype(jnp.bfloat16),
                     Bx.astype(jnp.bfloat16), Cx.astype(jnp.bfloat16), A,
                     mc.chunk)
    y = (y + xs.astype(jnp.float32) * p["D"]).astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_)


def mamba_decode_init(cfg, batch):
    mc = cfg.mamba
    di = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), COMPUTE_DTYPE),
        "h": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }


def mamba_decode(cfg, p, x, state):
    """Single-token decode.  x: [B, 1, D]."""
    mc = cfg.mamba
    dt_ = x.dtype
    b = x.shape[0]
    di = mc.expand * cfg.d_model
    xz = x[:, 0] @ p["in_proj"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xs[:, None]], axis=1)  # [B, d_conv, di]
    w = p["conv_w"].astype(dt_)
    conv = jnp.einsum("bkd,kd->bd", window, w) + p["conv_b"].astype(dt_)
    xs = jax.nn.silu(conv)
    proj = xs @ p["x_proj"].astype(dt_)
    dt_rank = p["dt_proj"].shape[0]
    dt_x, Bx, Cx = jnp.split(proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    delta = jax.nn.softplus(
        dt_x @ p["dt_proj"].astype(dt_) + p["dt_bias"].astype(dt_)
    ).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(delta[..., None] * A)  # [B, di, n]
    h = dA * state["h"] + (delta * xs.astype(jnp.float32))[..., None] * \
        Bx.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cx.astype(jnp.float32))
    y = (y + xs.astype(jnp.float32) * p["D"]).astype(dt_) * jax.nn.silu(z)
    out = (y @ p["out_proj"].astype(dt_))[:, None]
    return out, {"conv": window[:, 1:], "h": h}
