"""Unified alignment backend dispatch (DESIGN.md §9).

    from repro import align
    res = align.align_batch(texts, patterns, p_lens, t_lens,
                            cfg=GenASMConfig(), backend="pallas_dc")

Importing the package registers the built-in backends (``ref``, ``lax``,
``pallas_dc``, ``pallas_dc_v2``).
"""
from .api import (  # noqa: F401
    Backend,
    align_batch,
    autotune,
    available_backends,
    block_size_for,
    clear_autotune_cache,
    get_backend,
    needs_interpret,
    register_backend,
    resolve_backend,
)
from . import backends as _builtin_backends  # noqa: F401  (registers them)
