"""Backend registry + unified ``align_batch`` dispatch (DESIGN.md §9).

Every consumer of windowed GenASM alignment — `core/mapper.py`, the
serve engine, `genomics/pipeline.py`, `launch/serve_genomics.py`, the
benchmarks — calls :func:`align_batch` and names a backend (or lets
:func:`resolve_backend` pick one).  Adding a kernel to the system is a
registry entry plus a conformance-suite run (`tests/test_align_conformance.py`),
not another hand-wired call path.

Backends registered by `repro.align.backends`:

  ``ref``           host numpy DP oracle (exact, jit-safe via pure_callback)
  ``lax``           pure-`jax.lax` windowed aligner (`core/genasm.align`)
  ``pallas_dc``     Pallas GenASM-DC kernel, M/I/D TB store (paper-faithful)
  ``pallas_dc_v2``  Pallas kernel with R-only TB store (3× less TB traffic)

Platform handling: the Pallas kernels lower natively on TPU/GPU; on CPU
they would die with an opaque Mosaic lowering error, so dispatch passes
``interpret=True`` there — the kernel body runs as traced JAX ops with
identical semantics.  ``backend=None``/``"auto"`` resolves to the
``REPRO_ALIGN_BACKEND`` env var when set, else Pallas on an accelerator
and ``lax`` on CPU.

Block-size autotune: the kernels' batch tile ``block_bt`` trades launch
count against padding waste.  ``align_batch`` consults a per-process
cache keyed ``(backend, bucket_cap, k)``; misses fall back to a
heuristic, measure candidates on synthetic input when
``REPRO_ALIGN_AUTOTUNE=1`` (or via an explicit :func:`autotune` call),
or — ``REPRO_ALIGN_AUTOTUNE=model`` — are seeded from the analytic
roofline cost model (`repro.obs.roofline.predict_block_bt`) with zero
on-device search, via :func:`model_seed`.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import AlignResult, GenASMConfig

DEFAULT_BT = 128
_PALLAS_NATIVE = ("tpu", "gpu")


@dataclass(frozen=True)
class Backend:
    """One registered alignment implementation."""

    name: str
    fn: Callable  # (texts, patterns, p_lens, t_lens, *, cfg, p_cap,
    #               emit_cigar, block_bt, interpret) -> AlignResult
    uses_pallas: bool = False
    description: str = ""


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, fn: Callable, *, uses_pallas: bool = False,
                     description: str = "") -> Backend:
    """Register (or replace) a backend under ``name``."""
    b = Backend(name=name, fn=fn, uses_pallas=uses_pallas,
                description=description)
    _REGISTRY[name] = b
    return b


def available_backends() -> tuple[str, ...]:
    """Names of every registered alignment backend, registration order."""
    return tuple(_REGISTRY)


def get_backend(name: str) -> Backend:
    """Registered :class:`Backend` for ``name`` (ValueError if unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown align backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def needs_interpret(platform: str | None = None) -> bool:
    """True when Pallas must run in interpret mode (no native lowering)."""
    p = platform or jax.default_backend()
    return p not in _PALLAS_NATIVE


def resolve_backend(backend: str | None = None) -> Backend:
    """Map a requested name (or None/"auto") to a registered backend.

    Order: explicit name > ``REPRO_ALIGN_BACKEND`` env var > platform
    default (``pallas_dc`` on TPU/GPU, ``lax`` on CPU).
    """
    if backend in (None, "auto"):
        backend = os.environ.get("REPRO_ALIGN_BACKEND") or (
            "lax" if needs_interpret() else "pallas_dc")
    return get_backend(backend)


# ----------------------------------------------------------- autotune ----
_BLOCK_CACHE: dict[tuple[str, int, int], int] = {}


def _heuristic_block(batch: int) -> int:
    return min(DEFAULT_BT, max(8, batch))


def block_size_for(backend: str, bucket_cap: int, k: int, batch: int) -> int:
    """Cached/heuristic batch-tile size for a dispatch site."""
    got = _BLOCK_CACHE.get((backend, bucket_cap, k))
    if got is not None:
        return got
    return _heuristic_block(batch)


def autotune(backend: str, bucket_cap: int, k: int, *,
             batch: int = 64, candidates: tuple[int, ...] = (16, 64, 128),
             cfg: GenASMConfig | None = None, iters: int = 2) -> int:
    """Measure candidate ``block_bt`` values and cache the fastest.

    Synthetic input (fixed seed) at the site's ``(bucket_cap, k)``; the
    winner lands in the process-wide cache consulted by
    :func:`block_size_for`.  Returns the chosen block size.
    """
    be = get_backend(backend)
    if not be.uses_pallas:  # nothing to tune; pin the heuristic
        _BLOCK_CACHE[(backend, bucket_cap, k)] = _heuristic_block(batch)
        return _BLOCK_CACHE[(backend, bucket_cap, k)]
    cfg = cfg or GenASMConfig(k=k, o=min(k, 24) or 8)
    rng = np.random.default_rng(0xB10C)
    texts = jnp.asarray(
        rng.integers(0, 4, size=(batch, bucket_cap + 2 * cfg.w)), jnp.int8)
    pats = jnp.asarray(rng.integers(0, 4, size=(batch, bucket_cap)), jnp.int8)
    p_lens = jnp.full((batch,), bucket_cap, jnp.int32)
    t_lens = jnp.full((batch,), bucket_cap + 2 * cfg.w, jnp.int32)
    best_bt, best_t = None, float("inf")
    for bt in candidates:
        if bt > batch:
            continue
        fn = lambda: be.fn(texts, pats, p_lens, t_lens, cfg=cfg,
                           p_cap=bucket_cap, emit_cigar=False, block_bt=bt,
                           interpret=needs_interpret())
        jax.block_until_ready(fn().distance)  # compile off-clock
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().distance)
            ts.append(time.perf_counter() - t0)
        t = min(ts)
        if t < best_t:
            best_bt, best_t = bt, t
    best_bt = best_bt or _heuristic_block(batch)
    _BLOCK_CACHE[(backend, bucket_cap, k)] = best_bt
    return best_bt


def model_seed(backend: str, bucket_cap: int, k: int, *,
               batch: int = 64, spec=None) -> int:
    """Seed the block cache from the analytic roofline model.

    Ranks candidate ``block_bt`` values by predicted launch cost
    (``launches·overhead + max(ops/peak, bytes/bw)`` against the
    platform's `DeviceSpec`) instead of timing them — no compiles, no
    device work.  Same cache slot empirical :func:`autotune` fills, so
    the two modes are interchangeable per site.
    """
    from repro.obs.roofline import predict_block_bt

    be = get_backend(backend)
    if not be.uses_pallas:  # lax/ref vmap the whole batch; nothing to tune
        bt = _heuristic_block(batch)
    else:
        bt = predict_block_bt(backend, bucket_cap, k, batch, spec=spec)
    _BLOCK_CACHE[(backend, bucket_cap, k)] = bt
    return bt


def clear_autotune_cache() -> None:
    """Drop every cached block size (tests / re-tuning on new hardware)."""
    _BLOCK_CACHE.clear()


# ----------------------------------------------------------- dispatch ----
def align_batch(
    texts,
    patterns,
    p_lens,
    t_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    backend: str | None = None,
    p_cap: int | None = None,
    emit_cigar: bool = True,
    block_bt: int | None = None,
) -> AlignResult:
    """Align a batch of (text, pattern) pairs on the selected backend.

    ``texts`` [B, t_cap] / ``patterns`` [B, p_cap] int8 buffers with
    ``t_lens`` / ``p_lens`` valid lengths (anchored semi-global, pattern
    fully consumed).  Returns a batched :class:`AlignResult` — identical
    distances/CIGARs across ``lax`` and ``pallas_dc*`` backends.
    """
    be = resolve_backend(backend)
    cap = int(patterns.shape[-1]) if p_cap is None else p_cap
    batch = int(texts.shape[0])
    if block_bt is None:
        key = (be.name, cap, cfg.k)
        mode = os.environ.get("REPRO_ALIGN_AUTOTUNE")
        if be.uses_pallas and key not in _BLOCK_CACHE:
            if mode == "model":
                model_seed(be.name, cap, cfg.k, batch=max(batch, 16))
            elif mode == "1" and not isinstance(texts, jax.core.Tracer):
                autotune(be.name, cap, cfg.k, batch=max(batch, 16), cfg=cfg)
        block_bt = block_size_for(be.name, cap, cfg.k, batch)
    return be.fn(texts, patterns, p_lens, t_lens, cfg=cfg, p_cap=cap,
                 emit_cigar=emit_cigar, block_bt=block_bt,
                 interpret=needs_interpret())
