"""Batched windowed alignment on the Pallas GenASM-DC kernels.

`repro.core.genasm.align` runs the paper's window loop per alignment and
vmaps the whole thing — fine for the pure-`lax` DC, but it would drive
the Pallas kernels at batch 1 per window.  Here the loop nesting is
inverted: the *batch* advances through its window steps together, so
each step issues **one** kernel launch over `[B, w]` windows (the
lane-per-alignment mapping of DESIGN.md §2) and the data-dependent
traceback (`window_tb`/`window_tb_r`) vmaps over the kernel's TB store.
Lanes that finish early keep issuing no-op windows (advance 0) until the
scan ends — shapes stay static, which is what lets the serve engine
cache one executor per bucket.

The per-window commit rules are shared with `core/genasm.align` (one
`window_commit` helper), so the emitted distances and CIGARs are
bit-identical to the `lax` backend (the conformance suite and the golden
PAF test both pin this).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bitvector import pattern_bitmasks
from repro.core.genasm import (AlignResult, GenASMConfig, pad_pattern,
                               pad_text, window_commit)
from repro.core.genasm_tb import OP_PAD, window_tb, window_tb_r


def _pad_to_block(arr: jnp.ndarray, block: int, fill) -> jnp.ndarray:
    """Pad the leading (batch) axis up to a multiple of ``block``."""
    b = arr.shape[0]
    pad = (-b) % block
    if not pad:
        return arr
    return jnp.concatenate(
        [arr, jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)])


def _dc_v1(sub_t, sub_p, *, w, k, block_bt, interpret):
    from repro.kernels.genasm_dc import window_dc_batch

    b = sub_t.shape[0]
    bt = min(block_bt, max(8, b))
    d, tb = window_dc_batch(
        _pad_to_block(sub_t, bt, 4), _pad_to_block(sub_p, bt, 4),
        w=w, k=k, block_bt=bt, interpret=interpret)
    return d[:b], tb[:b]


def _dc_v2(sub_t, sub_p, *, w, k, block_bt, interpret):
    from repro.kernels.genasm_dc_v2 import window_dc_batch_v2

    b = sub_t.shape[0]
    bt = min(block_bt, max(8, b))
    d, r = window_dc_batch_v2(
        _pad_to_block(sub_t, bt, 4), _pad_to_block(sub_p, bt, 4),
        w=w, k=k, block_bt=bt, interpret=interpret)
    return d[:b], r[:b]


@partial(jax.jit, static_argnames=("cfg", "p_cap", "emit_cigar", "store_r",
                                   "block_bt", "interpret"))
def batched_kernel_align(
    texts: jnp.ndarray,
    patterns: jnp.ndarray,
    p_lens: jnp.ndarray,
    t_lens: jnp.ndarray,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int | None = None,
    emit_cigar: bool = True,
    store_r: bool = False,
    block_bt: int = 128,
    interpret: bool = True,
) -> AlignResult:
    """Windowed GenASM over a batch, DC on the Pallas kernel.

    ``texts``/``patterns``: [B, *] int8 buffers; ``p_lens``/``t_lens``:
    [B] lengths.  ``store_r`` selects the v2 (R-only TB store) kernel.
    Returns a batched :class:`AlignResult`.
    """
    if p_cap is None:
        p_cap = int(patterns.shape[-1])
    n_win = cfg.n_windows(p_cap)
    max_steps = 2 * cfg.commit
    w, o, k = cfg.w, cfg.o, cfg.k
    b = texts.shape[0]
    p_lens = p_lens.astype(jnp.int32)
    t_lens = t_lens.astype(jnp.int32)

    pats = jax.vmap(lambda p, pl: pad_pattern(p, pl, p_cap, cfg))(
        patterns, p_lens)
    txts = jax.vmap(
        lambda t, tl: pad_text(t, tl, p_cap + n_win * cfg.commit, cfg))(
        texts, t_lens)

    dc = _dc_v2 if store_r else _dc_v1
    slice_w = jax.vmap(lambda buf, i: lax.dynamic_slice(buf, (i,), (w,)))
    if store_r:
        tb_fn = jax.vmap(
            partial(window_tb_r, w=w, o=o, k=k, affine=cfg.affine))
    else:
        tb_fn = jax.vmap(partial(window_tb, w=w, o=o, k=k, affine=cfg.affine))

    def window_step(carry, _):
        cur_p, cur_t = carry[0], carry[1]  # each [B]
        sub_p = slice_w(pats, cur_p)  # [B, w]
        sub_t = slice_w(txts, cur_t)
        d_min, tb = dc(sub_t, sub_p, w=w, k=k, block_bt=block_bt,
                       interpret=interpret)
        cap_p = jnp.minimum(jnp.int32(cfg.commit), p_lens - cur_p)
        if store_r:
            pm = jax.vmap(lambda p: pattern_bitmasks(p, w))(sub_p)
            pc, tc, err, ops, n_ops, stuck = tb_fn(
                tb, sub_t, pm, jnp.minimum(d_min, k), cap_p)
        else:
            pc, tc, err, ops, n_ops, stuck = tb_fn(
                tb, jnp.minimum(d_min, k), cap_p)
        new_carry, n_emit = window_commit(
            carry, d_min=d_min, pc=pc, tc=tc, err=err, n_ops=n_ops,
            stuck=stuck, p_len=p_lens, k=k)
        return new_carry, (ops, n_emit)

    zeros = jnp.zeros((b,), jnp.int32)
    init = (zeros, zeros, zeros, jnp.zeros((b,), bool), p_lens <= 0)
    (fin_p, fin_t, dist, failed, done), (ops_w, n_ops_w) = lax.scan(
        window_step, init, None, length=n_win)
    failed = failed | (~done)
    # scan stacked per-window outputs: ops_w [n_win, B, max_steps]
    ops_w = jnp.swapaxes(ops_w, 0, 1)  # [B, n_win, max_steps]
    n_ops_w = jnp.swapaxes(n_ops_w, 0, 1)  # [B, n_win]

    cap = n_win * max_steps
    if emit_cigar:
        def scatter(ops_b, n_b):
            offsets = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(n_b)[:-1]])
            step_idx = jnp.arange(max_steps)[None, :]
            valid = step_idx < n_b[:, None]
            pos = jnp.where(valid, offsets[:, None] + step_idx, cap)
            out = jnp.full((cap,), OP_PAD, jnp.int8)
            return out.at[pos.reshape(-1)].set(ops_b.reshape(-1), mode="drop")

        out = jax.vmap(scatter)(ops_w, n_ops_w)
    else:
        out = jnp.full((b, 1), OP_PAD, jnp.int8)
    n_total = jnp.sum(n_ops_w, axis=-1)

    return AlignResult(
        distance=jnp.where(failed, jnp.int32(-1), dist),
        ops=out,
        n_ops=n_total,
        text_consumed=fin_t,
        failed=failed,
    )
