"""`"ref"` backend: exact host-side DP with traceback (oracle-backed).

Same anchored semi-global semantics as the GenASM aligner (alignment
starts at ``text[0]``, the pattern must be fully consumed, trailing text
is free), computed by the obviously-correct O(nm) DP that
`core/oracle.levenshtein_prefix` scores — extended here with a traceback
so it emits the packed M/X/I/D CIGAR the rest of the stack consumes.

Runs under `jax.pure_callback`, so the backend is jit-safe (the serve
engine can select it like any other) while staying off the accelerator:
it is the conformance suite's ground truth and an end-of-the-line
debugging fallback, never a production path.
"""
from __future__ import annotations

import numpy as np

from repro.core.bitvector import WILDCARD
from repro.core.genasm_tb import OP_D, OP_I, OP_M, OP_PAD, OP_X


def _matches(p: int, c: int) -> bool:
    # wildcard pattern char matches everything (incl. text sentinels)
    return p == c or p == WILDCARD


def align_one(pattern: np.ndarray, text: np.ndarray, cap: int):
    """Exact semi-global alignment of one pair.

    Returns ``(distance, ops [cap] int8, n_ops, text_consumed)``.
    ``n_ops`` is the true op count even when ``cap`` truncates the
    stored buffer (the distances-only dispatch path uses ``cap=1`` but
    still reports the count, matching the windowed backends).
    """
    m, n = len(pattern), len(text)
    D = np.empty((m + 1, n + 1), np.int32)
    # anchored at text[0]: text consumed before the pattern starts costs
    # (row 0 = deletions); trailing text is free (min over the last row)
    D[0, :] = np.arange(n + 1)
    D[:, 0] = np.arange(m + 1)
    for i in range(1, m + 1):
        pc = pattern[i - 1]
        for j in range(1, n + 1):
            cost = 0 if _matches(pc, text[j - 1]) else 1
            D[i, j] = min(D[i - 1, j] + 1,      # I: consume pattern
                          D[i, j - 1] + 1,      # D: consume text
                          D[i - 1, j - 1] + cost)
    j_end = int(np.argmin(D[m, :]))
    dist = int(D[m, j_end])

    ops_rev = []
    i, j = m, j_end
    while i > 0 or j > 0:
        if i > 0 and j > 0:
            cost = 0 if _matches(pattern[i - 1], text[j - 1]) else 1
            if D[i, j] == D[i - 1, j - 1] + cost:
                ops_rev.append(OP_M if cost == 0 else OP_X)
                i -= 1
                j -= 1
                continue
        if i > 0 and D[i, j] == D[i - 1, j] + 1:
            ops_rev.append(OP_I)
            i -= 1
            continue
        ops_rev.append(OP_D)
        j -= 1

    ops = np.full((cap,), OP_PAD, np.int8)
    n_store = min(len(ops_rev), cap)
    ops[:n_store] = np.asarray(ops_rev[::-1][:n_store], np.int8)
    return dist, ops, len(ops_rev), j_end


def align_batch_host(texts: np.ndarray, patterns: np.ndarray,
                     p_lens: np.ndarray, t_lens: np.ndarray, cap: int):
    """Vectorized-over-rows host DP; the pure_callback body."""
    b = len(p_lens)
    dist = np.full((b,), 0, np.int32)
    ops = np.full((b, cap), OP_PAD, np.int8)
    n_ops = np.zeros((b,), np.int32)
    t_used = np.zeros((b,), np.int32)
    for i in range(b):
        pl_, tl = int(p_lens[i]), int(t_lens[i])
        d, o, n, tc = align_one(np.asarray(patterns[i][:pl_]),
                                np.asarray(texts[i][:tl]), cap)
        dist[i], ops[i], n_ops[i], t_used[i] = d, o, n, tc
    failed = np.zeros((b,), bool)  # the oracle always finds an alignment
    return dist, ops, n_ops, t_used, failed
