"""Shared seeded input generators for benchmarks and conformance tests.

One source of synthetic alignment inputs (fixed seeds, documented
distributions) so `benchmarks/kernel_dc.py`, `benchmarks/bitalign.py`,
`benchmarks/align_dispatch.py` and `tests/test_align_conformance.py`
all measure/check the same thing instead of each rolling its own rng
setup.  Everything returns host numpy; callers move to device.
"""
from __future__ import annotations

import numpy as np

from repro.core.bitvector import SENTINEL, WILDCARD


def random_windows(batch: int, w: int, *, seed: int = 13,
                   n_chars: int = 4) -> tuple[np.ndarray, np.ndarray]:
    """[batch, w] int8 (texts, patterns) window pairs (uniform bases)."""
    rng = np.random.default_rng(seed)
    texts = rng.integers(0, n_chars, size=(batch, w)).astype(np.int8)
    pats = rng.integers(0, n_chars, size=(batch, w)).astype(np.int8)
    return texts, pats


def mutate(seq: np.ndarray, n_sub: int, n_ins: int, n_del: int,
           rng: np.random.Generator) -> np.ndarray:
    """Inject exactly the given numbers of substitutions/insertions/deletions."""
    s = list(int(c) for c in seq)
    for _ in range(n_sub):
        i = int(rng.integers(0, len(s)))
        s[i] = (s[i] + int(rng.integers(1, 4))) % 4
    for _ in range(n_ins):
        i = int(rng.integers(0, len(s) + 1))
        s.insert(i, int(rng.integers(0, 4)))
    for _ in range(n_del):
        i = int(rng.integers(0, len(s)))
        del s[i]
    return np.array(s, np.int8)


def mutated_pair(rng: np.random.Generator, m: int, *, n_sub: int = 0,
                 n_ins: int = 0, n_del: int = 0,
                 t_extra: int = 32) -> tuple[np.ndarray, np.ndarray]:
    """One (pattern, text) pair: the pattern is a mutated copy of the
    text's first ``m`` bases; the text carries ``t_extra`` trailing bases
    so deletions never run off its end."""
    text = rng.integers(0, 4, size=m + t_extra).astype(np.int8)
    pattern = mutate(text[:m], n_sub, n_ins, n_del, rng)
    return pattern, text


def padded_batch(pairs: list[tuple[np.ndarray, np.ndarray]], p_cap: int,
                 t_cap: int):
    """Sentinel/wildcard-pad a ragged pair list into fixed-shape buffers.

    Returns ``(texts [B, t_cap], patterns [B, p_cap], p_lens, t_lens)``
    with wildcard-padded pattern tails and sentinel-padded text tails —
    the exact layout `align_batch` consumes.
    """
    b = len(pairs)
    texts = np.full((b, t_cap), SENTINEL, np.int8)
    pats = np.full((b, p_cap), WILDCARD, np.int8)
    p_lens = np.zeros((b,), np.int32)
    t_lens = np.zeros((b,), np.int32)
    for i, (pattern, text) in enumerate(pairs):
        pl_, tl = min(len(pattern), p_cap), min(len(text), t_cap)
        pats[i, :pl_] = pattern[:pl_]
        texts[i, :tl] = text[:tl]
        p_lens[i], t_lens[i] = pl_, tl
    return texts, pats, p_lens, t_lens


def aligned_read_batch(batch: int, read_len: int, *, p_cap: int | None = None,
                       t_extra: int = 128, n_sub: int = 2, n_ins: int = 1,
                       n_del: int = 1, seed: int = 29):
    """Fixed-shape batch of read-vs-region pairs for dispatch benchmarks."""
    rng = np.random.default_rng(seed)
    pairs = [mutated_pair(rng, read_len, n_sub=n_sub, n_ins=n_ins,
                          n_del=n_del, t_extra=t_extra) for _ in range(batch)]
    p_cap = p_cap or ((read_len + n_ins + 31) // 32) * 32
    return padded_batch(pairs, p_cap, read_len + t_extra)


def variant_graph(n_nodes: int, *, seed: int, n_snp: int, n_ins: int,
                  n_del: int, ref_margin: int = 12,
                  variant_seed: int | None = None):
    """One random reference + simulated-variant graph: ``(g, refseq)``.

    The shared graph construction behind
    `benchmarks/kernel_dc.py::run_bitalign_kernel` and
    `benchmarks/bitalign.py` (previously duplicated in each).
    ``variant_seed`` defaults to ``seed`` so one seed pins the whole
    graph; pass it explicitly to reproduce a historical input set.
    """
    from repro.core.segram import graph
    from repro.genomics import simulate

    rng = np.random.default_rng(seed)
    refseq = rng.integers(0, 4, size=n_nodes - ref_margin).astype(np.int8)
    g = graph.build_graph(refseq, simulate.simulate_variants(
        refseq, n_snp=n_snp, n_ins=n_ins, n_del=n_del,
        seed=seed if variant_seed is None else variant_seed))
    return g, refseq


def graph_read_batch(batch: int, n_nodes: int, m_bits: int, *, k_read: int,
                     seed: int = 17, n_snp: int = 4, n_ins: int = 2,
                     n_del: int = 2, variant_seed: int | None = None):
    """Batched (bases, succ_bits, patterns, p_lens) over one variant graph,
    patterns sampled as exact reference substrings of ``m_bits - k_read``."""
    from repro.core.segram import graph

    g, refseq = variant_graph(n_nodes, seed=seed, n_snp=n_snp, n_ins=n_ins,
                              n_del=n_del, variant_seed=variant_seed)
    b_, s_ = graph.extract_subgraph(g, 0, n_nodes)
    bases = np.broadcast_to(b_, (batch, n_nodes)).copy()
    succ = np.broadcast_to(s_, (batch, n_nodes)).copy()
    rng = np.random.default_rng(seed + 1)
    pats = np.full((batch, m_bits), WILDCARD, np.int8)
    plen = m_bits - k_read
    for i in range(batch):
        st = int(rng.integers(0, max(len(refseq) - plen, 1)))
        pats[i, :plen] = refseq[st: st + plen]
    p_lens = np.full((batch,), plen, np.int32)
    return bases, succ, pats, p_lens


def profile_read_patterns(refseq: np.ndarray, batch: int, read_len: int,
                          m_bits: int, *, profile, seed: int):
    """Error-profile-mutated reference substrings, wildcard-padded to
    ``[batch, m_bits]`` (the read set of `benchmarks/bitalign.py`)."""
    from repro.genomics import simulate

    rng = np.random.default_rng(seed)
    pats = np.full((batch, m_bits), WILDCARD, np.int8)
    for i in range(batch):
        s = int(rng.integers(0, max(len(refseq) - read_len - 4, 1)))
        r = simulate.mutate(refseq[s: s + read_len], profile, rng)
        pats[i, : min(len(r), m_bits)] = r[:m_bits]
    p_lens = np.full((batch,), read_len, np.int32)
    return pats, p_lens
