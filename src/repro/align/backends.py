"""The four built-in alignment backends (registered on import).

Each backend is an adapter from the uniform dispatch signature
``(texts, patterns, p_lens, t_lens, *, cfg, p_cap, emit_cigar,
block_bt, interpret)`` to one implementation:

  * ``ref``          — `refdp.align_batch_host` under `jax.pure_callback`
  * ``lax``          — `core/genasm.align` vmapped (pure-`lax` DC + TB)
  * ``pallas_dc``    — `batched.batched_kernel_align` on the v1 kernel
  * ``pallas_dc_v2`` — same, v2 kernel (R-only TB store)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import genasm
from repro.core.genasm import AlignResult, GenASMConfig

from . import refdp
from .api import register_backend
from .batched import batched_kernel_align


def _ref_fn(texts, patterns, p_lens, t_lens, *, cfg: GenASMConfig,
            p_cap: int, emit_cigar: bool, block_bt: int, interpret: bool):
    del block_bt, interpret  # no kernel underneath
    b = texts.shape[0]
    # same ops width as the windowed backends; distances-only mode keeps
    # the [b, 1] padded shape but still reports the true n_ops (the
    # traceback is O(n+m), trivial next to the O(nm) DP already paid)
    cap = cfg.ops_cap(p_cap) if emit_cigar else 1
    shapes = (
        jax.ShapeDtypeStruct((b,), jnp.int32),       # distance
        jax.ShapeDtypeStruct((b, cap), jnp.int8),    # ops
        jax.ShapeDtypeStruct((b,), jnp.int32),       # n_ops
        jax.ShapeDtypeStruct((b,), jnp.int32),       # text_consumed
        jax.ShapeDtypeStruct((b,), jnp.bool_),       # failed
    )
    dist, ops, n_ops, t_used, failed = jax.pure_callback(
        partial(refdp.align_batch_host, cap=cap), shapes,
        texts, patterns, p_lens, t_lens, vmap_method="sequential")
    return AlignResult(distance=dist, ops=ops, n_ops=n_ops,
                       text_consumed=t_used, failed=failed)


def _lax_fn(texts, patterns, p_lens, t_lens, *, cfg: GenASMConfig,
            p_cap: int, emit_cigar: bool, block_bt: int, interpret: bool):
    del block_bt, interpret  # no kernel underneath
    f = partial(genasm.align, cfg=cfg, p_cap=p_cap, emit_cigar=emit_cigar)
    return jax.vmap(f)(texts, patterns, p_lens, t_lens)


def _pallas_fn(texts, patterns, p_lens, t_lens, *, cfg: GenASMConfig,
               p_cap: int, emit_cigar: bool, block_bt: int, interpret: bool,
               store_r: bool):
    return batched_kernel_align(
        texts, patterns, p_lens, t_lens, cfg=cfg, p_cap=p_cap,
        emit_cigar=emit_cigar, store_r=store_r, block_bt=block_bt,
        interpret=interpret)


register_backend(
    "ref", _ref_fn,
    description="host numpy DP oracle with traceback (exact; test ground "
                "truth, never a production path)")
register_backend(
    "lax", _lax_fn,
    description="pure-jax.lax windowed GenASM (CPU default)")
register_backend(
    "pallas_dc", partial(_pallas_fn, store_r=False), uses_pallas=True,
    description="Pallas GenASM-DC kernel, M/I/D TB store (paper-faithful)")
register_backend(
    "pallas_dc_v2", partial(_pallas_fn, store_r=True), uses_pallas=True,
    description="Pallas GenASM-DC v2 kernel, R-only TB store (3x less TB "
                "traffic)")

# the sequence-to-graph backends (graph_lax / graph_pallas) live with the
# graph subsystem; importing them registers them alongside the linear four
from repro.graph import backends as _graph_backends  # noqa: E402,F401
