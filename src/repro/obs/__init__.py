"""repro.obs — dependency-free tracing, attribution, and exposition.

The dissertation's method is *characterize, then co-design*: every
accelerator decision in the source papers starts from a per-phase
breakdown of where wall time goes.  This package produces ours
automatically on every serving and benchmark run (DESIGN.md §12):

* `trace` — thread-safe monotonic-clock `Span`/`Tracer` with parent
  links and per-span attributes, a ring-buffer `TraceLog`, Chrome/
  Perfetto ``trace_event`` JSON export, and a structured JSONL sink.
* `attrib` — folds finished spans into a per-stage wall-time ledger
  (enqueue-wait → seed/filter → graph prefilter → DC filter → shard
  scatter → host merge → align → emit) and renders the Amdahl report:
  serial fraction, per-stage p50/p99, projected speedup from sharding
  each stage.
* `http` — stdlib exposition endpoint serving ``/metrics`` (the
  engine's `Metrics.render()`), ``/healthz``, ``/trace`` (last-N
  spans), ``/attrib`` (the live Amdahl report), and ``/roofline``
  (the per-kernel roofline table).
* `roofline` — kernel-level roofline layer (DESIGN.md §13): exact
  analytic op/byte counters per align-kernel launch, pluggable JSON
  `DeviceSpec` roofline targets, XLA ``cost_analysis()`` cross-checks,
  and the analytic block-size model behind
  ``REPRO_ALIGN_AUTOTUNE=model``.

Stdlib-only at import by design: it must import (and stay cheap) in
every environment the serving path runs in, kernels or not — the
roofline module's measured side lazy-imports `jax` only when asked.
"""
from .attrib import (AttributionReport, StageLedger, build_ledger,
                     render_report)
from .http import ObsServer
from .roofline import (DeviceSpec, KernelCounters, RooflineManager,
                       align_counters, dc_window_counters, predict_block_bt)
from .trace import NULL_TRACER, Span, StageTimer, TraceLog, Tracer

__all__ = [
    "Span", "Tracer", "TraceLog", "StageTimer", "NULL_TRACER",
    "StageLedger", "AttributionReport", "build_ledger", "render_report",
    "ObsServer",
    "DeviceSpec", "KernelCounters", "RooflineManager", "align_counters",
    "dc_window_counters", "predict_block_bt",
]
