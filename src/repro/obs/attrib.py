"""Per-stage wall-time attribution and the Amdahl report.

`build_ledger` folds finished spans (a `TraceLog` or a span list) into a
`StageLedger`: total wall time, call count, and the raw duration sample
per canonical pipeline stage —

    enqueue_wait   admission-queue wait (submit → flush start)
    encode         host read batching/padding
    seed_filter    linear seed + GenASM-DC pre-alignment filter
    prefilter      graph seed + q-gram tile screen (no DC)
    dc_filter      graph BitAlign-DC over the compacted candidate rows
    scatter        sharded per-shard seed+filter stage
    merge          host lexicographic merge of per-shard winners (legacy)
    merge_device   packed-key argmin-reduce of shard winners on device
    align          windowed GenASM/BitAlign alignment of the winners
    align_shard    the same align stage sharded over the shard mesh
    emit           result materialization, cache put, future resolution
    other          flush time not covered by any child stage span

Stage spans parented by a ``flush`` span additionally feed the coverage
accounting: ``coverage`` is attributed-stage time over total flush time,
the "stage wall-times sum to ≥90% of end-to-end time" check.  Stage
spans without a flush parent (direct executor use, failover drills)
still land in the ledger.

`StageLedger.report()` renders the Amdahl view the ROADMAP's sharding
items need: each stage's wall-time fraction of engine busy time,
p50/p99, whether today's implementation runs it serially, the measured
serial fraction, and the projected whole-pipeline speedup from sharding
*each* stage across N devices (``1 / ((1-f) + f/N)``) plus its ``N→∞``
ceiling (``1 / (1-f)``) — the number that says which stage to shard
next.  `render_report` formats the same dict as a fixed-width text
table for terminals and EXPERIMENTS.md.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Iterable, NamedTuple

from .trace import Span, TraceLog

# canonical stage order (pipeline position, not size)
STAGE_ORDER = ("enqueue_wait", "encode", "seed_filter", "prefilter",
               "dc_filter", "scatter", "merge", "merge_device", "align",
               "align_shard", "emit", "other")
_STAGE_SET = frozenset(STAGE_ORDER)

# stages whose current implementation already scales with shards; the
# rest (host merge, serial align launch, host emit, …) are the measured
# serial fraction sharding cannot touch until they are redesigned.
# merge_device/align_shard are the PR-10 device-resident replacements
# for the serial host merge and serial align launch.
PARALLEL_STAGES = frozenset({"seed_filter", "prefilter", "dc_filter",
                             "scatter", "merge_device", "align_shard"})


def _quantile(sorted_durs: list[float], q: float) -> float:
    if not sorted_durs:
        return 0.0
    i = min(int(q * len(sorted_durs)), len(sorted_durs) - 1)
    return sorted_durs[i]


class AttributionReport(NamedTuple):
    """The Amdahl report: per-stage rows + whole-pipeline aggregates."""

    stages: list[dict]  # per-stage {name, calls, total_s, frac, p50_ms, ...}
    busy_s: float  # attributed engine busy time (excl. enqueue_wait)
    flush_s: float  # total wall time inside flush spans
    n_flushes: int
    coverage: float  # attributed-stage time / flush time (0 if no flushes)
    serial_fraction: float  # busy-time fraction in non-parallel stages

    def to_dict(self) -> dict:
        """Plain-dict form for JSON summaries and the `/attrib` endpoint."""
        return {"stages": self.stages, "busy_s": self.busy_s,
                "flush_s": self.flush_s, "n_flushes": self.n_flushes,
                "coverage": self.coverage,
                "serial_fraction": self.serial_fraction}


class StageLedger:
    """Accumulated per-stage durations, foldable from spans or directly."""

    def __init__(self) -> None:
        self._durs: dict[str, list[float]] = defaultdict(list)
        self._ops: dict[str, float] = defaultdict(float)  # analytic word-ops
        self._bytes: dict[str, float] = defaultdict(float)  # analytic HBM B
        self.flush_s = 0.0
        self.n_flushes = 0
        self.attributed_s = 0.0  # stage time parented inside flush spans

    def add(self, stage: str, duration_s: float, *, word_ops: float = 0.0,
            hbm_bytes: float = 0.0) -> None:
        """Record one stage execution (unknown names fold into "other").

        ``word_ops``/``hbm_bytes`` are the stage's analytic kernel
        counters when known (the engine attaches them to align spans) —
        they surface as ops/s and intensity columns in the report.
        """
        name = stage if stage in _STAGE_SET else "other"
        self._durs[name].append(max(float(duration_s), 0.0))
        self._ops[name] += max(float(word_ops), 0.0)
        self._bytes[name] += max(float(hbm_bytes), 0.0)

    def total(self, stage: str) -> float:
        """Accumulated wall seconds recorded for one stage."""
        return sum(self._durs.get(stage, ()))

    @property
    def busy_s(self) -> float:
        """Attributed busy time: every stage except the queue wait."""
        return sum(sum(d) for s, d in self._durs.items()
                   if s != "enqueue_wait")

    @property
    def coverage(self) -> float:
        """Attributed-stage share of total flush wall time (1.0 = all)."""
        if self.flush_s <= 0.0:
            return 0.0
        return self.attributed_s / self.flush_s

    def report(self, shard_counts: tuple[int, ...] = (2, 4)
               ) -> AttributionReport:
        """Fold the ledger into the Amdahl report (see module docstring)."""
        busy = self.busy_s
        stages = []
        serial = 0.0
        for name in STAGE_ORDER:
            durs = sorted(self._durs.get(name, ()))
            if not durs:
                continue
            total = sum(durs)
            # enqueue_wait overlaps other flushes' compute and is not
            # part of busy time, so a busy-fraction would be meaningless
            # (and can exceed 1 under load) — report it as 0
            frac = (total / busy if busy > 0 and name != "enqueue_wait"
                    else 0.0)
            parallel = name in PARALLEL_STAGES
            if name != "enqueue_wait" and not parallel:
                serial += frac
            row = {
                "stage": name, "calls": len(durs),
                "total_s": round(total, 6),
                "frac": round(frac, 4),
                "p50_ms": round(_quantile(durs, 0.50) * 1e3, 3),
                "p99_ms": round(_quantile(durs, 0.99) * 1e3, 3),
                "parallel": parallel,
            }
            # projected whole-pipeline speedup from sharding THIS stage
            for n in shard_counts:
                row[f"speedup_x{n}"] = round(
                    1.0 / ((1.0 - frac) + frac / n), 3) if frac < 1.0 else n
            row["speedup_inf"] = (round(1.0 / (1.0 - frac), 3)
                                  if frac < 1.0 else float("inf"))
            # per-kernel roofline columns, when counters were attached
            ops, nbytes = self._ops.get(name, 0.0), self._bytes.get(name, 0.0)
            if ops > 0.0 or nbytes > 0.0:
                row["word_ops"] = ops
                row["hbm_bytes"] = nbytes
                row["ops_per_s"] = round(ops / total, 1) if total else 0.0
                row["intensity"] = round(ops / nbytes, 4) if nbytes else 0.0
            stages.append(row)
        return AttributionReport(
            stages=stages, busy_s=round(busy, 6),
            flush_s=round(self.flush_s, 6), n_flushes=self.n_flushes,
            coverage=round(self.coverage, 4),
            serial_fraction=round(serial, 4))


def build_ledger(spans: TraceLog | Iterable[Span]) -> StageLedger:
    """Fold finished spans into a `StageLedger`.

    ``flush`` spans define the end-to-end window; their children with
    canonical stage names are attributed, and per-flush time no child
    covers lands in ``other`` (so the ledger always sums back to the
    flush wall time).  ``enqueue_wait`` spans are tallied but excluded
    from busy time and coverage — they overlap the previous flush's
    compute by design.
    """
    if isinstance(spans, TraceLog):
        spans = spans.spans()
    spans = list(spans)
    led = StageLedger()
    flushes = {s.span_id: s for s in spans if s.name == "flush"}
    covered = defaultdict(float)  # flush id → child stage time
    for s in spans:
        if s.name not in _STAGE_SET:
            continue
        led.add(s.name, s.duration_s,
                word_ops=s.attrs.get("word_ops", 0.0) or 0.0,
                hbm_bytes=s.attrs.get("hbm_bytes", 0.0) or 0.0)
        if s.parent_id in flushes and s.name != "enqueue_wait":
            covered[s.parent_id] += s.duration_s
            led.attributed_s += s.duration_s
    for fid, f in flushes.items():
        led.flush_s += f.duration_s
        led.n_flushes += 1
        led.add("other", max(f.duration_s - covered[fid], 0.0))
    return led


def render_report(report: AttributionReport) -> str:
    """Fixed-width text table of the Amdahl report."""
    lines = [
        f"stage attribution: {report.n_flushes} flushes, "
        f"busy {report.busy_s * 1e3:.1f} ms, coverage "
        f"{report.coverage:.1%}, serial fraction "
        f"{report.serial_fraction:.1%}",
        f"{'stage':<13}{'calls':>6}{'total_ms':>10}{'frac':>7}"
        f"{'p50_ms':>9}{'p99_ms':>9}{'par':>5}{'spd@4':>7}{'spd@inf':>9}",
    ]
    for r in report.stages:
        inf = r["speedup_inf"]
        inf_s = "inf" if inf == float("inf") else f"{inf:.2f}"
        lines.append(
            f"{r['stage']:<13}{r['calls']:>6}{r['total_s'] * 1e3:>10.1f}"
            f"{r['frac']:>7.1%}{r['p50_ms']:>9.2f}{r['p99_ms']:>9.2f}"
            f"{'y' if r['parallel'] else '-':>5}"
            f"{r.get('speedup_x4', 1.0):>7.2f}{inf_s:>9}")
    return "\n".join(lines)
