"""Kernel-level roofline observability (DESIGN.md §13).

PR 7's tracing plane says *where wall time goes* per stage; this module
says *whether each kernel is fast for the hardware it runs on*.  GenASM's
DC and traceback phases have exact, analytically countable work — bit-
vector word-ops per (text step, distance row, word) and TB-store bytes
per window — so every align-kernel launch gets three numbers:

* **analytic** — exact per-launch counters (`align_counters`) as a pure
  function of ``(backend, bucket_cap, k, batch, w, o, block_bt)``.  The
  per-window terms are the ones already measured in EXPERIMENTS perf
  #3/#14 (``w·(k+1)·6·nw`` word-ops, ``w·(k+1)·3·nw·4`` TB bytes for the
  M/I/D store, ``(w+1)·(k+1)·nw·4`` for the v2 R-only store).  Exact for
  our code; responds to block-size and ladder changes.
* **measured** — ``jax.jit(...).lower(...).compile().cost_analysis()``
  flops / bytes-accessed per compiled ``(backend, cap)`` executor.
  CAVEATS (verified on the CPU backend, same class of skew as
  `launch/roofline.py`): XLA counts a ``while``/scan body ONCE, so the
  window loop undercounts by ~``n_windows``; and the CPU flop counter
  ignores integer/bitwise ops, so ``flops`` sees only the float residue
  of an integer-dominated program.  The sanity gate therefore checks
  order-of-magnitude agreement (documented factors in DESIGN.md §13),
  not precision.
* **achieved** — analytic ops over the wall-clock seconds the tracing
  plane already collects (executor ``last_times`` align intervals),
  yielding ops/s, bytes/s, arithmetic intensity, and %-of-roof against a
  pluggable :class:`DeviceSpec` (JSON files under ``device_specs/``:
  ``tpu_v5e``, ``gpu_generic``, ``cpu_host`` — the hardcoded v5e
  constants of `launch/roofline.py` live there now).

The same analytic model seeds the block-size autotune cache
(`repro.align.api`, ``REPRO_ALIGN_AUTOTUNE=model``): predicted launch
cost ``launches·overhead + max(ops/peak, bytes/bw)`` ranks candidate
``block_bt`` values with zero on-device search.

Stdlib-only at import time (the `repro.obs` contract): `jax` and
`repro.align` are imported lazily inside the measured-side helpers.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field
from pathlib import Path

SPEC_DIR = Path(__file__).with_name("device_specs")

# mirrors repro.core.bitvector.WORD_BITS without importing jax-adjacent code
WORD_BITS = 32
# word-ops per (text step, distance row, word) of the DC recurrence:
# three shl1 (shift+carry-or counts as 2) feed one 3-way AND chain —
# ~6 uint32 ops per cell, the accounting perf #3 established
DC_OPS_PER_CELL = 6
# the paper's TB store streams 3 intermediate bitvectors (M, I, D)
TB_VECTORS_V1 = 3


# ---------------------------------------------------------------- specs ----
@dataclass(frozen=True)
class DeviceSpec:
    """Roofline targets of one device, loaded from a JSON spec file.

    ``peak_flops`` is the dense-matmul peak (bf16 FMA/s — the LM
    roofline in `launch/roofline.py` divides by it); ``peak_word_ops``
    is the 32-bit integer/logical throughput of the vector unit, the
    peak the bit-parallel GenASM kernels can actually reach;
    ``launch_overhead_s`` is the fixed per-kernel-launch cost the
    block-size model amortizes.
    """

    name: str
    peak_flops: float
    peak_word_ops: float
    hbm_bw: float
    link_bw: float = 0.0
    launch_overhead_s: float = 0.0
    description: str = ""

    @classmethod
    def from_json(cls, path: str | Path) -> "DeviceSpec":
        """Load a spec file (unknown keys are ignored, future-proof)."""
        raw = json.loads(Path(path).read_text())
        kw = {k: raw[k] for k in
              ("name", "peak_flops", "peak_word_ops", "hbm_bw", "link_bw",
               "launch_overhead_s", "description") if k in raw}
        return cls(**kw)

    @classmethod
    def load(cls, name: str | Path) -> "DeviceSpec":
        """Bundled spec by name (``tpu_v5e``/``gpu_generic``/``cpu_host``)
        or any explicit ``*.json`` path."""
        p = Path(name)
        if p.suffix == ".json" and p.exists():
            return cls.from_json(p)
        bundled = SPEC_DIR / f"{name}.json"
        if not bundled.exists():
            known = sorted(f.stem for f in SPEC_DIR.glob("*.json"))
            raise ValueError(f"unknown device spec {name!r}; bundled: {known}")
        return cls.from_json(bundled)

    @classmethod
    def for_platform(cls, platform: str | None = None) -> "DeviceSpec":
        """Spec for the current (or named) JAX platform; cpu_host if JAX
        is unavailable — `repro.obs` must work in kernel-free installs."""
        if platform is None:
            try:
                import jax

                platform = jax.default_backend()
            except Exception:
                platform = "cpu"
        return cls.load({"tpu": "tpu_v5e", "gpu": "gpu_generic"}.get(
            platform, "cpu_host"))

    def roof_ops_per_s(self, intensity: float) -> float:
        """Attainable word-ops/s at ``intensity`` (ops/HBM byte)."""
        return min(self.peak_word_ops, max(intensity, 0.0) * self.hbm_bw)


# ------------------------------------------------------- analytic model ----
@dataclass(frozen=True)
class KernelCounters:
    """Exact per-``align_batch``-call work of one dispatch site."""

    word_ops: float  # uint32 ops across all launches of one call
    tb_bytes: float  # TB-store stream (the ASIC's TB-SRAM traffic)
    hbm_bytes: float  # total device-memory traffic (inputs+outputs+TB)
    launches: int  # kernel grid launches per call
    exact: bool = True  # False for the ref oracle's DP-cell estimate
    notes: dict = field(default_factory=dict)

    @property
    def intensity(self) -> float:
        """Arithmetic intensity: word-ops per HBM byte."""
        return self.word_ops / self.hbm_bytes if self.hbm_bytes else 0.0


def n_windows(bucket_cap: int, *, w: int = 64, o: int = 24) -> int:
    """Window steps of one aligned read at ``bucket_cap`` (cfg.n_windows)."""
    return -(-bucket_cap // (w - o)) + 2


def dc_window_counters(w: int, k: int, *, store: str = "mid") -> dict:
    """Hand-checkable per-lane, per-window DC terms.

    ``store`` selects the TB layout: ``"mid"`` (M/I/D, paper-faithful —
    the v1 kernel and the lax backend, which materializes the same
    store) or ``"r"`` (v2 R-only rows, perf #3).
    """
    if w % WORD_BITS:
        raise ValueError(f"w must be a multiple of {WORD_BITS}, got {w}")
    nw = w // WORD_BITS
    word_ops = w * (k + 1) * DC_OPS_PER_CELL * nw
    if store == "mid":
        tb_bytes = w * (k + 1) * TB_VECTORS_V1 * nw * 4
    elif store == "r":
        tb_bytes = (w + 1) * (k + 1) * nw * 4  # incl. the i=w boundary row
    else:
        raise ValueError(f"store must be 'mid' or 'r', got {store!r}")
    return {"word_ops": word_ops, "tb_bytes": tb_bytes, "nw": nw}


def effective_block(block_bt: int | None, batch: int) -> int:
    """The batch tile the kernel driver actually uses (`align.batched`
    clamps ``block_bt`` to ``min(block_bt, max(8, batch))``)."""
    return min(block_bt if block_bt else 128, max(8, batch))


_STORE_OF = {"lax": "mid", "pallas_dc": "mid", "pallas_dc_v2": "r"}


def align_counters(backend: str, bucket_cap: int, k: int, batch: int, *,
                   w: int = 64, o: int = 24,
                   block_bt: int | None = None) -> KernelCounters:
    """Exact analytic counters for one ``align_batch`` call at a site.

    Padded lanes execute (the driver pads the batch up to a ``block_bt``
    multiple), so they count; distances-only vs CIGAR does not change DC
    work.  The ``ref`` oracle has no kernel — it gets a DP-cell estimate
    (1 op + ~2 bytes per cell) flagged ``exact=False``.
    """
    nwin = n_windows(bucket_cap, w=w, o=o)
    if backend == "ref":
        t_cap = bucket_cap + 2 * w
        cells = float(batch) * bucket_cap * t_cap
        return KernelCounters(
            word_ops=cells, tb_bytes=0.0, hbm_bytes=2.0 * cells, launches=0,
            exact=False, notes={"model": "dp_cells", "n_windows": nwin})
    store = _STORE_OF.get(backend)
    if store is None:
        raise KeyError(f"no analytic counter model for backend {backend!r}")
    per = dc_window_counters(w, k, store=store)
    if backend == "lax":
        bt, b_pad = batch, batch  # vmap over the full batch, one launch/step
    else:
        bt = effective_block(block_bt, batch)
        b_pad = -(-batch // bt) * bt
    launches = nwin * (b_pad // bt if bt else 1)
    lanes = nwin * b_pad  # window executions across the whole call
    word_ops = float(lanes) * per["word_ops"]
    tb_bytes = float(lanes) * per["tb_bytes"]
    # per window step: read text+pattern tiles (int8), write d_min (int32)
    # and stream the TB store to device memory
    io_bytes = float(nwin) * b_pad * (2 * w + 4)
    return KernelCounters(
        word_ops=word_ops, tb_bytes=tb_bytes, hbm_bytes=io_bytes + tb_bytes,
        launches=launches,
        notes={"n_windows": nwin, "block_bt": bt, "batch_padded": b_pad,
               "store": store})


def predict_time_s(c: KernelCounters, spec: DeviceSpec) -> float:
    """Model time of one call: launch overhead + the binding roof term."""
    roof = max(c.word_ops / spec.peak_word_ops,
               c.hbm_bytes / spec.hbm_bw if spec.hbm_bw else 0.0)
    return c.launches * spec.launch_overhead_s + roof


def predict_block_bt(backend: str, bucket_cap: int, k: int, batch: int, *,
                     spec: DeviceSpec | None = None,
                     candidates: tuple[int, ...] = (8, 16, 32, 64, 128, 256),
                     w: int = 64, o: int = 24) -> int:
    """Model-predicted best batch tile for a dispatch site.

    Ranks each candidate by :func:`predict_time_s` — padding waste grows
    the op/byte terms, small tiles grow the launch term — preferring the
    larger tile on ties (fewer launches never hurts the model).  No
    device work: this is what ``REPRO_ALIGN_AUTOTUNE=model`` calls.
    """
    spec = spec or DeviceSpec.for_platform()
    best_bt, best_t = None, float("inf")
    for bt in sorted(set(effective_block(c, batch) for c in candidates)):
        t = predict_time_s(
            align_counters(backend, bucket_cap, k, batch,
                           w=w, o=o, block_bt=bt), spec)
        if t < best_t or (t == best_t and best_bt is not None
                          and bt > best_bt):
            best_bt, best_t = bt, t
    return best_bt or effective_block(None, batch)


# -------------------------------------------------------- measured side ----
def measured_align_cost(backend: str, bucket_cap: int, k: int, batch: int, *,
                        block_bt: int | None = None) -> dict:
    """Compiled-executor ``cost_analysis()`` for one dispatch site.

    Lowers + compiles the backend fn on synthetic input at the site's
    signature (distances-only — DC work is what the model counts) and
    returns ``{"measured_ops", "measured_bytes"}``.  See the module
    docstring for the documented skews on CPU.  Raises whatever the
    lowering raises; callers that must not fail wrap this.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.align.api import get_backend, needs_interpret
    from repro.core.genasm import GenASMConfig

    be = get_backend(backend)
    cfg = GenASMConfig(k=k, o=min(k, 24) or 8)
    rng = np.random.default_rng(0xB10C)
    texts = jnp.asarray(
        rng.integers(0, 4, size=(batch, bucket_cap + 2 * cfg.w)), jnp.int8)
    pats = jnp.asarray(
        rng.integers(0, 4, size=(batch, bucket_cap)), jnp.int8)
    p_lens = jnp.full((batch,), bucket_cap, jnp.int32)
    t_lens = jnp.full((batch,), bucket_cap + 2 * cfg.w, jnp.int32)
    bt = effective_block(block_bt, batch)

    def fn(t, p, pl, tl):
        return be.fn(t, p, pl, tl, cfg=cfg, p_cap=bucket_cap,
                     emit_cigar=False, block_bt=bt,
                     interpret=needs_interpret()).distance

    ca = jax.jit(fn).lower(texts, pats, p_lens, t_lens).compile() \
        .cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<0.4.40 returns one dict/device
        ca = ca[0] if ca else {}
    return {"measured_ops": float(ca.get("flops", 0.0)),
            "measured_bytes": float(ca.get("bytes accessed", 0.0))}


# ------------------------------------------------------------- manager ----
@dataclass
class _Site:
    """One ``(backend, bucket_cap, k, batch, block_bt)`` dispatch site."""

    backend: str
    bucket_cap: int
    k: int
    batch: int
    block_bt: int | None
    counters: KernelCounters
    calls: int = 0
    align_s: float = 0.0
    measured: dict | None = None  # cost_analysis cache (or {"error": ...})

    @property
    def key(self) -> str:
        return f"{self.backend}/cap{self.bucket_cap}"


class RooflineManager:
    """Per-process registry of align-kernel dispatch sites (snippet-1 shape).

    The serve engine calls :meth:`record_flush` after every linear-
    workload flush with the align stage's wall interval; the manager
    folds in the site's analytic counters, increments the per-kernel
    `Metrics` counters (``kernel_<backend>_cap<cap>_word_ops`` /
    ``_tb_bytes`` / ``_hbm_bytes`` / ``_launches`` / ``_align_s``), and
    emits a Perfetto ``"C"`` counter sample through the bound tracer.
    :meth:`report` is the ``/roofline`` payload: one row per site with
    analytic, measured (lazy ``cost_analysis()``, cached), and achieved
    terms against the device spec.  ``enabled=False`` makes
    ``record_flush`` a no-op (the A/B switch the overhead benchmark
    toggles).
    """

    def __init__(self, spec: DeviceSpec | None = None, *, metrics=None,
                 tracer=None, enabled: bool = True,
                 measure: bool = True) -> None:
        self.spec = spec or DeviceSpec.for_platform()
        self.metrics = metrics
        self.tracer = tracer
        self.enabled = enabled
        self.measure = measure  # allow cost_analysis compiles from report()
        self._sites: dict[tuple, _Site] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ record --
    def site(self, backend: str, bucket_cap: int, k: int, batch: int,
             block_bt: int | None = None) -> _Site | None:
        """Get-or-register a dispatch site (None if unmodelable)."""
        key = (backend, bucket_cap, k, batch, block_bt)
        with self._lock:
            s = self._sites.get(key)
            if s is None:
                try:
                    c = align_counters(backend, bucket_cap, k, batch,
                                       block_bt=block_bt)
                except KeyError:  # graph/unknown backends: no model yet
                    return None
                s = self._sites[key] = _Site(
                    backend=backend, bucket_cap=bucket_cap, k=k, batch=batch,
                    block_bt=block_bt, counters=c)
            return s

    def record_flush(self, backend: str, bucket_cap: int, k: int, batch: int,
                     *, align_s: float | None,
                     block_bt: int | None = None) -> KernelCounters | None:
        """Fold one flush's align launch into the site's running totals."""
        if not self.enabled:
            return None
        s = self.site(backend, bucket_cap, k, batch, block_bt)
        if s is None:
            return None
        c = s.counters
        with self._lock:
            s.calls += 1
            if align_s is not None:
                s.align_s += max(align_s, 0.0)
            cum_ops, cum_bytes = c.word_ops * s.calls, c.hbm_bytes * s.calls
        if self.metrics is not None:
            pre = f"kernel_{backend}_cap{bucket_cap}"
            self.metrics.counter(f"{pre}_word_ops").inc(c.word_ops)
            self.metrics.counter(f"{pre}_tb_bytes").inc(c.tb_bytes)
            self.metrics.counter(f"{pre}_hbm_bytes").inc(c.hbm_bytes)
            self.metrics.counter(f"{pre}_launches").inc(c.launches)
            if align_s is not None:
                self.metrics.counter(f"{pre}_align_s").inc(max(align_s, 0.0))
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.counter(f"kernel/{s.key}", word_ops=cum_ops,
                                hbm_bytes=cum_bytes)
        return c

    # ------------------------------------------------------------ report --
    def _measure_site(self, s: _Site) -> dict | None:
        if s.measured is None and self.measure:
            try:
                s.measured = measured_align_cost(
                    s.backend, s.bucket_cap, s.k, s.batch,
                    block_bt=s.block_bt)
            except Exception as e:  # keep /roofline alive on exotic backends
                s.measured = {"error": f"{type(e).__name__}: {e}"}
        return s.measured

    def report(self, *, measure: bool | None = None) -> dict:
        """The ``/roofline`` payload: one row per compiled dispatch site."""
        with self._lock:
            sites = list(self._sites.values())
        rows = []
        for s in sites:
            c = s.counters
            m = self._measure_site(s) if (measure if measure is not None
                                          else self.measure) else s.measured
            m = m or {}
            ach_ops = c.word_ops * s.calls / s.align_s if s.align_s else 0.0
            ach_bytes = c.hbm_bytes * s.calls / s.align_s if s.align_s else 0.0
            roof = self.spec.roof_ops_per_s(c.intensity)
            rows.append({
                "kernel": s.key,
                "backend": s.backend, "bucket_cap": s.bucket_cap,
                "k": s.k, "batch": s.batch,
                "block_bt": c.notes.get("block_bt"),
                "launches_per_call": c.launches, "calls": s.calls,
                "exact": c.exact,
                "analytic_ops": c.word_ops,
                "analytic_tb_bytes": c.tb_bytes,
                "bytes": c.hbm_bytes,
                "measured_ops": m.get("measured_ops"),
                "measured_bytes": m.get("measured_bytes"),
                "measure_error": m.get("error"),
                "intensity": round(c.intensity, 4),
                "align_s": round(s.align_s, 6),
                "achieved_ops_per_s": ach_ops,
                "achieved_bytes_per_s": ach_bytes,
                "pct_of_roof": round(ach_ops / roof, 6) if roof else 0.0,
            })
        rows.sort(key=lambda r: (r["backend"], r["bucket_cap"]))
        return {"device_spec": {
                    "name": self.spec.name,
                    "peak_word_ops": self.spec.peak_word_ops,
                    "peak_flops": self.spec.peak_flops,
                    "hbm_bw": self.spec.hbm_bw,
                    "link_bw": self.spec.link_bw,
                    "launch_overhead_s": self.spec.launch_overhead_s},
                "kernels": rows}
