"""Thread-safe request tracing: spans, ring-buffer log, Perfetto export.

A `Span` is one named wall-time interval on the monotonic clock with a
parent link and free-form attributes (bucket cap, tile rung, shard id,
dc_rows, compile-vs-execute flag, …).  A `Tracer` hands them out either
scoped (``with tracer.span("flush"):`` — nesting tracked per thread) or
retroactively (``tracer.add(name, t0, t1)`` — how executors report
stage timings they measured themselves), and appends finished spans to
a bounded `TraceLog` ring buffer.

The log exports two ways:

* ``to_chrome()`` / ``export_chrome(path)`` — Chrome ``trace_event``
  JSON (the *JSON Object Format*: ``{"traceEvents": [...]}``), loadable
  in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
  Scoped spans become ``"ph": "X"`` complete events on their thread's
  track; spans marked ``async_=True`` (e.g. per-request enqueue waits,
  which overlap freely) become ``"b"``/``"e"`` async pairs so they
  never break slice nesting; instant events become ``"ph": "i"``;
  counter samples (``tracer.counter(...)``, numeric attrs only) become
  ``"ph": "C"`` counter tracks — Perfetto plots each attr as a series.
* ``export_jsonl(path)`` — one structured JSON object per line (name,
  t_start/t_end, duration, parent, tid, attrs), the machine-readable
  sink for offline analysis.

Everything is stdlib; a disabled tracer (`NULL_TRACER`) costs one
attribute check per call site, which is what keeps tracing overhead on
the serving hot path under the 3% budget (EXPERIMENTS.md perf #18).
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Span:
    """One named monotonic-clock interval with parent link + attributes."""

    name: str
    t_start: float
    t_end: float = 0.0
    span_id: int = 0
    parent_id: int | None = None
    tid: str = "main"
    kind: str = "span"  # "span" | "instant" | "async" | "counter"
    attrs: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        """Wall-clock seconds spanned (0.0 for unfinished/instant spans)."""
        return max(self.t_end - self.t_start, 0.0)

    def set(self, **attrs) -> None:
        """Attach attributes to a live span (inside its ``with`` block)."""
        self.attrs.update(attrs)

    def to_dict(self) -> dict:
        """Plain-dict form (the JSONL/`/trace` wire representation)."""
        return {
            "name": self.name, "span_id": self.span_id,
            "parent_id": self.parent_id, "tid": self.tid,
            "kind": self.kind, "t_start": self.t_start,
            "t_end": self.t_end, "duration_ms": self.duration_s * 1e3,
            "attrs": dict(self.attrs),
        }


class _NullSpan:
    """Inert stand-in yielded by a disabled tracer's ``span()``."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        """Accept and discard attributes (mirrors `Span.set`)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class TraceLog:
    """Bounded ring buffer of finished spans with JSON exporters."""

    def __init__(self, max_spans: int = 65536) -> None:
        self._buf: deque[Span] = deque(maxlen=max_spans)
        self._lock = threading.Lock()
        self.dropped = 0  # spans evicted by the ring bound
        self.t0 = time.monotonic()  # export time base

    @property
    def max_spans(self) -> int:
        """Ring capacity (the clamp bound for ``/trace?n=``)."""
        return self._buf.maxlen or 0

    def append(self, span: Span) -> None:
        """Push one finished span (evicts the oldest when full)."""
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(span)

    def spans(self) -> list[Span]:
        """Snapshot of the buffered spans, oldest first."""
        with self._lock:
            return list(self._buf)

    def last(self, n: int) -> list[dict]:
        """The most recent ``n`` spans as plain dicts (newest last)."""
        with self._lock:
            tail = list(self._buf)[-max(n, 0):]
        return [s.to_dict() for s in tail]

    def clear(self) -> None:
        """Drop every buffered span and reset the dropped counter."""
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # ------------------------------------------------------------- export --
    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` JSON object (Perfetto-loadable)."""
        events: list[dict] = []
        tids: dict[str, int] = {}

        def tid_of(label: str) -> int:
            i = tids.get(label)
            if i is None:
                i = tids[label] = len(tids) + 1
                events.append({"name": "thread_name", "ph": "M", "pid": 0,
                               "tid": i, "args": {"name": label}})
            return i

        for s in self.spans():
            ts = (s.t_start - self.t0) * 1e6
            base = {"name": s.name, "pid": 0, "tid": tid_of(s.tid),
                    "cat": "serve", "ts": ts}
            args = {k: v for k, v in s.attrs.items()}
            if s.kind == "instant":
                events.append({**base, "ph": "i", "s": "t", "args": args})
            elif s.kind == "counter":
                events.append({**base, "ph": "C", "args": args})
            elif s.kind == "async":
                ident = f"0x{s.span_id:x}"
                events.append({**base, "ph": "b", "id": ident, "args": args})
                events.append({**base, "ph": "e", "id": ident,
                               "ts": (s.t_end - self.t0) * 1e6, "args": {}})
            else:
                events.append({**base, "ph": "X", "args": args,
                               "dur": max((s.t_end - s.t_start) * 1e6, 0.0)})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> None:
        """Write the Perfetto/Chrome ``trace_event`` JSON file."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def export_jsonl(self, path: str) -> None:
        """Write one structured JSON object per span, oldest first."""
        with open(path, "w") as f:
            for s in self.spans():
                f.write(json.dumps(s.to_dict()) + "\n")


class Tracer:
    """Span factory over one `TraceLog`; per-thread nesting for parents.

    ``span()`` opens a scoped span (context manager — the parent is
    whatever span encloses it on the same thread); ``add()`` records a
    retroactive span from timestamps measured elsewhere (parented to
    the thread's current open span); ``event()`` records an instant.
    A tracer constructed with ``enabled=False`` turns every call into a
    near-free no-op — call sites never need their own guards, though
    hot loops may still check ``tracer.enabled`` to skip argument
    setup.
    """

    def __init__(self, enabled: bool = True,
                 log: TraceLog | None = None) -> None:
        self.enabled = enabled
        self.log = log if log is not None else TraceLog()
        self._ids = itertools.count(1)
        self._tl = threading.local()

    # ------------------------------------------------------------ helpers --
    def _stack(self) -> list[int]:
        st = getattr(self._tl, "stack", None)
        if st is None:
            st = self._tl.stack = []
        return st

    def _tid(self) -> str:
        t = threading.current_thread()
        return t.name or f"thread-{t.ident}"

    def current_parent(self) -> int | None:
        """Span id of this thread's innermost open span (None at top)."""
        st = self._stack()
        return st[-1] if st else None

    # ------------------------------------------------------------ surface --
    @contextmanager
    def span(self, name: str, **attrs):
        """Scoped span: ``with tracer.span("flush", bucket_cap=320) as s:``."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        s = Span(name=name, t_start=time.monotonic(),
                 span_id=next(self._ids), parent_id=self.current_parent(),
                 tid=self._tid(), attrs=attrs)
        st = self._stack()
        st.append(s.span_id)
        try:
            yield s
        finally:
            st.pop()
            s.t_end = time.monotonic()
            self.log.append(s)

    def add(self, name: str, t_start: float, t_end: float, *,
            tid: str | None = None, parent: int | None = None,
            async_: bool = False, **attrs) -> None:
        """Retroactive span from timestamps already on the monotonic clock."""
        if not self.enabled:
            return
        self.log.append(Span(
            name=name, t_start=t_start, t_end=t_end,
            span_id=next(self._ids),
            parent_id=self.current_parent() if parent is None else parent,
            tid=tid if tid is not None else self._tid(),
            kind="async" if async_ else "span", attrs=attrs))

    def event(self, name: str, **attrs) -> None:
        """Instant event (zero-duration span, ``ph: "i"`` in the export)."""
        if not self.enabled:
            return
        t = time.monotonic()
        self.log.append(Span(
            name=name, t_start=t, t_end=t, span_id=next(self._ids),
            parent_id=self.current_parent(), tid=self._tid(),
            kind="instant", attrs=attrs))

    def counter(self, name: str, **values) -> None:
        """Counter sample (``ph: "C"``): each numeric kwarg is a series.

        Samples with the same ``name`` form one Perfetto counter track;
        pass cumulative values for monotone plots (the roofline manager
        sends running op/byte totals per kernel).
        """
        if not self.enabled:
            return
        t = time.monotonic()
        self.log.append(Span(
            name=name, t_start=t, t_end=t, span_id=next(self._ids),
            parent_id=None, tid=self._tid(), kind="counter", attrs=values))


NULL_TRACER = Tracer(enabled=False)


class StageTimer:
    """Per-call stage clock executors use to fill their ``last_times``.

    Records ``(stage, t_start, t_end, attrs)`` tuples — the engine (or a
    benchmark) replays them into a `Tracer` via ``add()``.  Callers must
    block on the stage's device work inside the ``stage()`` scope
    (``jax.block_until_ready`` / ``np.asarray``) or the interval only
    measures async dispatch.
    """

    def __init__(self) -> None:
        self.times: list[tuple[str, float, float, dict]] = []

    @contextmanager
    def stage(self, name: str, **attrs):
        """Scope one stage: appends ``(name, t0, t1, attrs)`` on exit."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.times.append((name, t0, time.monotonic(), attrs))
