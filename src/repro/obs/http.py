"""Stdlib HTTP exposition: /metrics, /healthz, /trace, /attrib, /roofline.

`ObsServer` runs a ``ThreadingHTTPServer`` on a daemon thread and serves
the observability plane of one serving process:

* ``GET /metrics``  — the engine's ``Metrics.render()`` text page
  (Prometheus-style ``name value`` lines).
* ``GET /healthz``  — liveness probe, always ``200 ok`` while the
  thread is up (a k8s-style readiness hook point).
* ``GET /trace``    — the last-N finished spans as JSON (``?n=500``
  caps the tail; default 256, clamped to the ring size; non-integer or
  negative ``n`` is a ``400``).
* ``GET /attrib``   — the live per-stage Amdahl report folded from the
  tracer's ring buffer (`repro.obs.attrib`).
* ``GET /roofline`` — the per-kernel roofline table from an attached
  `RooflineManager` (`repro.obs.roofline`): analytic vs measured ops
  and bytes, intensity, %-of-roof per ``(backend, bucket_cap)`` site.
  ``?measure=0`` skips the lazy ``cost_analysis()`` compile step.

Construct with ``port=0`` for an ephemeral port (tests); ``.port``
reports the bound port either way.  ``close()`` shuts the thread down.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from .attrib import build_ledger
from .trace import Tracer


class ObsServer:
    """Daemon-thread HTTP endpoint over a `Metrics` registry + `Tracer`."""

    def __init__(self, *, metrics=None, tracer: Tracer | None = None,
                 roofline=None, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        obs = self

        class Handler(BaseHTTPRequestHandler):
            """Routes the five GET endpoints over the enclosing ObsServer."""

            def log_message(self, *args):
                """Silence the default per-request stderr logging."""

            def _send(self, code: int, body: str,
                      ctype: str = "text/plain; charset=utf-8") -> None:
                data = body.encode()
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
                """Serve /healthz, /metrics, /trace, /attrib (404 else)."""
                url = urlparse(self.path)
                try:
                    if url.path == "/healthz":
                        self._send(200, "ok\n")
                    elif url.path == "/metrics":
                        if obs.metrics is None:
                            self._send(404, "no metrics registry attached\n")
                        else:
                            self._send(200, obs.metrics.render())
                    elif url.path == "/trace":
                        if obs.tracer is None:
                            self._send(404, "no tracer attached\n")
                        else:
                            q = parse_qs(url.query, keep_blank_values=True)
                            raw = q.get("n", ["256"])[0]
                            try:
                                n = int(raw)
                            except ValueError:
                                n = -1
                            if n < 0:
                                self._send(400, f"bad n={raw!r}: must be a "
                                                "non-negative integer\n")
                            else:
                                n = min(n, obs.tracer.log.max_spans)
                                self._send(
                                    200,
                                    json.dumps(
                                        {"spans": obs.tracer.log.last(n),
                                         "dropped": obs.tracer.log.dropped}),
                                    "application/json")
                    elif url.path == "/attrib":
                        if obs.tracer is None:
                            self._send(404, "no tracer attached\n")
                        else:
                            rep = build_ledger(obs.tracer.log).report()
                            self._send(200, json.dumps(rep.to_dict()),
                                       "application/json")
                    elif url.path == "/roofline":
                        if obs.roofline is None:
                            self._send(404, "no roofline manager attached\n")
                        else:
                            q = parse_qs(url.query)
                            measure = q.get("measure", ["1"])[0] not in (
                                "0", "false", "no")
                            self._send(
                                200,
                                json.dumps(
                                    obs.roofline.report(measure=measure)),
                                "application/json")
                    else:
                        self._send(404, "unknown path; try /metrics, "
                                        "/healthz, /trace, /attrib, "
                                        "/roofline\n")
                except BrokenPipeError:  # client went away mid-write
                    pass

        self.metrics = metrics
        self.tracer = tracer
        self.roofline = roofline
        self._srv = ThreadingHTTPServer((host, port), Handler)
        self._srv.daemon_threads = True
        self.host, self.port = self._srv.server_address[:2]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, name="obs-http", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        """Base URL of the bound endpoint (ephemeral port resolved)."""
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        """Stop serving and join the endpoint thread (idempotent)."""
        self._srv.shutdown()
        self._srv.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
