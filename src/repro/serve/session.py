"""Client session + synthetic open-loop load generator.

``Session`` is the thin client surface over the engine: ``submit()``
tags each read with caller metadata (e.g. the global read id) and
``drain()`` returns ``(meta, ServeResult)`` pairs in submission order —
the shape both serving modes of `launch/serve_genomics.py` consume.

``poisson_load`` replays a read list through a session under *open-loop*
Poisson arrivals (exponential inter-arrival gaps at ``rate_rps``,
submitted on schedule regardless of completion — the arrival process of
an online mapping service, and the regime where micro-batching policy
actually matters: closed-loop benchmarks never build queues).
"""
from __future__ import annotations

import time
from typing import NamedTuple, Sequence

import numpy as np

from .engine import ServeEngine, ServeResult


class Session:
    """Order-preserving submit/drain wrapper around a ``ServeEngine``."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._pending: list[tuple[object, object]] = []  # (meta, future)

    def submit(self, read: np.ndarray, meta=None):
        fut = self.engine.submit(read)
        self._pending.append((meta, fut))
        return fut

    def drain(self) -> list[tuple[object, ServeResult]]:
        """Gather every outstanding result, in submission order."""
        out = [(meta, fut.result()) for meta, fut in self._pending]
        self._pending.clear()
        return out


class LoadReport(NamedTuple):
    results: list  # [(meta, ServeResult)] in submission order
    elapsed_s: float
    reads_per_s: float
    p50_ms: float
    p99_ms: float
    metrics: dict  # engine metrics snapshot at end of run


def poisson_load(engine: ServeEngine, reads: Sequence[np.ndarray], *,
                 rate_rps: float, seed: int = 0,
                 metas: Sequence | None = None) -> LoadReport:
    """Open-loop Poisson replay of ``reads`` at ``rate_rps`` arrivals/s."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=len(reads))
    sess = Session(engine)
    t0 = time.monotonic()
    next_t = t0
    for i, read in enumerate(reads):
        next_t += gaps[i]
        delay = next_t - time.monotonic()
        if delay > 0:  # open loop: never waits on completions, only the clock
            time.sleep(delay)
        sess.submit(read, metas[i] if metas is not None else i)
    results = sess.drain()
    elapsed = time.monotonic() - t0
    lat = sorted(r.latency_s for _, r in results)

    def q(p: float) -> float:
        return lat[min(int(p * len(lat)), len(lat) - 1)] * 1e3 if lat else 0.0

    return LoadReport(
        results=results, elapsed_s=elapsed,
        reads_per_s=len(reads) / elapsed if elapsed else 0.0,
        p50_ms=q(0.50), p99_ms=q(0.99),
        metrics=engine.metrics.snapshot())
