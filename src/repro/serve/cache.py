"""LRU result cache keyed on (read-bytes digest, index-epoch token).

Online mappers see heavy key reuse (duplicate reads from PCR/optical
duplicates, resubmitted requests, popular amplicons), and a mapping is a
pure function of (read bases, reference index) — so results are cacheable
as long as the key pins *which* reference index produced them.  The index
half of the key is an opaque hashable **epoch token**:

* single-device serving passes the scalar ``EpochedIndex`` /
  ``EpochedGraphIndex`` epoch (`core/minimizer_index.py`,
  `graph/index.py`) — refreshing the reference bumps it, which
  atomically invalidates every cached result without touching the cache
  (stale epochs simply never match and age out of the LRU);
* sharded serving (`repro.shard`) passes the ``(layout_key, epoch
  vector)`` token from ``EpochedShardedIndex.current()``.  The vector
  matters: shard-*local* epoch counters are not globally unique — after
  one shard's failover re-materialization, a scalar such as
  ``max(epochs)`` or a single shard's counter can collide with a
  different overall shard state (or a different layout entirely) and
  serve a result mapped against the wrong reference bytes.  Keying on
  the full layout + vector makes any observable index change a new key.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Hashable

import numpy as np


def read_digest(read: np.ndarray) -> bytes:
    """Stable digest of the read's bases (dtype/shape-normalized)."""
    return hashlib.blake2b(
        np.ascontiguousarray(read, dtype=np.int8).tobytes(), digest_size=16
    ).digest()


class ResultCache:
    """Thread-safe LRU of mapping results.

    ``capacity == 0`` disables caching (get always misses, put drops).
    Hit/miss counts feed the engine's cache-hit-rate metric.
    """

    def __init__(self, capacity: int = 4096) -> None:
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._d: OrderedDict[tuple[bytes, Hashable], object] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, read: np.ndarray, epoch: Hashable, *,
            digest: bytes | None = None):
        """Cached result for (read, epoch token), or None; counts hit/miss."""
        if self.capacity == 0:  # disabled: skip the digest on the hot path
            with self._lock:
                self.misses += 1
            return None
        key = (digest or read_digest(read), epoch)
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, read: np.ndarray, epoch: Hashable, value, *,
            digest: bytes | None = None) -> None:
        """Insert a result under (read, epoch token), evicting LRU overflow."""
        if self.capacity == 0:
            return
        key = (digest or read_digest(read), epoch)
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def evict_epochs_below(self, epoch: int) -> int:
        """Eagerly drop entries from pre-``epoch`` scalar-epoch indexes.

        Optional — stale entries are unreachable either way — but frees
        capacity immediately after a reference refresh.  Only entries
        whose token is a plain int are compared (sharded epoch-vector
        tokens have no total order; they age out of the LRU instead).
        """
        with self._lock:
            stale = [k for k in self._d
                     if isinstance(k[1], int) and k[1] < epoch]
            for k in stale:
                del self._d[k]
            return len(stale)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    @property
    def hit_rate(self) -> float:
        """Fraction of gets served from cache (0.0 before any get)."""
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
