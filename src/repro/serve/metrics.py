"""Serving metrics: counters, gauges, log-bucketed histograms, exposition.

Thread-safe, dependency-free observability for the micro-batching engine
(DESIGN.md §8).  The engine records queue depth, batch occupancy, padded
bases (the waste length bucketing removes), result-cache hits, and
end-to-end latency; `render()` emits a Prometheus-style text page and
`snapshot()` a plain dict for JSON perf logs (benchmarks/serve_engine.py).

Graph-workload flushes additionally record the tile pre-filter's
effectiveness, forwarded from the executor's ``last_stats``:
``graph_candidate_slots`` (dense candidate slots offered),
``graph_tiles_live`` (slots with seed votes), ``graph_tiles_kept`` /
``graph_tiles_pruned`` (q-gram screen verdicts), ``graph_dc_rows`` vs
``graph_dc_rows_dense`` (BitAlign-DC rows actually launched at the
chosen tile-count rung vs the dense [B·C] launch it replaced), and
``graph_reads_zero_survivor`` (reads short-circuited to the unmapped
result without any DC/align work).
"""
from __future__ import annotations

import bisect
import threading


class Counter:
    """Monotonic counter (float increments allowed, e.g. padded bases)."""

    def __init__(self) -> None:
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Gauge:
    """Last-write-wins instantaneous value (e.g. queue depth)."""

    def __init__(self) -> None:
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._v = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Histogram:
    """Log-spaced bucket histogram with interpolated quantiles.

    Buckets span ``[lo, hi]`` multiplicatively (default 1 µs .. 100 s for
    latencies); observations are clamped into range, so quantiles stay
    defined even for outliers.  Quantile estimates interpolate within the
    winning bucket — coarse but monotone, and plenty for p50/p99 serving
    dashboards.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 100.0,
                 n_buckets: int = 64) -> None:
        self._lo, self._hi = float(lo), float(hi)
        self._bounds = [
            lo * (hi / lo) ** (i / (n_buckets - 1)) for i in range(n_buckets)
        ]
        self._counts = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        x = min(max(float(v), self._lo), self._hi)
        # first bucket whose upper bound holds x: bucket j covers
        # (bounds[j-1], bounds[j]], so an observation landing exactly on
        # a bound belongs to that bound's bucket — bisect_left is exact
        # where the old log-space arithmetic could round across the edge
        j = min(bisect.bisect_left(self._bounds, x), len(self._bounds) - 1)
        with self._lock:
            self._counts[j] += 1
            self.count += 1
            self.sum += float(v)

    def _quantile_locked(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for j, c in enumerate(self._counts):
            if c and seen + c >= target:
                lo = self._bounds[j - 1] if j else self._lo
                frac = (target - seen) / c
                return lo + frac * (self._bounds[j] - lo)
            seen += c
        return self._bounds[-1]

    def quantile(self, q: float) -> float:
        with self._lock:
            return self._quantile_locked(q)

    def stats(self) -> dict:
        """count/sum/mean/p50/p99 read under one lock acquisition —
        a torn read of (count, sum) mid-``observe`` cannot happen."""
        with self._lock:
            count, total = self.count, self.sum
            p50 = self._quantile_locked(0.50)
            p99 = self._quantile_locked(0.99)
        return {"count": count, "sum": total,
                "mean": total / count if count else 0.0,
                "p50": p50, "p99": p99}

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0


class Metrics:
    """Named-instrument registry shared by engine, cache, and session."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, **kw) -> Histogram:
        with self._lock:
            if name not in self._hists:
                self._hists[name] = Histogram(**kw)
            return self._hists[name]

    def snapshot(self) -> dict:
        """Flat dict of every instrument (histograms → count/mean/p50/p99)."""
        with self._lock:  # registries may grow mid-scrape (lazy instruments)
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        out: dict[str, float] = {}
        for n, c in counters.items():
            out[n] = c.value
        for n, g in gauges.items():
            out[n] = g.value
        for n, h in hists.items():
            st = h.stats()  # count/sum/quantiles under the histogram's lock
            out[f"{n}_count"] = st["count"]
            out[f"{n}_mean"] = st["mean"]
            out[f"{n}_p50"] = st["p50"]
            out[f"{n}_p99"] = st["p99"]
        return out

    def render(self) -> str:
        """Prometheus-style text exposition (one ``name value`` per line)."""
        lines = []
        for n, v in sorted(self.snapshot().items()):
            lines.append(f"{n} {v:.6g}")
        return "\n".join(lines) + "\n"
