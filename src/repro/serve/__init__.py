"""repro.serve — async micro-batching engine for online read-mapping.

DESIGN.md §8: length-bucketed admission (`engine`), result caching keyed
on (read digest, index epoch) (`cache`), counters/histograms with text
exposition (`metrics`), and the client session + Poisson load generator
(`session`).
"""
from .cache import ResultCache
from .engine import EngineConfig, ServeEngine, ServeResult
from .metrics import Metrics
from .session import LoadReport, Session, poisson_load

__all__ = [
    "EngineConfig", "ServeEngine", "ServeResult", "ResultCache", "Metrics",
    "LoadReport", "Session", "poisson_load",
]
