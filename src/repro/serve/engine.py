"""Async micro-batching engine for online read-mapping (DESIGN.md §8).

Reads arrive continuously via ``submit() -> Future``; the engine admits
them into per-bucket queues and a background worker flushes a bucket when
it reaches ``max_batch`` *or* its oldest read has waited ``max_delay_s``
(the classic throughput/latency micro-batching tradeoff).

Two wastes of the offline driver are removed here:

* **Padding waste** — instead of padding every read to one global cap,
  reads are routed to the smallest rung of a *length-bucket ladder*
  (default 160/320/640/1280) that holds them, so a 150 bp Illumina read
  stops paying 1280-cap long-read padding.  `metrics` tracks the padded
  bases actually paid per bucket (benchmarks/serve_engine.py quantifies
  the win vs single-cap batching).
* **Recompile waste** — `mapper.map_batch` is shape-specialized, so each
  ``(bucket_cap, align_backend, config)`` triple jits exactly once into
  an *executor cache*; partial flushes are padded up to ``max_batch``
  rows to keep one trace per bucket (``trace_counts`` makes this
  assertable in tests).  Alignment inside the executor flows through
  `repro.align.align_batch`, so the engine serves any registered
  backend (``lax``, ``pallas_dc``, ``pallas_dc_v2``, …) unchanged.

Results are memoized in an LRU keyed on ``(read digest, index epoch
token)`` (`cache.py`) — a scalar epoch for single-device indexes, the
``(layout, epoch vector)`` token for sharded ones; refreshing the
reference bumps it and invalidates the lot.  The engine is
mode-agnostic: the offline WorkQueue path and the online Poisson path
in `launch/serve_genomics.py` both sit on the same
``submit()``/``drain()`` surface, which is what makes their PAF outputs
bit-identical.  With ``num_shards > 1`` the bucket executors become
`repro.shard` scatter/merge/align pipelines (DESIGN.md §11) with
byte-identical output.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Sequence

import numpy as np

from repro.core import mapper
from repro.core.genasm import GenASMConfig
from repro.core.minimizer_index import EpochedIndex, ReferenceIndex
from repro.genomics import encode
from repro.obs.trace import NULL_TRACER, Tracer

from .cache import ResultCache, read_digest
from .metrics import Metrics


@dataclass(frozen=True)
class EngineConfig:
    """Micro-batcher policy + the static half of the mapper signature.

    ``buckets`` are pattern caps (must be multiples of 32 for the
    bitvector layout, DESIGN.md §7); reads longer than the top rung are
    trimmed to it, matching `encode.batch_reads`.  ``filter_bits`` is
    clamped per bucket to the bucket cap so narrow buckets stay legal.
    ``align_backend`` names a `repro.align` registry entry ("auto"
    resolves per platform at engine construction); it is part of the
    executor-cache key, so switching backends never reuses a stale
    compiled executor.

    ``workload`` selects what a bucket executor compiles: ``"linear"``
    (`core/mapper.map_batch` against an `EpochedIndex`) or ``"graph"``
    (`repro.graph.mapper.map_batch` against an `EpochedGraphIndex`,
    results carrying the node path for GAF).  It is part of the
    executor-cache key; linear backend names resolve to their graph
    twins under the graph workload (``lax`` → ``graph_lax``, …).

    ``num_shards > 1`` serves through `repro.shard`: the engine wraps
    the index into its epoch-vector-stamped sharded form, bucket
    executors become scatter/merge/align pipelines (``shard_map`` over
    a shard mesh when enough devices exist, stacked ``vmap``
    otherwise), and the result cache keys on the (layout, epoch
    vector) token instead of a scalar epoch.  ``shard_candidates`` is
    each shard's per-read candidate budget (None = ``max_candidates``,
    the identity-preserving default; throughput deployments set
    ``max_candidates // num_shards`` to strong-scale the filter).
    PAF/GAF output is byte-identical to ``num_shards=1`` as long as the
    single-device winner ranks within ``shard_candidates`` by votes in
    its owning shard — automatic for real reads at the default budget;
    see the `repro.shard.mapper` caveat before shrinking it on highly
    repetitive references.

    ``align_sharded`` (sharded serving only) splits the winning-window
    align stage over the same shard mesh as the scatter stage;
    ``pipelined`` dispatches each flush through the executors'
    non-blocking ``start``/``finish`` surface and overlaps batch *i*'s
    align with batch *i+1*'s scatter (double buffering, one batch in
    flight).  Both are bitwise-neutral on output and part of the
    executor-cache key.
    """

    buckets: tuple[int, ...] = (160, 320, 640, 1280)
    max_batch: int = 32
    max_delay_s: float = 0.005
    genasm: GenASMConfig = GenASMConfig()
    align_backend: str = "auto"
    workload: str = "linear"
    filter_bits: int = 128
    filter_k: int = 12
    max_candidates: int = 4
    num_shards: int = 1
    shard_candidates: int | None = None  # None = max_candidates per shard
    # defaults match build_reference_index/build_epoched_index and
    # mapper.map_batch, so all-defaults construction is consistent
    minimizer_w: int = 10
    minimizer_k: int = 15
    cache_capacity: int = 4096  # 0 disables the result cache
    # graph workload: q-gram tile screen before the BitAlign-DC filter
    # (bitwise-neutral on output; off only for A/B measurement)
    graph_prefilter: bool = True
    # sharded serving: mesh-split align stage / double-buffered flushes
    align_sharded: bool = False
    pipelined: bool = False

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("need at least one bucket cap")
        if any(c % 32 or c <= 0 for c in self.buckets):
            raise ValueError(f"bucket caps must be positive multiples of 32, "
                             f"got {self.buckets}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.workload not in ("linear", "graph"):
            raise ValueError(f"workload must be 'linear' or 'graph', got "
                             f"{self.workload!r}")
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got "
                             f"{self.num_shards}")
        if self.shard_candidates is not None and self.shard_candidates < 1:
            raise ValueError(f"shard_candidates must be >= 1, got "
                             f"{self.shard_candidates}")
        if (self.align_sharded or self.pipelined) and self.num_shards < 2:
            raise ValueError(
                "align_sharded/pipelined serve through the repro.shard "
                "executors; they need num_shards > 1")
        object.__setattr__(self, "buckets", tuple(sorted(set(self.buckets))))

    def bucket_for(self, length: int) -> int:
        """Smallest rung holding ``length`` (top rung trims longer reads)."""
        for cap in self.buckets:
            if length <= cap:
                return cap
        return self.buckets[-1]


class ServeResult(NamedTuple):
    """Per-read mapping outcome delivered through the submit() future."""

    position: int  # reference start (-1 if unmapped)
    distance: int  # edit distance (-1 if unmapped)
    ops: np.ndarray  # packed CIGAR ops
    n_ops: int
    read_len: int
    bucket_cap: int
    cached: bool
    latency_s: float
    path: np.ndarray | None = None  # graph workload: node ids per op (-1=I)


@dataclass
class _Request:
    read: np.ndarray
    length: int
    bucket: int
    future: Future
    digest: bytes | None = None  # computed once in submit(), reused by put()
    t_submit: float = field(default_factory=time.monotonic)


class _PendingFlush(NamedTuple):
    """One dispatched-but-unmaterialized flush (pipelined mode)."""

    cap: int
    reqs: list
    fn: object  # the sharded executor that dispatched it
    pending: object  # its shard.PendingBatch
    epoch: object
    lens: np.ndarray
    t_flush: float


class ServeEngine:
    """Admission queue + per-bucket micro-batcher over `mapper.map_batch`."""

    def __init__(self, index,
                 config: EngineConfig = EngineConfig(),
                 metrics: Metrics | None = None,
                 tracer: Tracer | None = None,
                 roofline=None,
                 clock=time.monotonic):
        self.config = config
        # NULL_TRACER's span()/add()/event() are near-free no-ops, so the
        # untraced hot path stays untaxed (ISSUE: <3% overhead traced)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # optional repro.obs.roofline.RooflineManager: per-flush analytic
        # kernel counters keyed by this engine's align dispatch sites
        self.roofline = roofline
        # every deadline/latency decision reads this clock, so tests can
        # inject a fake monotonic clock and assert flush policy without
        # real sleeps (the worker still polls it every <=50 ms of real
        # time while reads wait)
        self._clock = clock

        def check_minimizer(kw):
            if (kw["w"], kw["k"]) != (config.minimizer_w, config.minimizer_k):
                raise ValueError(
                    f"index built with minimizer w={kw['w']}/k={kw['k']} but "
                    f"engine seeds with w={config.minimizer_w}/"
                    f"k={config.minimizer_k}; hashes would never match")

        if config.workload == "graph":
            from repro.graph.index import EpochedGraphIndex, GraphIndex

            if isinstance(index, GraphIndex):
                index = EpochedGraphIndex(index)
            elif not isinstance(index, EpochedGraphIndex) and not (
                    config.num_shards > 1 and self._is_sharded_graph(index)):
                raise TypeError(
                    f"graph workload needs a GraphIndex/EpochedGraphIndex, "
                    f"got {type(index).__name__}")
            if isinstance(index, EpochedGraphIndex):
                check_minimizer(index._build_kw)
            if config.num_shards > 1:
                index = self._shard_graph_index(index)
        elif config.num_shards > 1:
            index = self._shard_linear_index(index, check_minimizer)
        elif not isinstance(index, EpochedIndex):
            # a bare ReferenceIndex carries no build params, so the engine
            # assumes it was built with config.minimizer_w/k (prefer
            # build_epoched_index, which records the actual params and is
            # validated below); the wrap keeps refresh() consistent
            index = EpochedIndex(index, w=config.minimizer_w,
                                 k=config.minimizer_k)
        else:
            check_minimizer(index._build_kw)
        self.index = index
        # resolve "auto" once: the executor-cache key and every flush use
        # the same concrete backend for the engine's whole lifetime
        from repro import align as align_dispatch

        if config.workload == "graph":
            from repro.graph.mapper import graph_backend_name

            self.align_backend = graph_backend_name(config.align_backend)
        else:
            self.align_backend = align_dispatch.resolve_backend(
                config.align_backend).name
        self.metrics = metrics or Metrics()
        self.cache = ResultCache(config.cache_capacity)
        self._queues: dict[int, list[_Request]] = {c: [] for c in config.buckets}
        self._executors: dict[tuple, object] = {}
        self.trace_counts: dict[int, int] = {}
        self._cv = threading.Condition()
        self._inflight = 0
        self._pending: _PendingFlush | None = None  # pipelined: one in flight
        self._closed = False
        self._error: BaseException | None = None
        self._worker = threading.Thread(
            target=self._run, name="serve-engine", daemon=True)
        self._worker.start()

    # ------------------------------------------------------------ sharding --
    def _shard_halo(self) -> int:
        """Smallest halo covering every bucket's mapping geometry."""
        from repro import shard

        c = self.config
        cap = max(c.buckets)
        return max(shard.DEFAULT_HALO, shard.required_halo(
            p_cap=cap, filter_bits=min(c.filter_bits, cap),
            filter_k=c.filter_k, t_cap=cap + 2 * c.genasm.w))

    @staticmethod
    def _is_sharded_graph(index) -> bool:
        from repro.shard import EpochedShardedGraphIndex, ShardedGraphIndex

        return isinstance(index, (EpochedShardedGraphIndex,
                                  ShardedGraphIndex))

    def _shard_linear_index(self, index, check_minimizer):
        """Wrap/convert a linear index for ``num_shards > 1`` serving."""
        from repro import shard

        c = self.config
        if isinstance(index, shard.EpochedShardedIndex):
            esi = index
        elif isinstance(index, shard.ShardedIndex):
            raise TypeError(
                "sharded serving needs an EpochedShardedIndex (it carries "
                "the host reference for failover re-materialization); got "
                "a bare ShardedIndex — build via shard.from_epoched")
        else:
            if isinstance(index, EpochedIndex):
                check_minimizer(index._build_kw)
            index_or_epi = index if isinstance(index, EpochedIndex) else \
                EpochedIndex(index, w=c.minimizer_w, k=c.minimizer_k)
            esi = shard.from_epoched(index_or_epi, c.num_shards,
                                     halo=self._shard_halo())
        if esi.index.num_shards != c.num_shards:
            raise ValueError(
                f"index sharded {esi.index.num_shards} ways but config "
                f"asks for num_shards={c.num_shards}")
        if (esi.index.minimizer_w, esi.index.minimizer_k) != \
                (c.minimizer_w, c.minimizer_k):
            raise ValueError(
                f"sharded index built with minimizer "
                f"w={esi.index.minimizer_w}/k={esi.index.minimizer_k} but "
                f"engine seeds with w={c.minimizer_w}/k={c.minimizer_k}")
        return esi

    def _shard_graph_index(self, index):
        """Wrap/convert a graph index for ``num_shards > 1`` serving."""
        from repro import shard
        from repro.graph.index import EpochedGraphIndex

        c = self.config
        if isinstance(index, shard.EpochedShardedGraphIndex):
            esi = index
        elif isinstance(index, shard.ShardedGraphIndex):
            raise TypeError(
                "sharded graph serving needs an EpochedShardedGraphIndex "
                "— build via shard.from_epoched_graph")
        else:
            assert isinstance(index, EpochedGraphIndex)
            esi = shard.from_epoched_graph(index, c.num_shards,
                                           halo=self._shard_halo())
        if esi.index.num_shards != c.num_shards:
            raise ValueError(
                f"index sharded {esi.index.num_shards} ways but config "
                f"asks for num_shards={c.num_shards}")
        if (esi.index.minimizer_w, esi.index.minimizer_k) != \
                (c.minimizer_w, c.minimizer_k):
            raise ValueError(
                f"sharded graph index built with minimizer "
                f"w={esi.index.minimizer_w}/k={esi.index.minimizer_k} but "
                f"engine seeds with w={c.minimizer_w}/k={c.minimizer_k}")
        return esi

    # ----------------------------------------------------------- client API --
    def submit(self, read: np.ndarray) -> Future:
        """Admit one read; the future resolves to a ``ServeResult``."""
        read = np.ascontiguousarray(read, dtype=np.int8)
        fut: Future = Future()
        t0 = self._clock()
        with self._cv:  # a dead engine answers nothing, not even cache hits
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._error is not None:
                raise RuntimeError("engine worker died") from self._error
        _, epoch = self.index.current()
        # hit/miss accounting lives in the cache itself (cache.hit_rate),
        # not duplicated into Metrics
        digest = read_digest(read) if self.cache.capacity else None
        hit = self.cache.get(read, epoch, digest=digest)
        self.metrics.counter("reads_submitted").inc()
        if hit is not None:
            fut.set_result(hit._replace(
                cached=True, ops=hit.ops.copy(),  # callers own their arrays
                path=None if hit.path is None else hit.path.copy(),
                latency_s=self._clock() - t0))
            return fut
        req = _Request(read=read, length=len(read),
                       bucket=self.config.bucket_for(len(read)), future=fut,
                       digest=digest, t_submit=t0)
        with self._cv:
            # re-checked under the enqueue lock: a request can never land
            # after the worker has observed "closed and empty" and left
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._error is not None:
                raise RuntimeError("engine worker died") from self._error
            self._queues[req.bucket].append(req)
            self._inflight += 1
            self.metrics.gauge("queue_depth").set(
                sum(len(q) for q in self._queues.values()))
            self._cv.notify_all()  # the worker may not be the FIFO waiter
        if self.tracer.enabled:
            self.tracer.event("submit", bucket=req.bucket,
                              length=req.length)
        return fut

    def map_all(self, reads: Sequence[np.ndarray]) -> list[ServeResult]:
        """Submit a read list and gather results in submission order."""
        futs = [self.submit(r) for r in reads]
        return [f.result() for f in futs]

    def drain(self, timeout: float | None = None) -> None:
        """Block until every admitted read has a result."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cv:
            while self._inflight > 0 and self._error is None:
                wait = (None if deadline is None
                        else max(deadline - self._clock(), 0.0))
                if wait == 0.0:
                    raise TimeoutError(
                        f"drain timed out with {self._inflight} in flight")
                self._cv.wait(timeout=0.05 if wait is None else min(wait, 0.05))
        if self._error is not None:
            raise RuntimeError("engine worker died") from self._error

    def close(self) -> None:
        """Drain, then stop the worker (idempotent, even after worker death)."""
        with self._cv:
            if self._closed:
                return
        try:
            self.drain()
        except RuntimeError:
            pass  # worker already dead: nothing left to drain, still shut down
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ----------------------------------------------------- executor cache ----
    def _executor_key(self, cap: int, geom=None) -> tuple:
        c = self.config
        return (cap, c.workload, self.align_backend, c.genasm,
                min(c.filter_bits, cap), c.filter_k, c.max_candidates,
                c.num_shards, c.shard_candidates,
                c.minimizer_w, c.minimizer_k, c.max_batch, geom,
                c.graph_prefilter, c.align_sharded, c.pipelined)

    def _count_trace(self, cap: int, stage=None) -> None:
        """Executor-body hook: runs at trace time only → counts retraces.

        Every executor passes a stage key — linear ``("seed_filter",)``
        / ``("align",)``, sharded ``("scatter",)`` / ``("align",)``,
        graph ``("prefilter",)``, ``(n_cap,)`` per tile-count rung, and
        ``("align",)`` — counted as ``(cap, *stage)``, so the engine's
        (read-length rung, tile-count rung) bucket ladder is assertable
        as one trace per pair."""
        key = cap if stage is None else (cap,) + tuple(stage)
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    def _executor(self, cap: int, geom=None, sharded_index=None):
        """One compiled ``map_batch`` per (bucket_cap, workload, backend,
        config) — built lazily.  ``geom`` is the index geometry *at
        flush time* — the graph index's tile_stride, or a sharded
        index's ``layout_key`` — baked into the compiled closure, so it
        rides in the key: a refresh() that re-tiles the graph (or
        re-partitions the shards) gets a fresh executor instead of
        silently mis-gathering through a stale one.  ``sharded_index``
        is the *same snapshot* ``_execute`` took from ``current()`` —
        re-reading ``self.index`` here would race a concurrent
        ``refresh()`` and bake the new geometry under the old key."""
        key = self._executor_key(cap, geom)
        fn = self._executors.get(key)
        if fn is None:
            c = self.config
            fbits = min(c.filter_bits, cap)
            backend = self.align_backend
            mode = os.environ.get("REPRO_ALIGN_AUTOTUNE")
            if mode in ("1", "model"):
                # tune eagerly before jitting: under the executor's trace
                # align_batch only *consults* the block cache (it cannot
                # time candidates on tracers)
                from repro import align as align_dispatch

                if align_dispatch.get_backend(backend).uses_pallas:
                    if mode == "model":
                        align_dispatch.model_seed(backend, cap, c.genasm.k,
                                                  batch=c.max_batch)
                    else:
                        align_dispatch.autotune(backend, cap, c.genasm.k,
                                                batch=c.max_batch,
                                                cfg=c.genasm)

            n_cand = c.shard_candidates or c.max_candidates
            if c.num_shards > 1 and c.workload == "graph":
                from repro.shard import ShardedGraphMapExecutor

                fn = ShardedGraphMapExecutor(
                    sharded_index, cfg=c.genasm, p_cap=cap,
                    filter_bits=fbits, filter_k=c.filter_k,
                    shard_candidates=n_cand, backend=backend,
                    prefilter=c.graph_prefilter,
                    align_sharded=c.align_sharded,
                    trace_hook=partial(self._count_trace, cap))
            elif c.num_shards > 1:
                from repro.shard import ShardedMapExecutor

                fn = ShardedMapExecutor(
                    sharded_index, cfg=c.genasm, p_cap=cap,
                    filter_bits=fbits, filter_k=c.filter_k,
                    shard_candidates=n_cand, backend=backend,
                    align_sharded=c.align_sharded,
                    trace_hook=partial(self._count_trace, cap))
            elif c.workload == "graph":
                from repro.graph.mapper import GraphMapExecutor

                # host-orchestrated: the executor jits its own stages
                # (one prefilter + align trace per cap, one candidate
                # stage per tile-count rung — the graph bucket ladder)
                fn = GraphMapExecutor(
                    tile_stride=geom, cfg=c.genasm, p_cap=cap,
                    filter_bits=fbits, filter_k=c.filter_k,
                    max_candidates=c.max_candidates,
                    minimizer_w=c.minimizer_w, minimizer_k=c.minimizer_k,
                    backend=backend, prefilter=c.graph_prefilter,
                    trace_hook=partial(self._count_trace, cap))
            else:
                # host-orchestrated two-stage executor: same math as one
                # fused map_batch jit, but the seed_filter/align boundary
                # is observable (last_times) for per-stage attribution
                fn = mapper.LinearMapExecutor(
                    cfg=c.genasm, p_cap=cap, filter_bits=fbits,
                    filter_k=c.filter_k, max_candidates=c.max_candidates,
                    minimizer_w=c.minimizer_w, minimizer_k=c.minimizer_k,
                    backend=backend,
                    trace_hook=partial(self._count_trace, cap))
            self._executors[key] = fn
        return fn

    @property
    def n_executors(self) -> int:
        """Number of compiled bucket executors currently cached."""
        return len(self._executors)

    # ------------------------------------------------------------- worker ----
    def _flush_candidate(self, now: float) -> tuple[int, list[_Request]] | None:
        """Pick a bucket to flush: the most-overdue one, else any full one.

        Deadline beats fullness — sustained traffic keeping one bucket
        full must not starve another bucket's ``max_delay_s`` bound (the
        full bucket flushes on the very next worker cycle anyway).

        Caller holds the lock.  Returns (cap, requests) with the requests
        removed from the queue, or None if no bucket is ready.
        """
        overdue_cap, overdue_age = None, 0.0
        for cap, q in self._queues.items():
            if not q:
                continue
            age = now - q[0].t_submit
            if age >= self.config.max_delay_s and age >= overdue_age:
                overdue_cap, overdue_age = cap, age
        if overdue_cap is None:
            full = [c for c, q in self._queues.items()
                    if len(q) >= self.config.max_batch]
            if not full:
                return None
            overdue_cap = full[0]
        q = self._queues[overdue_cap]
        batch, self._queues[overdue_cap] = q[:self.config.max_batch], \
            q[self.config.max_batch:]
        return overdue_cap, batch

    def _next_deadline(self, now: float) -> float | None:
        ages = [now - q[0].t_submit for q in self._queues.values() if q]
        if not ages:
            return None
        return max(self.config.max_delay_s - max(ages), 0.0)

    def _run(self) -> None:
        picked: tuple[int, list[_Request]] | None = None
        try:
            while True:
                action = "stop"
                with self._cv:
                    while True:
                        if self._closed and not any(self._queues.values()):
                            action = "stop"
                            break
                        now = self._clock()
                        picked = self._flush_candidate(now)
                        if picked is not None:
                            action = "exec"
                            break
                        if self._pending is not None:
                            # idle queue: materialize the in-flight batch
                            # rather than sitting on its futures
                            action = "finish"
                            break
                        wait = self._next_deadline(now)
                        # cap the sleep so an injected fake clock (tests)
                        # is re-polled every <=50 ms of real time
                        self._cv.wait(timeout=0.05 if wait is None
                                      else min(wait, 0.05))
                    self.metrics.gauge("queue_depth").set(
                        sum(len(q) for q in self._queues.values()))
                if action == "stop":
                    self._finish_pending()
                    return
                if action == "finish":
                    self._finish_pending()
                    continue
                cap, reqs = picked  # compute outside the lock
                if self.config.pipelined:
                    self._execute_pipelined(cap, reqs)
                else:
                    self._execute(cap, reqs)
                picked = None
        except BaseException as e:  # noqa: BLE001 — worker must not die silently
            with self._cv:
                self._error = e
                failed = [r for q in self._queues.values() for r in q]
                if picked is not None:  # the batch mid-execute fails too
                    failed += picked[1]
                if self._pending is not None:  # and the dispatched one
                    failed += self._pending.reqs
                    self._pending = None
                for q in self._queues.values():
                    q.clear()
                for r in failed:
                    if not r.future.done():
                        r.future.set_exception(e)
                self._inflight = 0
                self._cv.notify_all()

    def _execute_pipelined(self, cap: int, reqs: list[_Request]) -> None:
        """Dispatch a flush without materializing it; finish the previous.

        Double buffering, one batch deep: batch *i+1*'s encode + scatter
        + device merge dispatch overlaps batch *i*'s still-running align
        (the executors' ``start`` surface never syncs between stages).
        """
        prev, self._pending = self._pending, None
        c = self.config
        try:
            t_flush = self._clock()
            index, epoch = self.index.current()
            fn = self._executor(cap, index.layout_key, sharded_index=index)
            arr, lens = encode.batch_reads(
                [r.read for r in reqs]
                + [np.zeros(0, np.int8)] * (c.max_batch - len(reqs)), cap)
            pending = fn.start(index.arrays, arr, lens, timed=False)
            self._pending = _PendingFlush(cap, reqs, fn, pending, epoch,
                                          lens, t_flush)
        except BaseException:
            self._pending = prev  # the worker handler fails prev too
            raise
        if prev is not None:
            self._finish_flush(prev)

    def _finish_pending(self) -> None:
        prev, self._pending = self._pending, None
        if prev is not None:
            self._finish_flush(prev)

    def _finish_flush(self, state: _PendingFlush) -> None:
        """Materialize a dispatched flush and deliver its results."""
        c, tr = self.config, self.tracer
        cap, reqs = state.cap, state.reqs
        try:
            with tr.span("flush", bucket_cap=cap, batch=len(reqs),
                         workload=c.workload, shards=c.num_shards,
                         pipelined=True):
                if tr.enabled:
                    for r in reqs:
                        tr.add("enqueue_wait", r.t_submit, state.t_flush,
                               bucket_cap=cap, async_=True)
                res, times = state.fn.finish(state.pending)
                state.fn.last_times = list(times)
                for name, t0, t1, attrs in times:
                    tr.add(name, t0, t1, bucket_cap=cap, **attrs)
                self._deliver(cap, reqs, state.epoch, state.lens, res,
                              getattr(state.pending, "stats", None))
        except BaseException as e:
            # this flush's futures die here; the worker handler that
            # re-raises cannot see them anymore (self._pending is clear)
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(e)
            raise
        with self._cv:
            self._inflight -= len(reqs)
            self._cv.notify_all()

    def _deliver(self, cap: int, reqs: list[_Request], epoch, lens, res,
                 stats) -> None:
        """Flush tail shared by both modes: metrics, cache, futures."""
        c, tr, m = self.config, self.tracer, self.metrics
        pos = np.asarray(res.position)
        dist = np.asarray(res.distance)
        ops = np.asarray(res.ops)
        n_ops = np.asarray(res.n_ops)
        paths = np.asarray(res.path) if c.workload == "graph" else None

        m.counter("batches_flushed").inc()
        m.counter(f"batches_flushed_cap{cap}").inc()
        m.histogram("batch_occupancy", lo=1e-3, hi=1.0).observe(
            len(reqs) / c.max_batch)
        real = int(sum(min(r.length, cap) for r in reqs))
        m.counter("bases_useful").inc(real)
        m.counter("bases_padded_read").inc(len(reqs) * cap - real)
        m.counter("bases_padded_slot").inc((c.max_batch - len(reqs)) * cap)
        if stats:  # graph executors: tile-screen / DC-occupancy
            for name, v in stats.items():
                m.counter(f"graph_{name}").inc(int(v))

        with tr.span("emit", bucket_cap=cap):
            done = self._clock()
            results = []
            for i, r in enumerate(reqs):
                out = ServeResult(
                    position=int(pos[i]), distance=int(dist[i]),
                    ops=ops[i].copy(), n_ops=int(n_ops[i]),
                    read_len=int(lens[i]), bucket_cap=cap,
                    cached=False, latency_s=done - r.t_submit,
                    path=None if paths is None else paths[i].copy())
                self.cache.put(r.read, epoch, out, digest=r.digest)
                m.histogram("latency_s").observe(out.latency_s)
                results.append(out)
            # resolve futures before releasing drain(): a drained
            # engine has every result observable, not merely computed
            for r, out in zip(reqs, results):
                r.future.set_result(out)

    def _execute(self, cap: int, reqs: list[_Request]) -> None:
        c = self.config
        tr = self.tracer
        t_flush = self._clock()
        with tr.span("flush", bucket_cap=cap, batch=len(reqs),
                     workload=c.workload, shards=c.num_shards):
            if tr.enabled:
                # queue waits overlap the previous flush's compute, so
                # they export as async spans (outside the slice nesting)
                for r in reqs:
                    tr.add("enqueue_wait", r.t_submit, t_flush,
                           bucket_cap=cap, async_=True)
            index, epoch = self.index.current()
            if c.num_shards > 1:
                payload = index.arrays
                fn = self._executor(cap, index.layout_key,
                                    sharded_index=index)
            elif c.workload == "graph":
                payload = index.arrays
                fn = self._executor(cap, index.tile_stride)
            else:
                payload = index
                fn = self._executor(cap)
            with tr.span("encode", bucket_cap=cap):
                arr, lens = encode.batch_reads(
                    [r.read for r in reqs]
                    + [np.zeros(0, np.int8)] * (c.max_batch - len(reqs)),
                    cap)
            res = fn(payload, arr, lens)
            last_times = getattr(fn, "last_times", ())
            # per-kernel analytic counters: the linear workload's align
            # stage has an exact op/byte model, sharded or not — the
            # mesh split changes the launch layout, not the per-read
            # op/byte totals (graph executors: not modeled yet)
            kc = None
            rf = self.roofline
            if rf is not None and rf.enabled and c.workload == "linear":
                from repro import align as align_dispatch

                align_s = next((t1 - t0 for name, t0, t1, _ in last_times
                                if name in ("align", "align_shard")), None)
                kc = rf.record_flush(
                    self.align_backend, cap, c.genasm.k, c.max_batch,
                    align_s=align_s,
                    block_bt=align_dispatch.block_size_for(
                        self.align_backend, cap, c.genasm.k, c.max_batch))
            # replay the executor's per-stage monotonic windows as child
            # spans of this flush (seed_filter/prefilter/dc_filter/
            # scatter/merge_device/align/align_shard, with compile/
            # dc_rows/shard attrs; the align span carries the analytic
            # counters when modeled)
            for name, t0, t1, attrs in last_times:
                if name in ("align", "align_shard") and kc is not None:
                    attrs = {**attrs, "word_ops": kc.word_ops,
                             "hbm_bytes": kc.hbm_bytes}
                tr.add(name, t0, t1, bucket_cap=cap, **attrs)
            self._deliver(cap, reqs, epoch, lens, res,
                          getattr(fn, "last_stats", None))
        with self._cv:
            self._inflight -= len(reqs)
            self._cv.notify_all()
