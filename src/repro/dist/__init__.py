"""Distribution subsystem: sharding resolution + fault tolerance.

Two layers (DESIGN.md §5):

* ``sharding`` — resolves the models' *logical axis* annotations
  (``repro.models.layers``) into concrete ``PartitionSpec`` trees for an
  arbitrary mesh, and provides the activation-constraint helpers the
  forward passes call at layer boundaries.
* ``fault`` — host-side fault tolerance: the lease-based ``WorkQueue``
  (work stealing for stragglers/failures), the ``Heartbeat`` straggler
  detector, and ``RestartableLoop`` resume-from-checkpoint driving
  ``repro.ckpt.checkpoint``.
"""
from . import fault, sharding  # noqa: F401
