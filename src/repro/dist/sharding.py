"""Logical-axis sharding resolver (DESIGN.md §5).

Every parameter in ``repro.models`` carries a parallel *logical axis*
annotation (the ``*_AXES`` tables next to each ``*_init``); this module
resolves those annotations against a concrete mesh into ``PartitionSpec``
trees.  The mapping is megatron-style tensor parallelism over ``"model"``
(heads / mlp / experts / vocab sharded, ``embed`` dim replicated) with the
batch over the data-parallel axes (``"pod"`` and/or ``"data"``).

The resolver is *shape-driven*: ``_fit`` reconciles a wanted spec against
the actual array shape — it pads for stacked leading axes (parameters are
stacked over blocks by ``jax.vmap``), drops mesh axes that do not exist on
the mesh, refuses to shard a dim the mesh axis does not divide, and never
uses one mesh axis twice.  The same resolver therefore works on the
production 16×16 ``("data", "model")`` pod mesh, the 2×16×16
``("pod", "data", "model")`` multi-pod mesh, the 2×2 debug mesh, and all
the degenerate (1-device, axis-size-1) meshes in between.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.attention import ATTN_AXES
from repro.models.layers import (CONV, EMBED, EXPERT, HEADS, KV_HEADS, MLP,
                                 MLP_AXES, QKV, STATE, VOCAB)
from repro.models.mamba import MAMBA_AXES
from repro.models.moe import MOE_AXES
from repro.models.rwkv6 import RWKV_AXES, RWKV_CM_AXES

# logical axis -> mesh axis it shards over (None = always replicated).
# ``embed`` stays replicated: the paired dim of every matmul is the
# tensor-parallel one, so activations enter/leave TP regions replicated
# over "model" and the all-reduce happens on the output projection.
MESH_RULES: dict[str, str | None] = {
    EMBED: None,
    MLP: "model",
    HEADS: "model",
    KV_HEADS: "model",
    QKV: "model",
    VOCAB: "model",
    EXPERT: "model",
    CONV: None,
    STATE: None,
}

# data-parallel axes in outer-to-inner order (subset present on the mesh
# is used; see launch/mesh.py).
DP_AXES = ("pod", "data")

# module key (pytree path component) -> {param name: logical axes}
_MODULE_AXES: dict[str, dict] = {
    "attn": ATTN_AXES,
    "xattn": ATTN_AXES,
    "mlp": MLP_AXES,
    "moe": MOE_AXES,
    "mamba": MAMBA_AXES,
    "rwkv": RWKV_AXES,
    "cmix": RWKV_CM_AXES,
    "embed": {"tokens": (VOCAB, EMBED)},
    "lm_head": {"w": (EMBED, VOCAB)},
    "frontend_proj": {"w": (None, EMBED)},
}


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def _fit(mesh, shape, want) -> P:
    """Reconcile a wanted spec against an actual shape on a mesh.

    ``want`` is a per-dim tuple of mesh-axis names (a str, a tuple of
    strs, or None).  Rules, in order:

    * shorter ``want`` than rank: pad with None on the *left* (stacked
      leading axes — blocks-stacked params, microbatch dims);
      longer: drop leading entries.
    * a mesh axis that is not on the mesh is ignored;
    * each mesh axis is used at most once across the whole spec;
    * a dim is only sharded if the (product of) axis sizes divides it —
      otherwise the axis is dropped (replicate rather than fail, which is
      what makes 1-device and axis-size-1 meshes degenerate no-ops).
    """
    sizes = _mesh_sizes(mesh)
    shape = tuple(shape)
    want = tuple(want)
    rank = len(shape)
    if len(want) < rank:
        want = (None,) * (rank - len(want)) + want
    elif len(want) > rank:
        want = want[len(want) - rank:]

    used: set[str] = set()
    out = []
    for dim, w in zip(shape, want):
        axes = (w,) if isinstance(w, str) else tuple(w or ())
        kept = []
        prod = 1
        for a in axes:
            if a not in sizes or a in used:
                continue
            if dim % (prod * sizes[a]) != 0:
                continue
            kept.append(a)
            prod *= sizes[a]
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    while out and out[-1] is None:  # canonical short form
        out.pop()
    return P(*out)


def _dp(mesh) -> tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in _mesh_sizes(mesh))


def _logical_to_want(axes) -> tuple:
    return tuple(None if a is None else MESH_RULES.get(a) for a in axes)


def _path_keys(path) -> list[str]:
    return [p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path]


def _param_want(path) -> tuple | None:
    """Logical-axes lookup for one parameter leaf by its pytree path."""
    keys = _path_keys(path)
    for key in reversed(keys[:-1]):
        table = _MODULE_AXES.get(key)
        if table is not None:
            axes = table.get(keys[-1])
            return None if axes is None else _logical_to_want(axes)
    return None  # norms, biases, unknown leaves: replicate


def param_specs(params, mesh):
    """Resolve a params pytree (arrays or ShapeDtypeStructs) to a matching
    tree of ``PartitionSpec``.  Unannotated leaves (norm scales, biases)
    are replicated; annotated leaves shard per ``MESH_RULES``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        want = _param_want(path)
        specs.append(P() if want is None else _fit(mesh, leaf.shape, want))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch, mesh):
    """Input batches shard dim 0 (the global batch) over the data axes."""
    dp = _dp(mesh)
    return jax.tree.map(
        lambda a: _fit(mesh, a.shape, (dp,) + (None,) * (len(a.shape) - 1)),
        batch)


def state_specs(state, mesh):
    """Decode-state trees: batch dim over data axes, KV heads over "model".

    State leaves are stacked over blocks ([n_blocks, B, ...]); the per-slot
    ``pos`` bookkeeping arrays stay replicated.
    """
    dp = _dp(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    specs = []
    for path, leaf in flat:
        name = _path_keys(path)[-1]
        rank = len(leaf.shape)
        if name == "pos" or rank < 3:
            specs.append(P())
        elif name in ("k", "v") and rank == 5:
            # [n_blocks, B, S, Hkv, dh]
            specs.append(_fit(mesh, leaf.shape, (None, dp, None, "model", None)))
        elif name in ("k_scale", "v_scale") and rank == 4:
            specs.append(_fit(mesh, leaf.shape, (None, dp, None, "model")))
        else:  # SSM / conv / WKV states: [n_blocks, B, ...]
            specs.append(_fit(mesh, leaf.shape,
                              (None, dp) + (None,) * (rank - 2)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def constrain_activations(x, mesh, *, seq_axis: bool = False):
    """Constrain a residual-stream activation [B, S, D] at a layer boundary.

    Batch over the data axes; with ``seq_axis`` the *sequence* dim is
    sharded over "model" (sequence parallelism — bounds the remat storage
    of 96-layer models; DESIGN.md §5).  ``mesh=None`` is the unsharded
    CPU/smoke path and is a no-op.
    """
    if mesh is None:
        return x

    dp = _dp(mesh)

    def con(a):
        want = (dp, "model" if seq_axis else None) + (None,) * (len(a.shape) - 2)
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, _fit(mesh, a.shape, want)))

    return jax.tree.map(con, x)


def shard_put(tree, mesh, specs=None):
    """Convenience: ``device_put`` a tree with resolved (or given) specs."""
    specs = param_specs(tree, mesh) if specs is None else specs
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(tree, shardings)


def stacked_specs(tree, mesh, *, axis: str = "shard"):
    """Specs for shard-stacked arrays: dim 0 over ``axis``, rest replicated.

    `repro.shard` stacks every per-shard reference array along a leading
    ``[num_shards, ...]`` axis; this resolves that convention against a
    1-D ``(axis,)`` mesh through the same `_fit` rules as the model
    params (a mesh without the axis, or a leading dim the axis size
    does not divide, degrades to replication instead of failing).
    """
    return jax.tree.map(
        lambda a: _fit(mesh, a.shape, (axis,) + (None,) * (len(a.shape) - 1)),
        tree)


def shard_mesh(num_shards: int, *, axis: str = "shard"):
    """1-D device mesh over the first ``num_shards`` devices, or None.

    Returns None when fewer than ``num_shards`` devices exist (callers
    fall back to a vmapped single-device execution of the same
    program) or when ``num_shards == 1`` (nothing to place).
    """
    import numpy as np

    if num_shards <= 1 or jax.device_count() < num_shards:
        return None
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:num_shards]), (axis,))
