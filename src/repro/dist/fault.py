"""Host-side fault tolerance: leases, heartbeats, restartable loops.

The serving/training drivers treat work as *stateless quanta* (read
batches, train steps between checkpoints), which reduces fault tolerance
to three small host-side pieces (DESIGN.md §5):

* ``WorkQueue`` — lease-based scheduler over ``n`` work items.  A claim
  grants a lease for ``lease_s`` seconds; if the worker neither completes
  nor renews in time, the item becomes claimable again (work *stealing*:
  a straggling or dead worker's item is simply re-issued).  Completion is
  idempotent, so a stolen item finishing twice is harmless — batch
  results are keyed by item id.
* ``Heartbeat`` — flags a straggler when the gap since the previous beat
  exceeds ``factor`` × the trailing-median gap.
* ``RestartableLoop`` — step loop with periodic async checkpoints; on
  (re)entry it resumes from ``CheckpointManager.latest_step()``, so a
  crashed process restarted by the job scheduler loses at most
  ``save_every`` steps.
"""
from __future__ import annotations

import threading
import time
from collections import deque


class WorkQueue:
    """Lease-based work queue over item ids ``0..n_items-1``.

    ``claim()`` hands out an unclaimed item first; when none remain it
    re-issues the *longest-expired* lease (steal ordering: oldest expiry
    first).  Returns None when nothing is claimable right now — either
    every item is done (``finished``) or all outstanding leases are still
    live (caller may retry/back off).  ``lease_s=0`` means leases expire
    immediately: every outstanding item is always stealable, the
    degenerate mode the tests use to exercise reassignment determinism.
    """

    def __init__(self, n_items: int, *, lease_s: float = 300.0):
        if n_items < 0:
            raise ValueError(f"n_items must be >= 0, got {n_items}")
        self.n_items = n_items
        self.lease_s = float(lease_s)
        self._lock = threading.Lock()
        self._pending = deque(range(n_items))  # never-claimed, FIFO
        self._leases: dict[int, float] = {}  # item -> expiry (monotonic)
        self._done: set[int] = set()

    # ------------------------------------------------------------ protocol --
    def claim(self) -> int | None:
        now = time.monotonic()
        with self._lock:
            if self._pending:
                item = self._pending.popleft()
                self._leases[item] = now + self.lease_s
                return item
            expired = sorted(
                (exp, item) for item, exp in self._leases.items() if exp <= now)
            if expired:
                _, item = expired[0]
                self._leases[item] = now + self.lease_s
                return item
            return None

    def renew(self, item: int) -> None:
        """Extend a live lease (long-running worker keep-alive)."""
        with self._lock:
            if item in self._leases:
                self._leases[item] = time.monotonic() + self.lease_s

    def complete(self, item: int) -> None:
        """Mark an item done (idempotent; stolen duplicates are harmless)."""
        with self._lock:
            self._done.add(item)
            self._leases.pop(item, None)

    def fail(self, item: int) -> None:
        """Return a claimed item to the head of the queue immediately."""
        with self._lock:
            if item not in self._done and self._leases.pop(item, None) is not None:
                self._pending.appendleft(item)

    # -------------------------------------------------------------- status --
    @property
    def finished(self) -> bool:
        with self._lock:
            return len(self._done) == self.n_items

    @property
    def outstanding(self) -> int:
        """Items claimed but not yet completed."""
        with self._lock:
            return len(self._leases)

    def __repr__(self) -> str:  # debugging/logs
        with self._lock:
            return (f"WorkQueue(n={self.n_items}, done={len(self._done)}, "
                    f"leased={len(self._leases)}, pending={len(self._pending)})")


class Heartbeat:
    """Straggler detector: ``beat()`` returns True when the gap since the
    previous beat exceeds ``factor`` × the trailing-median gap.

    Call once per step.  The first ``warmup`` intervals only build the
    baseline (never flag) — this absorbs the jit-compile first step.
    """

    def __init__(self, factor: float = 3.0, *, window: int = 64,
                 warmup: int = 5):
        self.factor = float(factor)
        self.warmup = warmup
        self._intervals: deque[float] = deque(maxlen=window)
        self._last: float | None = None
        self.straggler_count = 0

    def beat(self) -> bool:
        now = time.monotonic()
        if self._last is None:
            self._last = now
            return False
        gap = now - self._last
        self._last = now
        slow = False
        if len(self._intervals) >= self.warmup:
            med = sorted(self._intervals)[len(self._intervals) // 2]
            slow = gap > self.factor * max(med, 1e-9)
        if slow:
            self.straggler_count += 1
        else:  # straggler gaps don't poison the baseline
            self._intervals.append(gap)
        return slow


class RestartableLoop:
    """Checkpointed step loop: resume-from-latest on (re)entry.

    ``run(state, step_fn, n_steps)`` restores the latest checkpoint if one
    exists, then runs ``state = step_fn(state, step)`` for the remaining
    steps, saving every ``save_every`` steps (async, double-buffered by
    ``CheckpointManager``) and once more, blocking, at the end.  A crash
    inside ``step_fn`` propagates; the restarted process calls ``run``
    again and loses at most ``save_every`` steps of work.
    """

    def __init__(self, manager, save_every: int = 100):
        if save_every < 1:
            raise ValueError(f"save_every must be >= 1, got {save_every}")
        self.mgr = manager
        self.save_every = save_every

    def run(self, state, step_fn, *, n_steps: int):
        start = self.mgr.latest_step()
        if start is not None:
            state = self.mgr.restore(start, state)
            if start >= n_steps:  # already past the target: don't rewrite
                return state      # checkpoint history with mislabeled state
        else:
            start = 0
        saved = start
        for step in range(start, n_steps):
            state = step_fn(state, step)
            if (step + 1) % self.save_every == 0:
                self.mgr.save(step + 1, state)
                saved = step + 1
        if saved != n_steps:
            self.mgr.save(n_steps, state, blocking=True)
        else:
            self.mgr.wait()  # make the last periodic save durable
        return state
