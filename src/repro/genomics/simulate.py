"""Reference & read simulators with per-technology error profiles.

Mirrors the paper's methodology (§4.9): PBSIM-style long reads (PacBio CLR
~10% error, ONT R9 ~15%) and Mason-style short Illumina reads (~5% in the
paper's datasets).  Error composition follows the cited profiles:
PacBio/ONT are indel-dominated, Illumina substitution-dominated.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ErrorProfile(NamedTuple):
    name: str
    error_rate: float
    frac_sub: float
    frac_ins: float
    frac_del: float


ILLUMINA = ErrorProfile("illumina", 0.05, 0.80, 0.10, 0.10)
PACBIO_CLR = ErrorProfile("pacbio", 0.10, 0.20, 0.45, 0.35)
ONT_R9 = ErrorProfile("ont", 0.15, 0.25, 0.30, 0.45)

PROFILES = {p.name: p for p in (ILLUMINA, PACBIO_CLR, ONT_R9)}


def random_reference(length: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, size=length).astype(np.int8)


def mutate(seq: np.ndarray, profile: ErrorProfile, rng: np.random.Generator
           ) -> np.ndarray:
    """Apply the profile's edits to a sequence."""
    out: list[int] = []
    p_err = profile.error_rate
    for b in seq:
        r = rng.random()
        if r >= p_err:
            out.append(int(b))
            continue
        kind = rng.random()
        if kind < profile.frac_sub:
            out.append(int((b + rng.integers(1, 4)) % 4))
        elif kind < profile.frac_sub + profile.frac_ins:
            out.append(int(rng.integers(0, 4)))
            out.append(int(b))
        # else: deletion — emit nothing
    return np.array(out, np.int8)


class ReadSet(NamedTuple):
    reads: list[np.ndarray]
    true_pos: np.ndarray  # [B] int32 source positions


def simulate_reads(ref: np.ndarray, *, n_reads: int, read_len: int,
                   profile: ErrorProfile = ILLUMINA, seed: int = 0) -> ReadSet:
    rng = np.random.default_rng(seed)
    L = len(ref)
    pos = rng.integers(0, max(L - read_len, 1), size=n_reads).astype(np.int32)
    reads = [mutate(ref[p: p + read_len], profile, rng) for p in pos]
    return ReadSet(reads=reads, true_pos=pos)


def spell_graph_path(graph, start: int, length: int,
                     rng: np.random.Generator) -> np.ndarray:
    """Spell a read along a random successor walk of ``graph`` from
    ``start`` (ground-truth reads for sequence-to-graph tests)."""
    seq: list[int] = []
    cur = int(start)
    while len(seq) < length and cur < graph.n_nodes:
        seq.append(int(graph.bases[cur]))
        bits = int(graph.succ_bits[cur])
        if not bits:
            break
        hops = [h for h in range(32) if (bits >> h) & 1]
        cur = cur + 1 + int(rng.choice(hops))
    return np.array(seq, np.int8)


def simulate_variants(ref: np.ndarray, *, n_snp=10, n_ins=4, n_del=4, seed=0):
    """Variant list for genome-graph construction (spread, non-overlapping)."""
    from repro.core.segram.graph import Variant

    rng = np.random.default_rng(seed)
    L = len(ref)
    n_total = n_snp + n_ins + n_del
    pos = np.sort(rng.choice(np.arange(4, L - 8, 6), size=min(n_total, (L - 12) // 6),
                             replace=False))
    variants = []
    kinds = (["snp"] * n_snp + ["ins"] * n_ins + ["del"] * n_del)[: len(pos)]
    rng.shuffle(kinds)
    for p, kind in zip(pos, kinds):
        if kind == "snp":
            variants.append(Variant(int(p), "snp", (int((ref[p] + 1) % 4),)))
        elif kind == "ins":
            variants.append(Variant(int(p), "ins",
                                    tuple(int(x) for x in rng.integers(0, 4, 2))))
        else:
            variants.append(Variant(int(p), "del", span=2))
    return variants
