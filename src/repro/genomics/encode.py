"""Base encoding, 2-bit packing, and fixed-shape batching."""
from __future__ import annotations

import numpy as np

from repro.core.bitvector import SENTINEL, WILDCARD

_BASE_TO_ID = np.full(256, SENTINEL, np.int8)
for i, b in enumerate(b"ACGT"):
    _BASE_TO_ID[b] = i
    _BASE_TO_ID[ord(chr(b).lower())] = i
_ID_TO_BASE = np.frombuffer(b"ACGTN", dtype=np.uint8)


def encode(seq: bytes | str) -> np.ndarray:
    """ASCII sequence -> int8 ids (non-ACGT -> sentinel)."""
    if isinstance(seq, str):
        seq = seq.encode()
    return _BASE_TO_ID[np.frombuffer(seq, np.uint8)].copy()


def decode(ids: np.ndarray) -> str:
    return _ID_TO_BASE[np.clip(ids, 0, 4)].tobytes().decode()


def pack_2bit(ids: np.ndarray) -> np.ndarray:
    """2-bit pack ACGT ids (the paper's 715 MB GRCh38 representation).

    Non-ACGT collapse to A; keep a separate mask if needed.
    """
    ids = np.clip(ids, 0, 3).astype(np.uint8)
    pad = (-len(ids)) % 16
    ids = np.concatenate([ids, np.zeros(pad, np.uint8)])
    ids = ids.reshape(-1, 16)
    shifts = np.arange(16, dtype=np.uint32) * 2
    return (ids.astype(np.uint32) << shifts).sum(axis=1).astype(np.uint32)


def unpack_2bit(packed: np.ndarray, n: int) -> np.ndarray:
    shifts = np.arange(16, dtype=np.uint32) * 2
    out = ((packed[:, None] >> shifts) & 3).astype(np.int8).reshape(-1)
    return out[:n]


def batch_reads(reads: list[np.ndarray], cap: int, pad_value: int = WILDCARD):
    """Fixed-shape [B, cap] batch + lengths; reads longer than cap are trimmed."""
    b = len(reads)
    out = np.full((b, cap), pad_value, np.int8)
    lens = np.zeros(b, np.int32)
    for i, r in enumerate(reads):
        L = min(len(r), cap)
        out[i, :L] = r[:L]
        lens[i] = L
    return out, lens
