"""Sharded host→device input pipeline for read mapping.

Design for 1000+ nodes (DESIGN.md §5): each host process owns a disjoint
slice of the read stream (process_index striding), builds fixed-shape
batches, and places them as globally-sharded arrays over the ("pod",
"data") axes.  Batches are stateless work quanta: fault tolerance is a
(batch cursor, results offset) checkpoint, and straggler mitigation is
work-stealing over unclaimed batch ids (fault.py).  A double-buffered
prefetch thread overlaps host encode with device compute.

:func:`map_stream` closes the loop: it drives each prefetched batch
through `core/mapper.map_batch`, whose alignment stage dispatches via
`repro.align` — so the offline pipeline runs on any registered backend
(``lax``, ``pallas_dc``, ``pallas_dc_v2``) with one argument.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np

from .encode import batch_reads


class ReadBatches:
    """Deterministic batch iterator over a read list (host shard aware)."""

    def __init__(self, reads, *, batch: int, cap: int, process_index: int = 0,
                 process_count: int = 1, start_batch: int = 0):
        self.reads = reads
        self.batch = batch
        self.cap = cap
        self.pi = process_index
        self.pc = process_count
        self.start_batch = start_batch

    def __iter__(self) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
        n = len(self.reads)
        ids = np.arange(self.pi, n, self.pc)
        n_batches = -(-len(ids) // self.batch)
        for b in range(self.start_batch, n_batches):
            sel = ids[b * self.batch: (b + 1) * self.batch]
            reads = [self.reads[i] for i in sel]
            while len(reads) < self.batch:  # tail padding (masked by lens=0)
                reads.append(np.zeros(0, np.int8))
            arr, lens = batch_reads(reads, self.cap)
            yield b, arr, lens


class Prefetcher:
    """Double-buffered background prefetch (host encode ∥ device compute).

    A worker-thread exception is captured and re-raised in the consumer's
    ``__iter__`` (a silent worker death would otherwise hang or truncate
    the stream).  ``close()`` (or exiting the context manager) stops the
    worker and joins it, even mid-stream with a full queue.
    """

    _DONE = object()  # stream-end sentinel (worker exception rides in _exc)

    def __init__(self, it, device_put=None, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device_put = device_put or jax.device_put
        self._exc: BaseException | None = None
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, args=(it,), daemon=True)
        self._t.start()

    def _put(self, item) -> bool:
        """Bounded put that aborts when close() raises the stop flag."""
        while not self._stop.is_set():
            try:
                self.q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, it):
        try:
            for b, arr, lens in it:
                if not self._put((b, self.device_put(arr),
                                  self.device_put(lens))):
                    return  # closed mid-stream
        except BaseException as e:  # noqa: BLE001 — hand it to the consumer
            self._exc = e
        self._put(self._DONE)

    def __iter__(self):
        while True:
            try:
                item = self.q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():  # closed elsewhere: no sentinel comes
                    return
                continue
            if item is self._DONE:
                if self._exc is not None:
                    raise self._exc
                return
            yield item

    def close(self) -> None:
        """Stop the worker and join it (idempotent; safe mid-stream)."""
        self._stop.set()
        while self._t.is_alive():  # drain so a blocked put can observe stop
            try:
                self.q.get_nowait()
            except queue.Empty:
                pass
            self._t.join(timeout=0.05)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def map_stream(index, batches, *, backend: str | None = None, **map_kw
               ) -> Iterator[tuple[int, object]]:
    """Map every (batch_id, reads, lens) triple; yields (batch_id, MapResult).

    ``batches`` is any iterator in the `ReadBatches`/`Prefetcher` shape.
    ``backend`` names a `repro.align` registry entry (None/"auto" picks
    the platform default); remaining kwargs forward to
    `mapper.map_batch` (p_cap, filter_k, ...).
    """
    from repro.core import mapper

    for b, arr, lens in batches:
        yield b, mapper.map_batch(index, arr, lens, backend=backend, **map_kw)
