"""Sharded host→device input pipeline for read mapping.

Design for 1000+ nodes (DESIGN.md §5): each host process owns a disjoint
slice of the read stream (process_index striding), builds fixed-shape
batches, and places them as globally-sharded arrays over the ("pod",
"data") axes.  Batches are stateless work quanta: fault tolerance is a
(batch cursor, results offset) checkpoint, and straggler mitigation is
work-stealing over unclaimed batch ids (fault.py).  A double-buffered
prefetch thread overlaps host encode with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np

from .encode import batch_reads


class ReadBatches:
    """Deterministic batch iterator over a read list (host shard aware)."""

    def __init__(self, reads, *, batch: int, cap: int, process_index: int = 0,
                 process_count: int = 1, start_batch: int = 0):
        self.reads = reads
        self.batch = batch
        self.cap = cap
        self.pi = process_index
        self.pc = process_count
        self.start_batch = start_batch

    def __iter__(self) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
        n = len(self.reads)
        ids = np.arange(self.pi, n, self.pc)
        n_batches = -(-len(ids) // self.batch)
        for b in range(self.start_batch, n_batches):
            sel = ids[b * self.batch: (b + 1) * self.batch]
            reads = [self.reads[i] for i in sel]
            while len(reads) < self.batch:  # tail padding (masked by lens=0)
                reads.append(np.zeros(0, np.int8))
            arr, lens = batch_reads(reads, self.cap)
            yield b, arr, lens


class Prefetcher:
    """Double-buffered background prefetch (host encode ∥ device compute)."""

    def __init__(self, it, device_put=None, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device_put = device_put or jax.device_put
        self._t = threading.Thread(target=self._run, args=(it,), daemon=True)
        self._t.start()

    def _run(self, it):
        for item in it:
            b, arr, lens = item
            self.q.put((b, self.device_put(arr), self.device_put(lens)))
        self.q.put(None)

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            yield item
