"""FASTA/FASTQ parsing and writing (host side, numpy)."""
from __future__ import annotations

from pathlib import Path
from typing import Iterator, NamedTuple

import numpy as np

from .encode import decode, encode


class Record(NamedTuple):
    name: str
    seq: np.ndarray  # int8 base ids
    qual: str | None = None


def read_fasta(path: str | Path) -> Iterator[Record]:
    name, chunks = None, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                if name is not None:
                    yield Record(name, encode("".join(chunks)))
                name, chunks = line[1:].split()[0], []
            else:
                chunks.append(line)
    if name is not None:
        yield Record(name, encode("".join(chunks)))


def write_fasta(path: str | Path, records: list[Record], width: int = 80) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(f">{r.name}\n")
            s = decode(r.seq)
            for i in range(0, len(s), width):
                f.write(s[i: i + width] + "\n")


def read_fastq(path: str | Path) -> Iterator[Record]:
    with open(path) as f:
        while True:
            header = f.readline().strip()
            if not header:
                return
            seq = f.readline().strip()
            f.readline()
            qual = f.readline().strip()
            yield Record(header[1:].split()[0], encode(seq), qual)


def write_fastq(path: str | Path, records: list[Record]) -> None:
    with open(path, "w") as f:
        for r in records:
            q = r.qual or "I" * len(r.seq)
            f.write(f"@{r.name}\n{decode(r.seq)}\n+\n{q}\n")


CIGAR_CHARS = "MXID"


def cigar_string(ops: np.ndarray, n_ops: int) -> str:
    """Packed ops -> run-length CIGAR text (M/X/I/D)."""
    out = []
    run_op, run_len = None, 0
    for s in range(int(n_ops)):
        op = int(ops[s])
        if op == run_op:
            run_len += 1
        else:
            if run_op is not None:
                out.append(f"{run_len}{CIGAR_CHARS[run_op]}")
            run_op, run_len = op, 1
    if run_op is not None:
        out.append(f"{run_len}{CIGAR_CHARS[run_op]}")
    return "".join(out)


def _write_rows(path: str | Path, rows: list[dict],
                columns: tuple[str, ...]) -> None:
    """Shared PAF/GAF row formatter: tab columns, ``*`` defaults, cg tag."""
    with open(path, "w") as f:
        for r in rows:
            f.write(
                "\t".join(str(r.get(k, "*")) for k in columns)
                + (f"\tcg:Z:{r['cigar']}" if "cigar" in r else "")
                + "\n"
            )


def write_paf(path: str | Path, rows: list[dict]) -> None:
    """Minimal PAF writer (the paper's Minimap output format)."""
    _write_rows(path, rows, ("qname", "qlen", "qstart", "qend", "strand",
                             "tname", "tlen", "tstart", "tend", "nmatch",
                             "alnlen", "mapq"))


def gaf_path(nodes) -> tuple[str, int]:
    """Node-id walk -> (GAF path string, path length in nodes).

    The one-base-per-node graphs name a maximal run of consecutive node
    ids as one forward-oriented segment ``s<first>-<last>`` (a hop edge
    starts a new segment), so ``>s5-40>s44-61`` reads as "nodes 5..40,
    hop, nodes 44..61".  Unmapped/empty paths return ``("*", 0)``.
    """
    ids = [int(x) for x in nodes if int(x) >= 0]
    if not ids:
        return "*", 0
    segs = []
    run_start = prev = ids[0]
    for x in ids[1:]:
        if x != prev + 1:
            segs.append((run_start, prev))
            run_start = x
        prev = x
    segs.append((run_start, prev))
    return "".join(f">s{a}-{b}" for a, b in segs), len(ids)


def write_gaf(path: str | Path, rows: list[dict]) -> None:
    """Minimal GAF writer (graph alignment format, the SeGraM output).

    Columns: qname qlen qstart qend strand path plen pstart pend nmatch
    alnlen mapq, plus a ``cg:Z:`` CIGAR tag when present.  Keys outside
    the column list are ignored, mirroring `write_paf`.
    """
    _write_rows(path, rows, ("qname", "qlen", "qstart", "qend", "strand",
                             "path", "plen", "pstart", "pend", "nmatch",
                             "alnlen", "mapq"))
