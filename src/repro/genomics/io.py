"""FASTA/FASTQ parsing and writing (host side, numpy)."""
from __future__ import annotations

from pathlib import Path
from typing import Iterator, NamedTuple

import numpy as np

from .encode import decode, encode


class Record(NamedTuple):
    name: str
    seq: np.ndarray  # int8 base ids
    qual: str | None = None


def read_fasta(path: str | Path) -> Iterator[Record]:
    name, chunks = None, []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                if name is not None:
                    yield Record(name, encode("".join(chunks)))
                name, chunks = line[1:].split()[0], []
            else:
                chunks.append(line)
    if name is not None:
        yield Record(name, encode("".join(chunks)))


def write_fasta(path: str | Path, records: list[Record], width: int = 80) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(f">{r.name}\n")
            s = decode(r.seq)
            for i in range(0, len(s), width):
                f.write(s[i: i + width] + "\n")


def read_fastq(path: str | Path) -> Iterator[Record]:
    with open(path) as f:
        while True:
            header = f.readline().strip()
            if not header:
                return
            seq = f.readline().strip()
            f.readline()
            qual = f.readline().strip()
            yield Record(header[1:].split()[0], encode(seq), qual)


def write_fastq(path: str | Path, records: list[Record]) -> None:
    with open(path, "w") as f:
        for r in records:
            q = r.qual or "I" * len(r.seq)
            f.write(f"@{r.name}\n{decode(r.seq)}\n+\n{q}\n")


CIGAR_CHARS = "MXID"


def cigar_string(ops: np.ndarray, n_ops: int) -> str:
    """Packed ops -> run-length CIGAR text (M/X/I/D)."""
    out = []
    run_op, run_len = None, 0
    for s in range(int(n_ops)):
        op = int(ops[s])
        if op == run_op:
            run_len += 1
        else:
            if run_op is not None:
                out.append(f"{run_len}{CIGAR_CHARS[run_op]}")
            run_op, run_len = op, 1
    if run_op is not None:
        out.append(f"{run_len}{CIGAR_CHARS[run_op]}")
    return "".join(out)


def write_paf(path: str | Path, rows: list[dict]) -> None:
    """Minimal PAF writer (the paper's Minimap output format)."""
    with open(path, "w") as f:
        for r in rows:
            f.write(
                "\t".join(
                    str(r.get(k, "*"))
                    for k in ("qname", "qlen", "qstart", "qend", "strand",
                              "tname", "tlen", "tstart", "tend", "nmatch",
                              "alnlen", "mapq")
                )
                + (f"\tcg:Z:{r['cigar']}" if "cigar" in r else "")
                + "\n"
            )
