"""Pallas TPU kernel for BitAlign sequence-to-graph DC (paper §6.8.2).

Same lane strategy as the GenASM-DC kernels: one (read × subgraph window)
alignment per VPU lane, word-major bitvectors, sequential reverse-
topological node scan with the hop-queue ring buffer carried in registers
(the BitAlign PE's hopBits queue, Figure 6-8).  Emits per-node status rows
(R-only storage, the §Perf #8 scheme generalized to graphs) and the
per-node match distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitvector import NUM_CHARS, WORD_BITS
from repro.core.segram.graph import HOP_LIMIT

from .genasm_dc import _pm_table, _shl1_wm


def _tail_mask_wm(p_lens: jnp.ndarray, m_bits: int, nw: int) -> jnp.ndarray:
    """[nw, BT] uint32 tail masks (low (m_bits - p_len) bits cleared)."""
    pad = (m_bits - p_lens).astype(jnp.int32)  # [BT]
    out = []
    for wd in range(nw):
        bits_below = jnp.clip(pad - 32 * wd, 0, 32)
        low = jnp.where(
            bits_below >= 32,
            jnp.uint32(0xFFFFFFFF),
            (jnp.uint32(1) << bits_below.astype(jnp.uint32)) - jnp.uint32(1),
        )
        out.append(~low)
    return jnp.stack(out)  # [nw, BT]


def _bitalign_kernel(bases_ref, succ_ref, pattern_ref, plen_ref, dists_ref,
                     r_ref, *, n: int, m_bits: int, k: int, nw: int):
    bt = bases_ref.shape[0]
    pm = _pm_table(pattern_ref[...], m_bits, nw)  # [5, nw, BT]
    tail = _tail_mask_wm(plen_ref[...], m_bits, nw)  # [nw, BT]
    tail_rows = jnp.broadcast_to(tail, (k + 1, nw, bt))
    H = HOP_LIMIT

    def step(s, hist):
        # hist: [H, k+1, nw, BT]; hist[h] = R of node i+1+h
        i = n - 1 - s
        sb = succ_ref[:, i]  # [BT] uint32 hopBits
        comb = tail_rows
        for h in range(H):
            hop_ok = ((sb >> jnp.uint32(h)) & 1).astype(bool)  # [BT]
            comb = comb & jnp.where(hop_ok[None, None, :], hist[h], tail_rows)
        # wait: AND with tail_rows when hop off is identity only if comb
        # already ≤ tail; tail_rows has tail bits 0 → keeps invariant.
        c = bases_ref[:, i].astype(jnp.int32)
        cur_pm = jnp.zeros((nw, bt), jnp.uint32)
        for ch in range(NUM_CHARS):
            cur_pm = jnp.where((c == ch)[None, :], pm[ch], cur_pm)
        R0 = _shl1_wm(comb[0]) | cur_pm
        rows = [R0 & tail]
        for d in range(1, k + 1):
            D = comb[d - 1]
            S = _shl1_wm(comb[d - 1])
            I = _shl1_wm(rows[d - 1])
            M = _shl1_wm(comb[d]) | cur_pm
            rows.append(D & S & I & M & tail)
        R = jnp.stack(rows)  # [k+1, nw, BT]
        r_ref[:, i] = R.transpose(2, 0, 1)
        msbs = (R[:, nw - 1, :] >> 31) & 1  # [k+1, BT]
        found = msbs == 0
        d_i = jnp.where(jnp.any(found, axis=0), jnp.argmax(found, axis=0),
                        k + 1).astype(jnp.int32)
        dists_ref[:, i] = d_i
        new_hist = jnp.concatenate([R[None], hist[:-1]], axis=0)
        return new_hist

    hist0 = jnp.broadcast_to(tail_rows, (H, k + 1, nw, bt))
    lax.fori_loop(0, n, step, hist0)


@functools.partial(
    jax.jit, static_argnames=("m_bits", "k", "block_bt", "interpret"))
def bitalign_dc_batch(bases, succ_bits, patterns, p_lens, *, m_bits: int,
                      k: int, block_bt: int = 32, interpret: bool = False):
    """Batched BitAlign DC.

    bases: [B, N] int8; succ_bits: [B, N] uint32; patterns: [B, m_bits]
    int8 wildcard-padded; p_lens: [B] int32.
    Returns (dists [B, N] int32, R [B, N, k+1, nw] uint32).
    """
    nw = m_bits // WORD_BITS
    b, n = bases.shape
    if b % block_bt != 0:
        raise ValueError(f"batch {b} not a multiple of block_bt {block_bt}")
    kernel = functools.partial(_bitalign_kernel, n=n, m_bits=m_bits, k=k, nw=nw)
    return pl.pallas_call(
        kernel,
        grid=(b // block_bt,),
        in_specs=[
            pl.BlockSpec((block_bt, n), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, n), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, m_bits), lambda i: (i, 0)),
            pl.BlockSpec((block_bt,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_bt, n), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, n, k + 1, nw), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n, k + 1, nw), jnp.uint32),
        ],
        interpret=interpret,
    )(bases, succ_bits, patterns, p_lens)
