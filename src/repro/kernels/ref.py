"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import genasm_dc as _dc
from repro.core import myers as _my


@partial(jax.jit, static_argnames=("w", "k"))
def window_dc_batch(sub_texts, sub_patterns, *, w: int = 64, k: int = 24):
    """Reference for kernels.genasm_dc.window_dc_batch (vmapped core impl)."""
    f = partial(_dc.window_dc, w=w, k=k)
    return jax.vmap(f)(sub_texts, sub_patterns)


@partial(jax.jit, static_argnames=("m_bits", "mode"))
def myers_distance_batch(texts, patterns, m_lens, *, m_bits: int, mode: str = "global"):
    """Reference for kernels.myers.myers_distance_batch."""
    f = partial(_my.myers_distance, m_bits=m_bits, mode=mode)
    return jax.vmap(f)(texts, patterns, m_lens)


@partial(jax.jit, static_argnames=("w", "k"))
def window_dc_batch_v2(sub_texts, sub_patterns, *, w: int = 64, k: int = 24):
    """Reference for kernels.genasm_dc_v2 (vmapped core window_dc_r)."""
    f = partial(_dc.window_dc_r, w=w, k=k)
    return jax.vmap(f)(sub_texts, sub_patterns)


@partial(jax.jit, static_argnames=("m_bits", "k"))
def bitalign_dc_batch(bases, succ_bits, patterns, p_lens, *, m_bits: int, k: int):
    """Reference for kernels.bitalign (vmapped core bitalign_dc; R rows only)."""
    from repro.core.segram import bitalign as _ba

    def one(b, s, p, pl_):
        dists, store = _ba.bitalign_dc(b, s, p, pl_, m_bits=m_bits, k=k)
        return dists, store[:, :, 0]  # R rows

    return jax.vmap(one)(bases, succ_bits, patterns, p_lens)
