"""Pallas TPU kernel for batched Myers bit-parallel edit distance.

Same lane strategy as the GenASM-DC kernel: one alignment per VPU lane,
word-major ``[nw, BT]`` bitvectors, sequential over text characters with
Pv/Mv/score carried in registers through a ``fori_loop``.  The multi-word
carry of Myers' additive term is a static unroll over ``nw`` words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitvector import NUM_CHARS, WORD_BITS


def _peq_table(pattern_tile: jnp.ndarray, nw: int) -> jnp.ndarray:
    """[5, nw, BT]: bit j of PEq[c] = 1 iff pattern[j] == c (LSB = pattern[0])."""
    p = pattern_tile.astype(jnp.int32)  # [BT, m_bits]
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out = []
    for c in range(NUM_CHARS):
        m = ((p == c) | (p == 4)).astype(jnp.uint32).reshape(p.shape[0], nw, WORD_BITS)
        out.append(jnp.sum(m * weights[None, None, :], axis=-1, dtype=jnp.uint32).T)
    return jnp.stack(out)


def _add_carry_wm(a: jnp.ndarray, b: jnp.ndarray, nw: int) -> jnp.ndarray:
    """Multi-word add on [nw, BT] word-major vectors (drop final carry)."""
    outs = []
    cin = jnp.zeros(a.shape[-1:], jnp.uint32)
    for wd in range(nw):
        s1 = a[wd] + b[wd]
        c1 = (s1 < a[wd]).astype(jnp.uint32)
        s2 = s1 + cin
        c2 = (s2 < s1).astype(jnp.uint32)
        outs.append(s2)
        cin = c1 | c2
    return jnp.stack(outs)


def _shl1_in_wm(x: jnp.ndarray, bit_in: jnp.ndarray) -> jnp.ndarray:
    carry = x >> 31
    shifted = x << 1
    incoming = jnp.concatenate([bit_in[None, :], carry[:-1]], axis=0)
    return shifted | incoming


def _myers_kernel(text_ref, pattern_ref, mlen_ref, dist_ref, *, n: int, nw: int,
                  mode: str):
    bt = text_ref.shape[0]
    peq = _peq_table(pattern_ref[...], nw)  # [5, nw, BT]
    m_len = mlen_ref[...].astype(jnp.int32)  # [BT]
    score_word = (m_len - 1) // WORD_BITS  # [BT]
    score_off = ((m_len - 1) % WORD_BITS).astype(jnp.uint32)
    cin = (
        jnp.ones((bt,), jnp.uint32) if mode == "global" else jnp.zeros((bt,), jnp.uint32)
    )

    def pick_word(v, wsel):
        out = jnp.zeros((bt,), jnp.uint32)
        for wd in range(nw):
            out = jnp.where(wsel == wd, v[wd], out)
        return out

    def step(j, state):
        Pv, Mv, score, best = state
        c = text_ref[:, j].astype(jnp.int32)
        Eq = jnp.zeros((nw, bt), jnp.uint32)
        for ch in range(NUM_CHARS):
            Eq = jnp.where((c == ch)[None, :], peq[ch], Eq)
        Xv = Eq | Mv
        Xh = (_add_carry_wm(Eq & Pv, Pv, nw) ^ Pv) | Eq
        Ph = Mv | ~(Xh | Pv)
        Mh = Pv & Xh
        ph_bit = (pick_word(Ph, score_word) >> score_off) & 1
        mh_bit = (pick_word(Mh, score_word) >> score_off) & 1
        score = score + ph_bit.astype(jnp.int32) - mh_bit.astype(jnp.int32)
        Ph = _shl1_in_wm(Ph, cin)
        Mh = _shl1_in_wm(Mh, jnp.zeros((bt,), jnp.uint32))
        Pv = Mh | ~(Xv | Ph)
        Mv = Ph & Xv
        best = jnp.minimum(best, score)
        return Pv, Mv, score, best

    Pv0 = jnp.full((nw, bt), 0xFFFFFFFF, jnp.uint32)
    Mv0 = jnp.zeros((nw, bt), jnp.uint32)
    Pv, Mv, score, best = lax.fori_loop(0, n, step, (Pv0, Mv0, m_len, m_len))
    dist_ref[...] = score if mode == "global" else best


@functools.partial(jax.jit, static_argnames=("m_bits", "mode", "block_bt", "interpret"))
def myers_distance_batch(
    texts: jnp.ndarray,
    patterns: jnp.ndarray,
    m_lens: jnp.ndarray,
    *,
    m_bits: int,
    mode: str = "global",
    block_bt: int = 128,
    interpret: bool = False,
):
    """Batched Myers distance via Pallas.

    ``texts``: [B, n] int8; ``patterns``: [B, m_bits] int8 wildcard-padded;
    ``m_lens``: [B] int32.  Returns [B] int32 distances (global NW or
    semiglobal min-over-prefixes per ``mode``).
    """
    nw = m_bits // WORD_BITS
    b, n = texts.shape
    if b % block_bt != 0:
        raise ValueError(f"batch {b} not a multiple of block_bt {block_bt}")
    kernel = functools.partial(_myers_kernel, n=n, nw=nw, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(b // block_bt,),
        in_specs=[
            pl.BlockSpec((block_bt, n), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, m_bits), lambda i: (i, 0)),
            pl.BlockSpec((block_bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(texts, patterns, m_lens)
