"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, bit-for-bit matching the TPU
lowering semantics.  On TPU backends the compiled kernels run natively.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import genasm_dc as _gdc
from . import myers as _my


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def window_dc(sub_texts, sub_patterns, *, w: int = 64, k: int = 24, squeeze=False,
              block_bt: int | None = None):
    """GenASM-DC over a batch of windows (Pallas kernel, padded to tile).

    ``sub_texts``/``sub_patterns``: [B, w] int8.  Returns
    ``(d_min [B], tb [B, w, k+1, 3, nw])``; with ``squeeze=True`` drops a
    leading singleton batch (used by the windowed aligner's scan body).
    """
    b = sub_texts.shape[0]
    bt = block_bt or min(_gdc.DEFAULT_BT, max(8, b))
    pad = (-b) % bt
    if pad:
        sub_texts = jnp.concatenate(
            [sub_texts, jnp.full((pad, sub_texts.shape[1]), 4, sub_texts.dtype)]
        )
        sub_patterns = jnp.concatenate(
            [sub_patterns, jnp.full((pad, sub_patterns.shape[1]), 4, sub_patterns.dtype)]
        )
    d, tb = _gdc.window_dc_batch(
        sub_texts, sub_patterns, w=w, k=k, block_bt=bt, interpret=_interpret()
    )
    d, tb = d[:b], tb[:b]
    if squeeze:
        return d[0], tb[0]
    return d, tb


def myers_distance(texts, patterns, m_lens, *, m_bits: int, mode: str = "global",
                   block_bt: int | None = None):
    """Batched Myers edit distance (Pallas kernel, padded to tile)."""
    b = texts.shape[0]
    bt = block_bt or min(128, max(8, b))
    pad = (-b) % bt
    if pad:
        texts = jnp.concatenate([texts, jnp.full((pad, texts.shape[1]), 4, texts.dtype)])
        patterns = jnp.concatenate(
            [patterns, jnp.full((pad, patterns.shape[1]), 4, patterns.dtype)]
        )
        m_lens = jnp.concatenate([m_lens, jnp.ones((pad,), m_lens.dtype)])
    out = _my.myers_distance_batch(
        texts, patterns, m_lens, m_bits=m_bits, mode=mode, block_bt=bt,
        interpret=_interpret(),
    )
    return out[:b]


def window_dc_v2(sub_texts, sub_patterns, *, w: int = 64, k: int = 24,
                 squeeze=False, block_bt: int | None = None):
    """v2 kernel: R-only TB store (3× smaller; see genasm_dc_v2)."""
    from . import genasm_dc_v2 as _v2

    b = sub_texts.shape[0]
    bt = block_bt or min(_gdc.DEFAULT_BT, max(8, b))
    pad = (-b) % bt
    if pad:
        sub_texts = jnp.concatenate(
            [sub_texts, jnp.full((pad, sub_texts.shape[1]), 4, sub_texts.dtype)])
        sub_patterns = jnp.concatenate(
            [sub_patterns, jnp.full((pad, sub_patterns.shape[1]), 4,
                                    sub_patterns.dtype)])
    d, r = _v2.window_dc_batch_v2(sub_texts, sub_patterns, w=w, k=k,
                                  block_bt=bt, interpret=_interpret())
    d, r = d[:b], r[:b]
    if squeeze:
        return d[0], r[0]
    return d, r


def bitalign_dc(bases, succ_bits, patterns, p_lens, *, m_bits: int, k: int,
                block_bt: int | None = None):
    """Batched BitAlign DC kernel (padded to tile)."""
    from . import bitalign as _ba

    b = bases.shape[0]
    bt = block_bt or min(32, max(8, b))
    pad = (-b) % bt
    if pad:
        bases = jnp.concatenate([bases, jnp.full((pad, bases.shape[1]), 4,
                                                 bases.dtype)])
        succ_bits = jnp.concatenate(
            [succ_bits, jnp.zeros((pad, succ_bits.shape[1]), succ_bits.dtype)])
        patterns = jnp.concatenate(
            [patterns, jnp.full((pad, patterns.shape[1]), 4, patterns.dtype)])
        p_lens = jnp.concatenate([p_lens, jnp.ones((pad,), p_lens.dtype)])
    d, r = _ba.bitalign_dc_batch(bases, succ_bits, patterns, p_lens,
                                 m_bits=m_bits, k=k, block_bt=bt,
                                 interpret=_interpret())
    return d[:b], r[:b]
