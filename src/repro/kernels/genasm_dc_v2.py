"""GenASM-DC kernel v2 — beyond-paper TB-store compression (§Perf #3).

Hypothesis (napkin math): the paper's accelerator streams 3 intermediate
bitvectors (M, I, D) per (i, d) cell to TB-SRAM — 24 B/cycle/PE; but all
four TB checks are *derivable from the status bitvectors alone*:

    D(i,d) = R(i+1, d-1)           S(i,d) = shl1(D) = shl1(R(i+1, d-1))
    I(i,d) = shl1(R(i, d-1))       M(i,d) = shl1(R(i+1, d)) | PM[text[i]]

so storing only ``R`` rows ([W+1, k+1, nw] incl. the i=W boundary = all
ones) cuts TB-store writes and footprint by 3× (38.4 KB → 13 KB per
window at k=24), at the cost of one extra indexed read (the i+1 row) and
a PM re-derivation per TB step — TB executes ≤ W−O steps/window vs the
DC's W·(k+1) writes, so trading DC-side bytes for TB-side gathers is a
clear win (DC is the streaming bottleneck the paper engineered TB-SRAMs
for).  Confirmed by measurement in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitvector import NUM_CHARS, WORD_BITS

from .genasm_dc import _pm_table, _shl1_wm


def _dc_kernel_v2(text_ref, pattern_ref, dmin_ref, r_ref, *, w: int, k: int,
                  nw: int):
    bt = text_ref.shape[0]
    pm = _pm_table(pattern_ref[...], w, nw)  # [5, nw, BT]
    ones = jnp.full((k + 1, nw, bt), 0xFFFFFFFF, jnp.uint32)
    r_ref[:, w] = ones.transpose(2, 0, 1)  # boundary row (i = w)

    def step(s, R_old):
        i = w - 1 - s
        c = text_ref[:, i].astype(jnp.int32)
        cur_pm = jnp.zeros((nw, bt), jnp.uint32)
        for ch in range(NUM_CHARS):
            cur_pm = jnp.where((c == ch)[None, :], pm[ch], cur_pm)
        R0 = _shl1_wm(R_old[0]) | cur_pm
        rows = [R0]
        for d in range(1, k + 1):
            D = R_old[d - 1]
            S = _shl1_wm(R_old[d - 1])
            I = _shl1_wm(rows[d - 1])
            M = _shl1_wm(R_old[d]) | cur_pm
            rows.append(D & S & I & M)
        R_new = jnp.stack(rows)  # [k+1, nw, BT]
        r_ref[:, i] = R_new.transpose(2, 0, 1)
        return R_new

    R_fin = lax.fori_loop(0, w, step, ones)
    msbs = (R_fin[:, nw - 1, :] >> 31) & 1
    found = msbs == 0
    dmin_ref[...] = jnp.where(
        jnp.any(found, axis=0), jnp.argmax(found, axis=0), k + 1
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("w", "k", "block_bt", "interpret"))
def window_dc_batch_v2(sub_texts, sub_patterns, *, w: int = 64, k: int = 24,
                       block_bt: int = 128, interpret: bool = False):
    """Returns ``(d_min [B], R [B, w+1, k+1, nw])`` — status rows only."""
    nw = w // WORD_BITS
    b = sub_texts.shape[0]
    if b % block_bt != 0:
        raise ValueError(f"batch {b} not a multiple of block_bt {block_bt}")
    kernel = functools.partial(_dc_kernel_v2, w=w, k=k, nw=nw)
    return pl.pallas_call(
        kernel,
        grid=(b // block_bt,),
        in_specs=[
            pl.BlockSpec((block_bt, w), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_bt,), lambda i: (i,)),
            pl.BlockSpec((block_bt, w + 1, k + 1, nw), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, w + 1, k + 1, nw), jnp.uint32),
        ],
        interpret=interpret,
    )(sub_texts, sub_patterns)
