"""Pallas TPU kernel for GenASM-DC window batches.

TPU adaptation of the paper's 64-PE bit-parallel DC systolic array
(DESIGN.md §2): instead of unrolling the (i, d) anti-diagonals across PEs,
the batch of independent window alignments is the vector axis — every VPU
lane advances one alignment, sequentially in ``i`` (text chars) and with a
*statically unrolled* ``d`` loop (the k+1 distance rows, k=24 default).

Data layout inside the kernel is word-major ``[.., nw, BT]`` so the batch
tile ``BT`` occupies the 128-wide lane dimension; bitvector words (nw=2
for W=64) and distance rows live in sublanes/registers.  The per-window
traceback store (the ASIC's TB-SRAM) is the kernel output, written once
per text step — the same "24 B/cycle/PE" streaming locality the paper
engineers, here expressed as one VMEM->HBM block stream per window tile.

VMEM budget per block (BT=128, W=64, k=24): tb out 4.9 MB + text/pattern
tiles 16 KB + PM scratch 5 KB + R carry 26 KB — well under 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.bitvector import NUM_CHARS, WORD_BITS

DEFAULT_BT = 128


def _shl1_wm(x: jnp.ndarray) -> jnp.ndarray:
    """shift-left-1 for word-major [.., nw, BT] bitvectors."""
    carry = x >> 31
    shifted = x << 1
    zeros = jnp.zeros(x.shape[:-2] + (1,) + x.shape[-1:], jnp.uint32)
    incoming = jnp.concatenate([zeros, carry[..., :-1, :]], axis=-2)
    return shifted | incoming


def _pm_table(pattern_tile: jnp.ndarray, w: int, nw: int) -> jnp.ndarray:
    """[NUM_CHARS, nw, BT] uint32 PM table from a [BT, w] int8 pattern tile."""
    rev = pattern_tile[:, ::-1].astype(jnp.int32)  # [BT, w]; rev[:, g] = char at bit g
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out = []
    for c in range(NUM_CHARS):
        mismatch = ~((rev == c) | (rev == 4))
        mm = mismatch.astype(jnp.uint32).reshape(rev.shape[0], nw, WORD_BITS)
        pm = jnp.sum(mm * weights[None, None, :], axis=-1, dtype=jnp.uint32)  # [BT, nw]
        out.append(pm.T)  # [nw, BT]
    return jnp.stack(out)  # [5, nw, BT]


def _dc_kernel(text_ref, pattern_ref, dmin_ref, tb_ref, *, w: int, k: int, nw: int):
    bt = text_ref.shape[0]
    pm = _pm_table(pattern_ref[...], w, nw)  # [5, nw, BT]
    ones = jnp.full((k + 1, nw, bt), 0xFFFFFFFF, jnp.uint32)

    def step(s, R_old):
        i = w - 1 - s  # text position, scanned w-1 .. 0
        c = text_ref[:, i].astype(jnp.int32)  # [BT]
        cur_pm = jnp.zeros((nw, bt), jnp.uint32)
        for ch in range(NUM_CHARS):
            cur_pm = jnp.where((c == ch)[None, :], pm[ch], cur_pm)

        R0 = _shl1_wm(R_old[0]) | cur_pm
        new_rows = [R0]
        stores = [jnp.stack([R0, ones[0], ones[0]])]  # d=0: (M=R0, I=1s, D=1s)
        for d in range(1, k + 1):
            D = R_old[d - 1]
            S = _shl1_wm(R_old[d - 1])
            I = _shl1_wm(new_rows[d - 1])
            M = _shl1_wm(R_old[d]) | cur_pm
            new_rows.append(D & S & I & M)
            stores.append(jnp.stack([M, I, D]))
        R_new = jnp.stack(new_rows)  # [k+1, nw, BT]
        st = jnp.stack(stores)  # [k+1, 3, nw, BT]
        tb_ref[:, i] = st.transpose(3, 0, 1, 2)  # [BT, k+1, 3, nw]
        return R_new

    R_fin = lax.fori_loop(0, w, step, ones)
    msbs = (R_fin[:, nw - 1, :] >> 31) & 1  # [k+1, BT]
    found = msbs == 0
    dmin = jnp.where(
        jnp.any(found, axis=0), jnp.argmax(found, axis=0), k + 1
    ).astype(jnp.int32)
    dmin_ref[...] = dmin


@functools.partial(
    jax.jit, static_argnames=("w", "k", "block_bt", "interpret")
)
def window_dc_batch(
    sub_texts: jnp.ndarray,
    sub_patterns: jnp.ndarray,
    *,
    w: int = 64,
    k: int = 24,
    block_bt: int = DEFAULT_BT,
    interpret: bool = False,
):
    """Batched GenASM-DC windows via Pallas.

    ``sub_texts``/``sub_patterns``: [B, w] int8 (B a multiple of
    ``block_bt``; pad with sentinel windows).  Returns
    ``(d_min [B] int32, tb [B, w, k+1, 3, nw] uint32)`` identical to
    vmapped :func:`repro.core.genasm_dc.window_dc`.
    """
    if w % WORD_BITS != 0:
        raise ValueError("w must be a multiple of 32")
    nw = w // WORD_BITS
    b = sub_texts.shape[0]
    if b % block_bt != 0:
        raise ValueError(f"batch {b} not a multiple of block_bt {block_bt}")

    kernel = functools.partial(_dc_kernel, w=w, k=k, nw=nw)
    grid = (b // block_bt,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_bt, w), lambda i: (i, 0)),
            pl.BlockSpec((block_bt, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_bt,), lambda i: (i,)),
            pl.BlockSpec((block_bt, w, k + 1, 3, nw), lambda i: (i, 0, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, w, k + 1, 3, nw), jnp.uint32),
        ],
        interpret=interpret,
    )(sub_texts, sub_patterns)
