"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + Qwen2-0.5B backbone.  [arXiv:2404.16821; hf]
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (256 tokens, InternViT-300M width 1024)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    act="silu_glu",
    norm="rmsnorm",
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vision_stub",
    frontend_len=256,
    frontend_dim=1024,
    rope_theta=1_000_000.0,
)
