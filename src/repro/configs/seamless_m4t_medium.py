"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]
Audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (assignment spec); 12 encoder + 12 decoder layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    enc_layers=12,
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    frontend="audio_stub",
    frontend_len=4096,
    rope_theta=10_000.0,
)
