"""The paper's own workload config: GenASM read-alignment service.

Window geometry per the dissertation (W=64, O=24), long-read parameters
matching the evaluation datasets (§4.9): 10 kbp reads at 10–15% error.
"""
from dataclasses import dataclass

from repro.core.genasm import GenASMConfig


@dataclass(frozen=True)
class GenASMServiceConfig:
    genasm: GenASMConfig = GenASMConfig(w=64, o=24, k=24)
    # repro.align registry name; "auto" = Pallas on TPU/GPU, lax on CPU —
    # matching the resolution policy of the live entry points
    align_backend: str = "auto"
    read_cap: int = 10_240          # long reads (paper: 10 kbp)
    short_read_cap: int = 256       # Illumina use case
    filter_bits: int = 128
    filter_k: int = 12
    minimizer_w: int = 10
    minimizer_k: int = 15
    batch_reads: int = 2048         # per-device alignment batch


CONFIG = GenASMServiceConfig()
