"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892; hf]  64 heads of 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    pattern=("rwkv",),
    act="sq_relu",
    norm="layernorm",
)
