"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — Mamba+attn 1:7 interleave.  [arXiv:2403.19887]
Pattern: 8-layer Jamba block, attention at slot 4, MoE every other slot."""
from .base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    moe_slots=(1, 3, 5, 7),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    act="silu_glu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)
