"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    moe_slots=(0,),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    act="silu_glu",
    norm="rmsnorm",
    sliding_window=4096,
    rope_theta=1_000_000.0,
)
