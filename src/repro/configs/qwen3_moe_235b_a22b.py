"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per-expert) vocab=151936, MoE 128e top-8.  [hf:Qwen/Qwen3-30B-A3B-family]
head_dim=128 (explicit, > d_model/n_heads), QK-norm omitted, qkv_bias off."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    moe_slots=(0,),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    act="silu_glu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)
