"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

from importlib import import_module

from .base import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K, DECODE_32K,
                   ModelConfig, ShapeConfig, reduced)

_ARCH_MODULES = {
    "command-r-35b": "command_r_35b",
    "yi-6b": "yi_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "internlm2-1.8b": "internlm2_1_8b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return import_module(f"repro.configs.{_ARCH_MODULES[arch]}").CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str) -> list[str]:
    """Shape names applicable to an arch (assignment skip rules)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
