"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU (non-gated MLP).  [arXiv:2402.16819]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    act="sq_relu",
    norm="layernorm",
    rope_theta=10_000.0,
)
