"""Model/shape configuration schema for the assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

LayerKind = Literal["attn", "mamba", "rwkv"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    # repeating layer pattern; len must divide n_layers.  e.g. jamba:
    # ("attn",) + ("mamba",)*7
    pattern: tuple[LayerKind, ...] = ("attn",)
    # which pattern slots use MoE MLPs (empty = all dense)
    moe_slots: tuple[int, ...] = ()
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    act: Literal["silu_glu", "sq_relu", "gelu"] = "silu_glu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    sliding_window: int | None = None
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # encoder-decoder (seamless): n_layers applies to the decoder
    enc_layers: int = 0
    cross_attention: bool = False
    # modality frontend stub: embeddings arrive precomputed (spec'd shapes)
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    frontend_len: int = 0  # encoder/prefix length fed by the stub
    frontend_dim: int | None = None  # stub embedding dim (defaults d_model)
    attn_logit_softcap: float | None = None
    parallel_block: bool = False  # command-r style parallel attn+mlp

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding shards evenly over "model"
        (MaxText-style padding; extra rows are never targeted)."""
        return -(-self.vocab // 512) * 512

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.name, self.n_layers)
        return self.n_layers // len(self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is admissible (spec's long_500k rule)."""
        has_full_attn = "attn" in self.pattern and self.sliding_window is None
        return not has_full_attn or self.pattern.count("attn") < len(self.pattern)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test configuration of the same family (small dims, same pattern)."""
    small = dict(
        n_layers=len(cfg.pattern) * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=128,
        vocab=512,
        head_dim=16,
        frontend_len=min(cfg.frontend_len, 16) if cfg.frontend_len else 0,
        frontend_dim=32 if cfg.frontend_dim else None,
        enc_layers=2 if cfg.enc_layers else 0,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
        )
    if cfg.mamba is not None:
        small["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16)
    if cfg.sliding_window:
        small["sliding_window"] = 32
    small.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **small)
