"""On-device shard merge: packed monotone uint64 keys + argmin-reduce.

The PR-5 sharded mappers merged per-shard winners on the host — every
``[S, B]`` distance/position/window array round-tripped device → host
numpy → device between the filter and align stages, exactly the
accelerator-to-host data movement the dissertation's GenASM co-design
removes by keeping the DC→TB handoff on-accelerator.  This module
replaces that host step with a device reduction:

* `pack_linear_key` / `pack_graph_key` pack one candidate's
  lexicographic sort tuple — ``(distance, position)`` for the linear
  workload, ``(distance, origin, tile)`` for the graph workload — into
  a single **monotone** ``uint64``: ``a < b`` tuple-wise iff
  ``pack(a) < pack(b)``.  Sentinel components (`POS_SENTINEL`, the
  "no candidate" marker) map to the top of their bit field, so masked
  candidates sort last, exactly like the host rule.
* `merge_linear` / `merge_graph` take the stacked ``[S, B, ...]`` stage
  outputs, ``argmin`` the packed key over the shard axis, and gather
  the winner row per read.  ``jnp.argmin`` returns the *first* minimal
  index, which reproduces `repro.core.mapper.lex_best`'s tie-break
  (lowest shard wins on a full-key tie) bit for bit — proven
  differentially by ``tests/test_shard_merge.py``.

JAX runs with ``x64`` disabled globally, so the 64-bit key only exists
inside a `jax.experimental.enable_x64` scope: wrap calls to the jitted
merge in `x64_scope` (the executors do).  Inputs and outputs are plain
``int32`` arrays, so nothing 64-bit leaks to callers.  The pack/unpack
helpers are dtype-driven (``.astype``/shift/mask only), so they run
unchanged on numpy ``uint64`` arrays — which is how the property suite
checks order-isomorphism without touching the x64 flag.

Field layout (bit widths chosen once, validated by `check_graph_domain`):

    linear  key = distance[32] . position[32]
    graph   key = distance[12] . origin[31]  . tile[21]

``origin``'s 31-bit field tops out at ``2**31 - 1 == POS_SENTINEL``
itself, so sentinel origins need no remapping; tile sentinels clamp to
the 21-bit field max and `unpack_graph_key` restores them.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import enable_x64 as x64_scope  # re-exported

from repro.core.mapper import POS_SENTINEL

# graph key bit layout: 12 + 31 + 21 = 64
GRAPH_D_BITS = 12
GRAPH_ORIGIN_BITS = 31
GRAPH_TILE_BITS = 21
GRAPH_D_MAX = (1 << GRAPH_D_BITS) - 1
GRAPH_ORIGIN_MAX = (1 << GRAPH_ORIGIN_BITS) - 1  # == POS_SENTINEL
GRAPH_TILE_MAX = (1 << GRAPH_TILE_BITS) - 1  # sentinel encoding for tiles


def pack_linear_key(distance, position):
    """Monotone uint64 key for the linear ``(distance, position)`` tuple.

    Valid for non-negative int32 components (positions use
    `POS_SENTINEL` for "none", which already sorts last).  Works on
    jnp arrays inside an `x64_scope` and on numpy arrays as-is.
    """
    return ((distance.astype("uint64") << 32)
            | position.astype("uint64"))


def unpack_linear_key(key):
    """Inverse of `pack_linear_key`: ``(distance, position)`` int32."""
    return ((key >> 32).astype("int32"),
            (key & ((1 << 32) - 1)).astype("int32"))


def pack_graph_key(distance, origin, tile):
    """Monotone uint64 key for the graph ``(distance, origin, tile)`` tuple.

    Domain (validated once per geometry by `check_graph_domain`):
    ``distance <= GRAPH_D_MAX``, ``origin < POS_SENTINEL`` or exactly
    `POS_SENTINEL` (the 31-bit field max, so the sentinel is its own
    encoding), ``tile < GRAPH_TILE_MAX`` or `POS_SENTINEL` (clamped to
    the 21-bit field max).  Dead candidates carry sentinel origin *and*
    tile (same ``live`` mask upstream), which keeps the packed argmin
    equal to the host three-level masked merge.
    """
    t = tile.clip(0, GRAPH_TILE_MAX)
    return ((distance.astype("uint64") << (GRAPH_ORIGIN_BITS
                                           + GRAPH_TILE_BITS))
            | (origin.astype("uint64") << GRAPH_TILE_BITS)
            | t.astype("uint64"))


def unpack_graph_key(key):
    """Inverse of `pack_graph_key`: ``(distance, origin, tile)`` int32.

    Tile field-max decodes back to `POS_SENTINEL` (the only value the
    clamp can have mapped there, per the `check_graph_domain` bound).
    """
    d = (key >> (GRAPH_ORIGIN_BITS + GRAPH_TILE_BITS)).astype("int32")
    origin = ((key >> GRAPH_TILE_BITS) & GRAPH_ORIGIN_MAX).astype("int32")
    t = (key & GRAPH_TILE_MAX).astype("int32")
    tile = t + (t == GRAPH_TILE_MAX) * (POS_SENTINEL - GRAPH_TILE_MAX)
    return d, origin, tile.astype("int32")


def check_graph_domain(*, n_tiles: int, filter_k: int) -> None:
    """Raise if a graph geometry cannot round-trip through the key fields.

    ``n_tiles`` must leave the 21-bit field max free for the sentinel
    and ``filter_k + 1`` (the "no candidate" distance) must fit the
    12-bit distance field — generous bounds (2M tiles, distance 4094)
    for any geometry the bucket ladder serves, but checked rather than
    assumed.
    """
    if n_tiles >= GRAPH_TILE_MAX:
        raise ValueError(
            f"graph index has {n_tiles} tiles but the packed merge key's "
            f"tile field holds {GRAPH_TILE_MAX - 1} + sentinel; shard the "
            f"graph or widen GRAPH_TILE_BITS")
    if filter_k + 1 > GRAPH_D_MAX:
        raise ValueError(
            f"filter_k {filter_k} overflows the packed merge key's "
            f"{GRAPH_D_BITS}-bit distance field (max {GRAPH_D_MAX - 1})")


def _gather_winner(arr, win):
    """``arr[win[b], b, ...]`` for stacked ``[S, B, ...]`` leaves."""
    idx = win.reshape((1,) + win.shape + (1,) * (arr.ndim - 2))
    return jnp.take_along_axis(arr, idx, axis=0)[0]


def merge_linear(distance, position, text, t_len):
    """Device argmin-reduce of stacked linear shard winners.

    Same contract as the host ``ShardedMapExecutor.merge`` —
    ``(fd, pos, text, t_len, winner_shard)`` per read, tie-breaking
    bit-identical to `lex_best` — but as one jittable program over the
    ``[S, B, ...]`` stage outputs, so the winners never leave the
    device between filter and align.  Call inside `x64_scope`.
    """
    key = pack_linear_key(distance, position)  # [S, B] uint64
    win = jnp.argmin(key, axis=0).astype(jnp.int32)  # first min = low shard
    return (_gather_winner(distance, win), _gather_winner(position, win),
            _gather_winner(text, win), _gather_winner(t_len, win), win)


def merge_graph(distance, origin, tile, gwin, bwin, t_len, prefilter_ok):
    """Device argmin-reduce of stacked graph shard winners.

    Field-by-field twin of the host ``ShardedGraphMapExecutor.merge``
    (three-level ``(distance, origin, tile)`` lexicographic min): the
    packed-key argmin picks the same shard because dead candidates
    carry sentinel origin *and* tile together (the stage's shared
    ``live`` mask), so masking and key order agree.  Returns the
    merged per-read fields plus the winner shard.  Call inside
    `x64_scope`.
    """
    key = pack_graph_key(distance, origin, tile)  # [S, B] uint64
    win = jnp.argmin(key, axis=0).astype(jnp.int32)
    pick = lambda a: _gather_winner(a, win)  # noqa: E731
    return (pick(distance), pick(origin), pick(tile), pick(gwin),
            pick(bwin), pick(t_len), pick(prefilter_ok), win)
