"""Shard-parallel graph mapping: the GAF twin of `shard.mapper`.

Same pipeline as the whole-graph `repro.graph.mapper.GraphMapExecutor`,
scattered: every shard runs the seed + q-gram tile screen
(`tile_prefilter`) over its own :class:`~repro.graph.mapper.GraphView`,
a host sync on the per-shard survivor counts picks one shared
`tile_rung`, each shard compacts its survivors into that many DC rows
(`graph_candidate_stage` with ``pf``/``n_cap``), per-shard winners merge
**on device** by an argmin-reduce over the packed monotone uint64
``(filter distance, origin node, tile)`` key (`repro.shard.merge`,
global coordinates; the host lex merge survives as ``merge_host``, the
differential oracle), and one batched graph ``align_batch`` call
finishes — optionally sharded over the same mesh
(``align_sharded=True``) and dispatchable without blocking through the
``start()``/``finish()`` pipeline surface.  The screen and compaction are bitwise-neutral per shard
(see `graph/mapper`), and the merge rule is the same one the whole-graph
mapper applies across its candidate axis — so GAF output stays
byte-identical at 1 and N shards, prefilter on or off.  Winners travel
with their packed window bytes *and* per-node backbone coordinates
(``bwin``), so the align stage needs no graph arrays at all.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.core.mapper import POS_SENTINEL
from repro.dist import sharding as dist_sharding
from repro.graph.mapper import (CandidateStageResult, GraphMapResult,
                                GraphView, _env_prefilter, align_winners,
                                graph_backend_name, graph_candidate_stage,
                                tile_prefilter, tile_rung, unmapped_result)

from . import merge as shard_merge
from .graph_partition import GraphShardArrays, ShardedGraphIndex
from .mapper import PendingBatch


def validate_graph_geometry(sharded: ShardedGraphIndex, *, p_cap: int,
                            filter_k: int, cfg: GenASMConfig) -> None:
    """Raise if the tile/halo geometry cannot cover this mapping setup."""
    from repro.core.segram.graph import HOP_LIMIT

    t_cap = p_cap + 2 * cfg.w
    span = sharded.tile_len - t_cap
    if span < sharded.tile_stride:
        raise ValueError(
            f"tile_len {sharded.tile_len} leaves a {span}-node anchor "
            f"search span < tile_stride {sharded.tile_stride} at p_cap "
            f"{p_cap}; rebuild the index with window >= {t_cap}")
    need = p_cap + 32 + HOP_LIMIT + filter_k
    if sharded.layout.halo < need:
        raise ValueError(
            f"graph shard halo {sharded.layout.halo} < {need} required "
            f"for p_cap={p_cap}, filter_k={filter_k}; rebuild with "
            f"halo >= {need}")


def _shard_view(tiles, tvalid, tbase, nob, nboff, bb, nbase, hashes, poss,
                tbloom, tslack) -> GraphView:
    """One shard's arrays (`GraphShardArrays` row order) as a GraphView."""
    return GraphView(
        tile_gtext=tiles, tile_valid=tvalid, tile_base=tbase,
        node_of_backbone=nob, nb_offset=nboff, backbone=bb,
        node_base=nbase, idx_hashes=hashes, idx_positions=poss,
        tile_bloom=tbloom, tile_slack=tslack)


def _pf_one_shard(*args, static):
    """One graph shard's seed + tile screen over the whole read batch."""
    arrs, (reads, lens) = args[:-2], args[-2:]
    return tile_prefilter(_shard_view(*arrs), reads, lens, **static)


def _stage_one_shard(*args, n_cap, static):
    """One shard's compacted candidate stage (survivors from ``pf``)."""
    arrs, (reads, lens, pf) = args[:-3], args[-3:]
    return graph_candidate_stage(_shard_view(*arrs), reads, lens, pf=pf,
                                 n_cap=n_cap, **static)


class ShardedGraphMapExecutor:
    """Compiled scatter/screen/merge/align pipeline for one sharded graph.

    Mirrors `graph.mapper.GraphMapExecutor` across shards: a
    ``shard_map`` (or stacked ``vmap``) prefilter stage, a host sync
    that picks one `tile_rung` from the worst shard's survivor count, a
    per-rung compiled compacted candidate stage, the host lexicographic
    merge, and one jitted graph-align stage.  ``trace_hook`` (if given)
    is called with a hashable stage key at trace time —
    ``("prefilter",)``, ``(n_cap,)`` per rung, and ``("align",)`` — or
    with no argument if it doesn't accept one (legacy align-only hook).
    ``last_stats`` carries pruning/occupancy counters for the engine.
    """

    def __init__(self, sharded: ShardedGraphIndex, *,
                 cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 shard_candidates: int = 4,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 force_vmap: bool = False,
                 align_sharded: bool = False,
                 prefilter: bool | None = None,
                 trace_hook=None):
        validate_graph_geometry(sharded, p_cap=p_cap, filter_k=filter_k,
                                cfg=cfg)
        shard_merge.check_graph_domain(n_tiles=sharded.n_tiles,
                                       filter_k=filter_k)
        self.align_sharded = align_sharded
        self.num_shards = sharded.num_shards
        self.backend = graph_backend_name(backend)
        self.cfg = cfg
        self.p_cap = p_cap
        self.shard_candidates = shard_candidates
        self.prefilter = _env_prefilter(prefilter)
        t_cap = p_cap + 2 * cfg.w

        self._compiled: set = set()  # stage keys that have traced

        def hook(key):
            self._compiled.add(key)
            if trace_hook is None:
                return
            try:
                trace_hook(key)
            except TypeError:
                trace_hook()

        self._hook = hook
        static_pf = dict(
            tile_stride=sharded.tile_stride, n_tiles=sharded.n_tiles,
            backbone_len=sharded.ref_len,
            filter_bits=min(filter_bits, p_cap), filter_k=filter_k,
            max_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w,
            minimizer_k=sharded.minimizer_k, prefilter=self.prefilter)
        static = dict(
            tile_stride=sharded.tile_stride, n_tiles=sharded.n_tiles,
            backbone_len=sharded.ref_len, n_nodes=sharded.n_nodes,
            t_cap=t_cap, filter_bits=min(filter_bits, p_cap),
            filter_k=filter_k, max_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w,
            minimizer_k=sharded.minimizer_k,
            use_kernel=False, block_bt=block_bt, interpret=True)
        pf_fn = partial(_pf_one_shard, static=static_pf)

        mesh = None if force_vmap else dist_sharding.shard_mesh(
            self.num_shards)
        self.spmd = mesh is not None
        if self.spmd:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            arr_specs = tuple(dist_sharding.stacked_specs(
                sharded.arrays, mesh))

            def block_pf(*args):
                self._hook(("prefilter",))
                arrs, (reads, lens) = args[:-2], args[-2:]
                out = pf_fn(*[a[0] for a in arrs], reads, lens)
                return jax.tree.map(lambda x: x[None], out)

            self._pf = jax.jit(shard_map(
                block_pf, mesh=mesh, in_specs=arr_specs + (P(), P()),
                out_specs=P("shard")))

            def make_stage(n_cap):
                stage = partial(_stage_one_shard, n_cap=n_cap,
                                static=static)

                def block_stage(*args):
                    self._hook((n_cap,))
                    arrs, (reads, lens, pf) = args[:-3], args[-3:]
                    pf0 = jax.tree.map(lambda x: x[0], pf)
                    out = stage(*[a[0] for a in arrs], reads, lens, pf0)
                    return jax.tree.map(lambda x: x[None], out)

                return jax.jit(shard_map(
                    block_stage, mesh=mesh,
                    in_specs=arr_specs + (P(), P(), P("shard")),
                    out_specs=P("shard")))
        else:
            def stacked_pf(*args):
                self._hook(("prefilter",))
                arrs, (reads, lens) = args[:-2], args[-2:]
                return jax.vmap(
                    lambda *rows: pf_fn(*rows, reads, lens))(*arrs)

            self._pf = jax.jit(stacked_pf)

            def make_stage(n_cap):
                stage = partial(_stage_one_shard, n_cap=n_cap,
                                static=static)

                def stacked_stage(*args):
                    self._hook((n_cap,))
                    arrs, (reads, lens, pf) = args[:-3], args[-3:]
                    return jax.vmap(
                        lambda *rows: stage(*rows[:-1], reads, lens,
                                            rows[-1]))(*arrs, pf)

                return jax.jit(stacked_stage)

        self._make_stage = make_stage
        self._stages: dict[int, object] = {}

        def align_core(merged: CandidateStageResult, reads, lens):
            return align_winners(merged, reads, lens, cfg=cfg, p_cap=p_cap,
                                 backend=self.backend, block_bt=block_bt)

        def align_stage(merged: CandidateStageResult, reads, lens):
            self._hook(("align",))
            return align_core(merged, reads, lens)

        s = self.num_shards

        def align_stage_sharded(merged: CandidateStageResult, reads, lens):
            # round-robin [S, B/S] split of the merged winners on the
            # shard mesh; windows/bwin travel with the winner, so each
            # block aligns without graph arrays — bit-neutral per read
            self._hook(("align_shard",))
            b = reads.shape[0]
            bs = -(-b // s)

            def blocked(x):
                x = jnp.pad(x, ((0, bs * s - b),)
                            + ((0, 0),) * (x.ndim - 1))
                return x.reshape((s, bs) + x.shape[1:])

            margs = jax.tree.map(blocked, merged)
            rargs = (blocked(reads), blocked(lens))
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P

                def block(m, r, ln):
                    out = align_core(jax.tree.map(lambda y: y[0], m),
                                     r[0], ln[0])
                    return jax.tree.map(lambda y: y[None], out)

                out = shard_map(
                    block, mesh=mesh,
                    in_specs=(P("shard"), P("shard"), P("shard")),
                    out_specs=P("shard"))(margs, *rargs)
            else:
                out = jax.vmap(align_core)(margs, *rargs)
            return jax.tree.map(
                lambda y: y.reshape((bs * s,) + y.shape[2:])[:b], out)

        self._align = jax.jit(
            align_stage_sharded if align_sharded else align_stage)
        self._align_stage_name = ("align_shard" if align_sharded
                                  else "align")
        # packed (distance, origin, tile) argmin-reduce on device
        self._merge = jax.jit(shard_merge.merge_graph)
        # the argmin collapses the shard axis but leaves its outputs
        # replicated across the mesh; a full-batch align traced on
        # replicated operands re-runs on every device, so the tiny
        # merged rows are committed to one device first.  A mesh-split
        # align partitions the work itself and must see mesh-addressable
        # inputs, so it keeps them replicated.
        self._off_mesh = (None if mesh is None or align_sharded
                          else mesh.devices.flat[0])
        self.last_stats: dict = {}
        # (stage, t0, t1, attrs) monotonic windows from the last call —
        # the serve engine replays them as child spans of its flush span
        self.last_times: list[tuple[str, float, float, dict]] = []

    def _stage_for(self, n_cap: int):
        fn = self._stages.get(n_cap)
        if fn is None:
            fn = self._stages[n_cap] = self._make_stage(n_cap)
        return fn

    @staticmethod
    def merge_host(st: CandidateStageResult) -> CandidateStageResult:
        """Reference host merge: lex ``(distance, origin, tile)`` per read.

        Kept as the independently coded oracle for the differential
        suite — the packed-key device merge must match it bit for bit.
        Identical windows duplicated across neighbouring shards'
        overlap regions collapse because their full sort key (and the
        window bytes behind it) are equal.
        """
        d = np.asarray(st.distance)
        origin = np.asarray(st.origin)
        tile = np.asarray(st.tile)
        dm = d.min(axis=0, keepdims=True)
        om = np.where(d == dm, origin, POS_SENTINEL)
        omin = om.min(axis=0, keepdims=True)
        tm = np.where(om == omin, tile, POS_SENTINEL)
        win = tm.argmin(axis=0)
        cols = np.arange(d.shape[1])
        pick = lambda a: np.asarray(a)[win, cols]  # noqa: E731
        return CandidateStageResult(
            distance=pick(st.distance), origin=pick(st.origin),
            tile=pick(st.tile), gwin=pick(st.gwin), bwin=pick(st.bwin),
            t_len=pick(st.t_len), prefilter_ok=pick(st.prefilter_ok))

    # chaos drills and older callers used ``ex.merge``
    merge = merge_host

    def merge_device(self, st: CandidateStageResult
                     ) -> CandidateStageResult:
        """Packed-key argmin-reduce on device (`repro.shard.merge`).

        Same winner and tie-break as `merge_host` — dead candidates
        carry sentinel origin *and* tile (the stage's shared ``live``
        mask), so the packed order and the three-level masked merge
        agree — with no host round trip.
        """
        with shard_merge.x64_scope():
            d, origin, tile, gwin, bwin, t_len, pf_ok, _win = self._merge(
                st.distance, st.origin, st.tile, st.gwin, st.bwin,
                st.t_len, st.prefilter_ok)
        out = CandidateStageResult(
            distance=d, origin=origin, tile=tile, gwin=gwin, bwin=bwin,
            t_len=t_len, prefilter_ok=pf_ok)
        if self._off_mesh is not None:
            out = jax.device_put(out, self._off_mesh)
        return out

    def start(self, arrays: GraphShardArrays, reads, read_lens, *,
              timed: bool = True) -> PendingBatch:
        """Dispatch screen → scatter → device merge → align, non-blocking.

        The prefilter's host sync (rung selection needs the survivor
        counts) always happens; everything after it stays on device
        until `finish`.  ``timed=False`` skips the inter-stage syncs
        for pipelined serving.  The zero-survivor short-circuit returns
        an already-materialized batch (``tail=None``).
        """
        reads = jnp.asarray(reads)
        lens = jnp.asarray(read_lens, jnp.int32)
        b = int(reads.shape[0])
        slots = b * self.shard_candidates
        c_pf = ("prefilter",) not in self._compiled
        t0 = time.monotonic()
        pf = self._pf(*arrays, reads, lens)  # leaves [S, B, ...]
        n_keep = np.asarray(pf.n_keep)  # [S, B]; host sync ends prefilter
        t1 = time.monotonic()
        kept = int(n_keep.sum())
        live = int(np.asarray(pf.n_live).sum())
        # one rung for all shards: the worst shard's survivor count
        n_cap = tile_rung(int(n_keep.sum(axis=1).max()), slots)
        stats = dict(
            candidate_slots=self.num_shards * slots, tiles_live=live,
            tiles_kept=kept, tiles_pruned=live - kept,
            dc_rows=self.num_shards * n_cap,
            dc_rows_dense=self.num_shards * slots,
            reads_zero_survivor=int((n_keep.sum(axis=0) == 0).sum()))
        self.last_stats = stats
        times = [("prefilter", t0, t1, {"compile": c_pf,
                                        "shards": self.num_shards})]
        if n_cap == 0:
            res = jax.tree_util.tree_map(
                np.asarray, unmapped_result(b, cfg=self.cfg,
                                            p_cap=self.p_cap))
            return PendingBatch(res=res, times=tuple(times), t_dispatch=t1,
                                tail=None, stats=stats)
        c_dc = (n_cap,) not in self._compiled
        c_al = (self._align_stage_name,) not in self._compiled
        t2 = time.monotonic()
        st = self._stage_for(n_cap)(*arrays, reads, lens, pf)
        if timed:
            jax.block_until_ready(st)
            t3 = time.monotonic()
            times.append(("dc_filter", t2, t3,
                          {"compile": c_dc,
                           "dc_rows": self.num_shards * n_cap}))
        merged = self.merge_device(st)
        if timed:
            jax.block_until_ready(merged.distance)
            t4 = time.monotonic()
            times.append(("merge_device", t3, t4,
                          {"shards": self.num_shards}))
        else:
            t4 = time.monotonic()
        res = self._align(merged, reads, lens)
        return PendingBatch(res=res, times=tuple(times), t_dispatch=t4,
                            tail=(self._align_stage_name,
                                  {"compile": c_al,
                                   "sharded": self.align_sharded}),
                            stats=stats)

    @staticmethod
    def finish(pending: PendingBatch):
        """Materialize a `start` batch → ``(numpy result, stage times)``."""
        if pending.tail is None:
            return pending.res, pending.times
        res = jax.tree_util.tree_map(np.asarray, pending.res)
        name, attrs = pending.tail
        return res, pending.times + ((name, pending.t_dispatch,
                                      time.monotonic(), attrs),)

    def __call__(self, arrays: GraphShardArrays, reads, read_lens
                 ) -> GraphMapResult:
        """Map one batch: screen → scatter → device merge → align."""
        res, times = self.finish(self.start(arrays, reads, read_lens))
        self.last_times = list(times)
        return res


# bounded LRU, mirroring shard.mapper: refresh() cycles must not leak
# compiled executors
_EXECUTORS: OrderedDict[tuple, ShardedGraphMapExecutor] = OrderedDict()
_EXECUTOR_CACHE_CAP = 8


def get_graph_executor(
    sharded: ShardedGraphIndex,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    prefilter: bool | None = None,
    align_sharded: bool = False,
) -> ShardedGraphMapExecutor:
    """Cached :class:`ShardedGraphMapExecutor` per (geometry, params)."""
    prefilter = _env_prefilter(prefilter)
    key = (sharded.layout_key, cfg, p_cap, filter_bits, filter_k,
           shard_candidates, backend, block_bt, force_vmap, prefilter,
           align_sharded)
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = ShardedGraphMapExecutor(
            sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
            filter_k=filter_k, shard_candidates=shard_candidates,
            backend=backend, block_bt=block_bt, force_vmap=force_vmap,
            prefilter=prefilter, align_sharded=align_sharded)
        _EXECUTORS[key] = ex
        while len(_EXECUTORS) > _EXECUTOR_CACHE_CAP:
            _EXECUTORS.popitem(last=False)
    else:
        _EXECUTORS.move_to_end(key)
    return ex


def map_batch_sharded_graph(
    sharded: ShardedGraphIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    prefilter: bool | None = None,
    align_sharded: bool = False,
    pipelined: bool = False,
) -> GraphMapResult:
    """Map a read batch against a sharded variation-graph index.

    Returns the same :class:`repro.graph.mapper.GraphMapResult` (numpy
    leaves) as the single-device `graph.mapper.map_batch` —
    byte-identical positions, CIGARs, and GAF node paths for any shard
    count, with the q-gram tile screen on or off.  Executors are cached
    per (geometry, parameters).  ``pipelined`` dispatches through the
    non-blocking `start`/`finish` surface (no inter-stage syncs).
    """
    ex = get_graph_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, block_bt=block_bt, force_vmap=force_vmap,
        prefilter=prefilter, align_sharded=align_sharded)
    if pipelined:
        res, times = ex.finish(ex.start(sharded.arrays, reads, read_lens,
                                        timed=False))
        ex.last_times = list(times)
        return res
    return ex(sharded.arrays, reads, read_lens)
