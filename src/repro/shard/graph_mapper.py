"""Shard-parallel graph mapping: the GAF twin of `shard.mapper`.

Same pipeline as the whole-graph `repro.graph.mapper.GraphMapExecutor`,
scattered: every shard runs the seed + q-gram tile screen
(`tile_prefilter`) over its own :class:`~repro.graph.mapper.GraphView`,
a host sync on the per-shard survivor counts picks one shared
`tile_rung`, each shard compacts its survivors into that many DC rows
(`graph_candidate_stage` with ``pf``/``n_cap``), per-shard winners merge
on the host by the lexicographic ``min (filter distance, origin node,
tile)`` in global coordinates, and one batched graph ``align_batch``
call finishes.  The screen and compaction are bitwise-neutral per shard
(see `graph/mapper`), and the merge rule is the same one the whole-graph
mapper applies across its candidate axis — so GAF output stays
byte-identical at 1 and N shards, prefilter on or off.  Winners travel
with their packed window bytes *and* per-node backbone coordinates
(``bwin``), so the align stage needs no graph arrays at all.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.core.mapper import POS_SENTINEL
from repro.dist import sharding as dist_sharding
from repro.graph.mapper import (CandidateStageResult, GraphMapResult,
                                GraphView, _env_prefilter, align_winners,
                                graph_backend_name, graph_candidate_stage,
                                tile_prefilter, tile_rung, unmapped_result)

from .graph_partition import GraphShardArrays, ShardedGraphIndex


def validate_graph_geometry(sharded: ShardedGraphIndex, *, p_cap: int,
                            filter_k: int, cfg: GenASMConfig) -> None:
    """Raise if the tile/halo geometry cannot cover this mapping setup."""
    from repro.core.segram.graph import HOP_LIMIT

    t_cap = p_cap + 2 * cfg.w
    span = sharded.tile_len - t_cap
    if span < sharded.tile_stride:
        raise ValueError(
            f"tile_len {sharded.tile_len} leaves a {span}-node anchor "
            f"search span < tile_stride {sharded.tile_stride} at p_cap "
            f"{p_cap}; rebuild the index with window >= {t_cap}")
    need = p_cap + 32 + HOP_LIMIT + filter_k
    if sharded.layout.halo < need:
        raise ValueError(
            f"graph shard halo {sharded.layout.halo} < {need} required "
            f"for p_cap={p_cap}, filter_k={filter_k}; rebuild with "
            f"halo >= {need}")


def _shard_view(tiles, tvalid, tbase, nob, nboff, bb, nbase, hashes, poss,
                tbloom, tslack) -> GraphView:
    """One shard's arrays (`GraphShardArrays` row order) as a GraphView."""
    return GraphView(
        tile_gtext=tiles, tile_valid=tvalid, tile_base=tbase,
        node_of_backbone=nob, nb_offset=nboff, backbone=bb,
        node_base=nbase, idx_hashes=hashes, idx_positions=poss,
        tile_bloom=tbloom, tile_slack=tslack)


def _pf_one_shard(*args, static):
    """One graph shard's seed + tile screen over the whole read batch."""
    arrs, (reads, lens) = args[:-2], args[-2:]
    return tile_prefilter(_shard_view(*arrs), reads, lens, **static)


def _stage_one_shard(*args, n_cap, static):
    """One shard's compacted candidate stage (survivors from ``pf``)."""
    arrs, (reads, lens, pf) = args[:-3], args[-3:]
    return graph_candidate_stage(_shard_view(*arrs), reads, lens, pf=pf,
                                 n_cap=n_cap, **static)


class ShardedGraphMapExecutor:
    """Compiled scatter/screen/merge/align pipeline for one sharded graph.

    Mirrors `graph.mapper.GraphMapExecutor` across shards: a
    ``shard_map`` (or stacked ``vmap``) prefilter stage, a host sync
    that picks one `tile_rung` from the worst shard's survivor count, a
    per-rung compiled compacted candidate stage, the host lexicographic
    merge, and one jitted graph-align stage.  ``trace_hook`` (if given)
    is called with a hashable stage key at trace time —
    ``("prefilter",)``, ``(n_cap,)`` per rung, and ``("align",)`` — or
    with no argument if it doesn't accept one (legacy align-only hook).
    ``last_stats`` carries pruning/occupancy counters for the engine.
    """

    def __init__(self, sharded: ShardedGraphIndex, *,
                 cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 shard_candidates: int = 4,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 force_vmap: bool = False,
                 prefilter: bool | None = None,
                 trace_hook=None):
        validate_graph_geometry(sharded, p_cap=p_cap, filter_k=filter_k,
                                cfg=cfg)
        self.num_shards = sharded.num_shards
        self.backend = graph_backend_name(backend)
        self.cfg = cfg
        self.p_cap = p_cap
        self.shard_candidates = shard_candidates
        self.prefilter = _env_prefilter(prefilter)
        t_cap = p_cap + 2 * cfg.w

        self._compiled: set = set()  # stage keys that have traced

        def hook(key):
            self._compiled.add(key)
            if trace_hook is None:
                return
            try:
                trace_hook(key)
            except TypeError:
                trace_hook()

        self._hook = hook
        static_pf = dict(
            tile_stride=sharded.tile_stride, n_tiles=sharded.n_tiles,
            backbone_len=sharded.ref_len,
            filter_bits=min(filter_bits, p_cap), filter_k=filter_k,
            max_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w,
            minimizer_k=sharded.minimizer_k, prefilter=self.prefilter)
        static = dict(
            tile_stride=sharded.tile_stride, n_tiles=sharded.n_tiles,
            backbone_len=sharded.ref_len, n_nodes=sharded.n_nodes,
            t_cap=t_cap, filter_bits=min(filter_bits, p_cap),
            filter_k=filter_k, max_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w,
            minimizer_k=sharded.minimizer_k,
            use_kernel=False, block_bt=block_bt, interpret=True)
        pf_fn = partial(_pf_one_shard, static=static_pf)

        mesh = None if force_vmap else dist_sharding.shard_mesh(
            self.num_shards)
        self.spmd = mesh is not None
        if self.spmd:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            arr_specs = tuple(dist_sharding.stacked_specs(
                sharded.arrays, mesh))

            def block_pf(*args):
                self._hook(("prefilter",))
                arrs, (reads, lens) = args[:-2], args[-2:]
                out = pf_fn(*[a[0] for a in arrs], reads, lens)
                return jax.tree.map(lambda x: x[None], out)

            self._pf = jax.jit(shard_map(
                block_pf, mesh=mesh, in_specs=arr_specs + (P(), P()),
                out_specs=P("shard")))

            def make_stage(n_cap):
                stage = partial(_stage_one_shard, n_cap=n_cap,
                                static=static)

                def block_stage(*args):
                    self._hook((n_cap,))
                    arrs, (reads, lens, pf) = args[:-3], args[-3:]
                    pf0 = jax.tree.map(lambda x: x[0], pf)
                    out = stage(*[a[0] for a in arrs], reads, lens, pf0)
                    return jax.tree.map(lambda x: x[None], out)

                return jax.jit(shard_map(
                    block_stage, mesh=mesh,
                    in_specs=arr_specs + (P(), P(), P("shard")),
                    out_specs=P("shard")))
        else:
            def stacked_pf(*args):
                self._hook(("prefilter",))
                arrs, (reads, lens) = args[:-2], args[-2:]
                return jax.vmap(
                    lambda *rows: pf_fn(*rows, reads, lens))(*arrs)

            self._pf = jax.jit(stacked_pf)

            def make_stage(n_cap):
                stage = partial(_stage_one_shard, n_cap=n_cap,
                                static=static)

                def stacked_stage(*args):
                    self._hook((n_cap,))
                    arrs, (reads, lens, pf) = args[:-3], args[-3:]
                    return jax.vmap(
                        lambda *rows: stage(*rows[:-1], reads, lens,
                                            rows[-1]))(*arrs, pf)

                return jax.jit(stacked_stage)

        self._make_stage = make_stage
        self._stages: dict[int, object] = {}

        def align_stage(merged: CandidateStageResult, reads, lens):
            self._hook(("align",))
            return align_winners(merged, reads, lens, cfg=cfg, p_cap=p_cap,
                                 backend=self.backend, block_bt=block_bt)

        self._align = jax.jit(align_stage)
        self.last_stats: dict = {}
        # (stage, t0, t1, attrs) monotonic windows from the last call —
        # the serve engine replays them as child spans of its flush span
        self.last_times: list[tuple[str, float, float, dict]] = []

    def _stage_for(self, n_cap: int):
        fn = self._stages.get(n_cap)
        if fn is None:
            fn = self._stages[n_cap] = self._make_stage(n_cap)
        return fn

    @staticmethod
    def merge(st: CandidateStageResult) -> CandidateStageResult:
        """Host merge: lexicographic ``(distance, origin, tile)`` per read.

        Identical windows duplicated across neighbouring shards'
        overlap regions collapse because their full sort key (and the
        window bytes behind it) are equal.
        """
        d = np.asarray(st.distance)
        origin = np.asarray(st.origin)
        tile = np.asarray(st.tile)
        dm = d.min(axis=0, keepdims=True)
        om = np.where(d == dm, origin, POS_SENTINEL)
        omin = om.min(axis=0, keepdims=True)
        tm = np.where(om == omin, tile, POS_SENTINEL)
        win = tm.argmin(axis=0)
        cols = np.arange(d.shape[1])
        pick = lambda a: np.asarray(a)[win, cols]  # noqa: E731
        return CandidateStageResult(
            distance=pick(st.distance), origin=pick(st.origin),
            tile=pick(st.tile), gwin=pick(st.gwin), bwin=pick(st.bwin),
            t_len=pick(st.t_len), prefilter_ok=pick(st.prefilter_ok))

    def __call__(self, arrays: GraphShardArrays, reads, read_lens
                 ) -> GraphMapResult:
        """Map one batch: screen → rung-compacted scatter → merge → align."""
        reads = jnp.asarray(reads)
        lens = jnp.asarray(read_lens, jnp.int32)
        b = int(reads.shape[0])
        slots = b * self.shard_candidates
        c_pf = ("prefilter",) not in self._compiled
        t0 = time.monotonic()
        pf = self._pf(*arrays, reads, lens)  # leaves [S, B, ...]
        n_keep = np.asarray(pf.n_keep)  # [S, B]; host sync ends prefilter
        t1 = time.monotonic()
        kept = int(n_keep.sum())
        live = int(np.asarray(pf.n_live).sum())
        # one rung for all shards: the worst shard's survivor count
        n_cap = tile_rung(int(n_keep.sum(axis=1).max()), slots)
        self.last_stats = dict(
            candidate_slots=self.num_shards * slots, tiles_live=live,
            tiles_kept=kept, tiles_pruned=live - kept,
            dc_rows=self.num_shards * n_cap,
            dc_rows_dense=self.num_shards * slots,
            reads_zero_survivor=int((n_keep.sum(axis=0) == 0).sum()))
        self.last_times = [("prefilter", t0, t1, {"compile": c_pf,
                                                  "shards": self.num_shards})]
        if n_cap == 0:
            return jax.tree_util.tree_map(
                np.asarray, unmapped_result(b, cfg=self.cfg,
                                            p_cap=self.p_cap))
        c_dc = (n_cap,) not in self._compiled
        c_al = ("align",) not in self._compiled
        t2 = time.monotonic()
        st = self._stage_for(n_cap)(*arrays, reads, lens, pf)
        jax.block_until_ready(st)
        t3 = time.monotonic()
        merged = self.merge(st)
        t4 = time.monotonic()
        res = self._align(jax.tree.map(jnp.asarray, merged), reads, lens)
        res = jax.tree_util.tree_map(np.asarray, res)
        t5 = time.monotonic()
        self.last_times += [
            ("dc_filter", t2, t3,
             {"compile": c_dc, "dc_rows": self.num_shards * n_cap}),
            ("merge", t3, t4, {}),
            ("align", t4, t5, {"compile": c_al})]
        return res


# bounded LRU, mirroring shard.mapper: refresh() cycles must not leak
# compiled executors
_EXECUTORS: OrderedDict[tuple, ShardedGraphMapExecutor] = OrderedDict()
_EXECUTOR_CACHE_CAP = 8


def get_graph_executor(
    sharded: ShardedGraphIndex,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    prefilter: bool | None = None,
) -> ShardedGraphMapExecutor:
    """Cached :class:`ShardedGraphMapExecutor` per (geometry, params)."""
    prefilter = _env_prefilter(prefilter)
    key = (sharded.layout_key, cfg, p_cap, filter_bits, filter_k,
           shard_candidates, backend, block_bt, force_vmap, prefilter)
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = ShardedGraphMapExecutor(
            sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
            filter_k=filter_k, shard_candidates=shard_candidates,
            backend=backend, block_bt=block_bt, force_vmap=force_vmap,
            prefilter=prefilter)
        _EXECUTORS[key] = ex
        while len(_EXECUTORS) > _EXECUTOR_CACHE_CAP:
            _EXECUTORS.popitem(last=False)
    else:
        _EXECUTORS.move_to_end(key)
    return ex


def map_batch_sharded_graph(
    sharded: ShardedGraphIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    prefilter: bool | None = None,
) -> GraphMapResult:
    """Map a read batch against a sharded variation-graph index.

    Returns the same :class:`repro.graph.mapper.GraphMapResult` (numpy
    leaves) as the single-device `graph.mapper.map_batch` —
    byte-identical positions, CIGARs, and GAF node paths for any shard
    count, with the q-gram tile screen on or off.  Executors are cached
    per (geometry, parameters).
    """
    ex = get_graph_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, block_bt=block_bt, force_vmap=force_vmap,
        prefilter=prefilter)
    return ex(sharded.arrays, reads, read_lens)
