"""Shard-parallel graph mapping: the GAF twin of `shard.mapper`.

Same three-beat pipeline as the linear sharded mapper — scatter the
read batch to every graph shard, merge per-shard winners on the host,
one batched graph ``align_batch`` call — with the per-shard stage being
`repro.graph.mapper.graph_candidate_stage` over that shard's
:class:`~repro.graph.mapper.GraphView` (local tile/backbone slices,
global ids).  The winner rule is the lexicographic
``min (filter distance, origin node, tile)`` in global coordinates, the
same rule the whole-graph mapper applies across its candidate axis, so
GAF output is byte-identical at 1 and N shards.  Winners travel with
their packed window bytes *and* per-node backbone coordinates
(``bwin``), so the align stage needs no graph arrays at all.
"""
from __future__ import annotations

from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.core.mapper import POS_SENTINEL
from repro.dist import sharding as dist_sharding
from repro.graph.mapper import (CandidateStageResult, GraphMapResult,
                                GraphView, align_winners,
                                graph_backend_name, graph_candidate_stage)

from .graph_partition import GraphShardArrays, ShardedGraphIndex


def validate_graph_geometry(sharded: ShardedGraphIndex, *, p_cap: int,
                            filter_k: int, cfg: GenASMConfig) -> None:
    """Raise if the tile/halo geometry cannot cover this mapping setup."""
    from repro.core.segram.graph import HOP_LIMIT

    t_cap = p_cap + 2 * cfg.w
    span = sharded.tile_len - t_cap
    if span < sharded.tile_stride:
        raise ValueError(
            f"tile_len {sharded.tile_len} leaves a {span}-node anchor "
            f"search span < tile_stride {sharded.tile_stride} at p_cap "
            f"{p_cap}; rebuild the index with window >= {t_cap}")
    need = p_cap + 32 + HOP_LIMIT + filter_k
    if sharded.layout.halo < need:
        raise ValueError(
            f"graph shard halo {sharded.layout.halo} < {need} required "
            f"for p_cap={p_cap}, filter_k={filter_k}; rebuild with "
            f"halo >= {need}")


def _stage_one_shard(tiles, tvalid, tbase, nob, nboff, bb, nbase, hashes,
                     poss, reads, lens, *, static):
    """One graph shard's candidate stage over the whole read batch."""
    view = GraphView(
        tile_gtext=tiles, tile_valid=tvalid, tile_base=tbase,
        node_of_backbone=nob, nb_offset=nboff, backbone=bb,
        node_base=nbase, idx_hashes=hashes, idx_positions=poss)
    return graph_candidate_stage(view, reads, lens, **static)


class ShardedGraphMapExecutor:
    """Compiled scatter/merge/align pipeline for one sharded graph index.

    Mirrors `shard.mapper.ShardedMapExecutor`: a ``shard_map`` (or
    stacked ``vmap``) candidate stage, a host lexicographic merge, and
    one jitted graph-align stage producing
    :class:`repro.graph.mapper.GraphMapResult`.
    """

    def __init__(self, sharded: ShardedGraphIndex, *,
                 cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 shard_candidates: int = 4,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 force_vmap: bool = False,
                 trace_hook=None):
        validate_graph_geometry(sharded, p_cap=p_cap, filter_k=filter_k,
                                cfg=cfg)
        self.num_shards = sharded.num_shards
        self.backend = graph_backend_name(backend)
        t_cap = p_cap + 2 * cfg.w
        static = dict(
            tile_stride=sharded.tile_stride, n_tiles=sharded.n_tiles,
            backbone_len=sharded.ref_len, n_nodes=sharded.n_nodes,
            t_cap=t_cap, filter_bits=min(filter_bits, p_cap),
            filter_k=filter_k, max_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w,
            minimizer_k=sharded.minimizer_k,
            use_kernel=False, block_bt=block_bt, interpret=True)
        stage = partial(_stage_one_shard, static=static)

        mesh = None if force_vmap else dist_sharding.shard_mesh(
            self.num_shards)
        self.spmd = mesh is not None
        if self.spmd:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            arr_specs = tuple(dist_sharding.stacked_specs(
                sharded.arrays, mesh))

            def block_stage(*args):
                arrs, (reads, lens) = args[:-2], args[-2:]
                out = stage(*[a[0] for a in arrs], reads, lens)
                return jax.tree.map(lambda x: x[None], out)

            self._stage = jax.jit(shard_map(
                block_stage, mesh=mesh,
                in_specs=arr_specs + (P(), P()),
                out_specs=P("shard")))
        else:
            def stacked_stage(*args):
                arrs, (reads, lens) = args[:-2], args[-2:]
                return jax.vmap(
                    lambda *rows: stage(*rows, reads, lens))(*arrs)

            self._stage = jax.jit(stacked_stage)

        def align_stage(merged: CandidateStageResult, reads, lens):
            if trace_hook is not None:
                trace_hook()
            return align_winners(merged, reads, lens, cfg=cfg, p_cap=p_cap,
                                 backend=self.backend, block_bt=block_bt)

        self._align = jax.jit(align_stage)

    def stage(self, arrays: GraphShardArrays, reads, read_lens
              ) -> CandidateStageResult:
        """Run the scatter stage: ``[S, B, ...]`` per-shard winners."""
        return self._stage(*arrays, jnp.asarray(reads),
                           jnp.asarray(read_lens, jnp.int32))

    @staticmethod
    def merge(st: CandidateStageResult) -> CandidateStageResult:
        """Host merge: lexicographic ``(distance, origin, tile)`` per read.

        Identical windows duplicated across neighbouring shards'
        overlap regions collapse because their full sort key (and the
        window bytes behind it) are equal.
        """
        d = np.asarray(st.distance)
        origin = np.asarray(st.origin)
        tile = np.asarray(st.tile)
        dm = d.min(axis=0, keepdims=True)
        om = np.where(d == dm, origin, POS_SENTINEL)
        omin = om.min(axis=0, keepdims=True)
        tm = np.where(om == omin, tile, POS_SENTINEL)
        win = tm.argmin(axis=0)
        cols = np.arange(d.shape[1])
        pick = lambda a: np.asarray(a)[win, cols]  # noqa: E731
        return CandidateStageResult(
            distance=pick(st.distance), origin=pick(st.origin),
            tile=pick(st.tile), gwin=pick(st.gwin), bwin=pick(st.bwin),
            t_len=pick(st.t_len), prefilter_ok=pick(st.prefilter_ok))

    def __call__(self, arrays: GraphShardArrays, reads, read_lens
                 ) -> GraphMapResult:
        """Map one batch: scatter → merge → single graph align call."""
        st = self.stage(arrays, reads, read_lens)
        merged = self.merge(st)
        res = self._align(
            jax.tree.map(jnp.asarray, merged), jnp.asarray(reads),
            jnp.asarray(read_lens, jnp.int32))
        return jax.tree_util.tree_map(np.asarray, res)


# bounded LRU, mirroring shard.mapper: refresh() cycles must not leak
# compiled executors
_EXECUTORS: OrderedDict[tuple, ShardedGraphMapExecutor] = OrderedDict()
_EXECUTOR_CACHE_CAP = 8


def get_graph_executor(
    sharded: ShardedGraphIndex,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
) -> ShardedGraphMapExecutor:
    """Cached :class:`ShardedGraphMapExecutor` per (geometry, params)."""
    key = (sharded.layout_key, cfg, p_cap, filter_bits, filter_k,
           shard_candidates, backend, block_bt, force_vmap)
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = ShardedGraphMapExecutor(
            sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
            filter_k=filter_k, shard_candidates=shard_candidates,
            backend=backend, block_bt=block_bt, force_vmap=force_vmap)
        _EXECUTORS[key] = ex
        while len(_EXECUTORS) > _EXECUTOR_CACHE_CAP:
            _EXECUTORS.popitem(last=False)
    else:
        _EXECUTORS.move_to_end(key)
    return ex


def map_batch_sharded_graph(
    sharded: ShardedGraphIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
) -> GraphMapResult:
    """Map a read batch against a sharded variation-graph index.

    Returns the same :class:`repro.graph.mapper.GraphMapResult` (numpy
    leaves) as the single-device `graph.mapper.map_batch` —
    byte-identical positions, CIGARs, and GAF node paths for any shard
    count.  Executors are cached per (geometry, parameters).
    """
    ex = get_graph_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, block_bt=block_bt, force_vmap=force_vmap)
    return ex(sharded.arrays, reads, read_lens)
