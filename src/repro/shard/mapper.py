"""Shard-parallel linear mapping: scatter reads, merge candidates, align.

Execution plan per flush (the dissertation's channel dataflow, DESIGN.md
§11):

1. **Scatter** — the read batch is broadcast to every shard; each shard
   seeds against its own minimizer-table slice and GenASM-DC-filters its
   own ``shard_candidates`` best diagonals, entirely inside its haloed
   reference slice.  This stage runs under ``shard_map`` over a
   ``("shard",)`` mesh (specs from `repro.dist.sharding.stacked_specs`)
   when enough devices exist, else under a ``vmap`` over the stacked
   shard axis — the two lower to the same math, so results are
   bit-identical.
2. **Merge** — per-shard winners carry *global* (filter distance,
   refined position) pairs plus their ``[t_cap]`` alignment window
   bytes; a device argmin-reduce on the packed monotone uint64
   ``(distance, position)`` key (`repro.shard.merge`) picks the
   lexicographic minimum per read *without leaving the device* — the
   host lex merge survives only as the reference implementation
   (``merge_host``) for the differential suite and chaos drills.
   Windows in overlap halos are byte-identical across neighbouring
   shards, so duplicated boundary candidates dedup by construction.
3. **Align** — one batched `repro.align.align_batch` call on the
   winning windows (any registered backend); no stage after the merge
   touches the sharded reference.  With ``align_sharded=True`` the
   batch is round-robin split into ``[S, B/S]`` blocks and aligned
   under the same shard mesh (``dist.sharding.stacked_specs`` layout);
   per-read results are independent, so the split is bit-neutral.

The executor also exposes a two-phase ``start()``/``finish()`` surface:
``start`` dispatches scatter → device merge → align and returns a
:class:`PendingBatch` of device-resident results without blocking on
the align program, ``finish`` materializes it.  The serve engine's
``pipelined`` mode uses this to overlap batch *i*'s align against
batch *i+1*'s scatter (double buffering); ``__call__`` is simply
``finish(start(...))`` with per-stage timing in between.

The per-shard stage calls `repro.core.mapper.seed_filter_read` — the
*same* function the single-device mapper runs with offset 0 — which is
what makes ``num_shards=1`` vs ``N`` PAF output byte-identical.

Identity caveat: per-shard seeding keeps each shard's top
``shard_candidates`` diagonals *by local vote count*, so the merged
candidate set is guaranteed to contain the single-device winner only
while that winner ranks within ``shard_candidates`` in its owning
shard's table.  Real reads satisfy this easily (the true diagonal
dominates local voting, even split across a cut — pinned by the golden
and boundary suites); a highly repetitive reference combined with a
reduced per-shard budget (``shard_candidates < max_candidates``, the
throughput configuration) can in principle evict it.  Serve with the
full per-shard budget when byte-stability across re-sharding is a hard
requirement.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapper as core_mapper
from repro.core.genasm import GenASMConfig
from repro.core.mapper import MapResult, POS_SENTINEL
from repro.dist import sharding as dist_sharding

from . import merge as shard_merge
from .partition import ShardArrays, ShardedIndex


class ShardStageResult(NamedTuple):
    """Per-(shard, read) winner of the scatter stage, global coordinates."""

    distance: jnp.ndarray  # [S, B] int32 filter distance (filter_k+1 = none)
    position: jnp.ndarray  # [S, B] int32 refined global start (sentinel=none)
    text: jnp.ndarray  # [S, B, t_cap] int8 alignment window at position
    t_len: jnp.ndarray  # [S, B] int32 valid window length


class PendingBatch(NamedTuple):
    """In-flight batch from ``start()``: device results + closed spans.

    ``res`` holds the executor's result tree with *device* leaves (the
    align program may still be running); ``times`` the already-closed
    ``(stage, t0, t1, attrs)`` windows; ``tail`` the name/attrs of the
    span ``finish()`` will close from ``t_dispatch`` to materialization
    (None when the result is already host-resident, e.g. the graph
    zero-survivor short-circuit); ``stats`` the graph executors'
    ``last_stats`` payload (None for linear).
    """

    res: object
    times: tuple
    t_dispatch: float
    tail: tuple | None  # (stage_name, attrs)
    stats: dict | None = None


def required_halo(*, p_cap: int, filter_bits: int, filter_k: int,
                  t_cap: int) -> int:
    """Smallest overlap halo that loses no boundary mapping.

    Left of a core: a candidate diagonal seeded by an entry at the core
    boundary can start up to ``p_cap`` bases earlier (read-relative
    seed offset) plus 32 bases of diagonal-bucket rounding, and the
    filter reads ``margin = filter_k + 32`` bases of drift before it.
    Right of a core: the filter region extends ``filter_bits + margin``
    past the candidate and the refined anchor needs ``t_cap`` bases of
    alignment text after it.
    """
    margin = filter_k + 32
    left = p_cap + 32 + margin
    right = filter_bits + 2 * margin + t_cap
    return max(left, right)


def validate_geometry(sharded: ShardedIndex, *, p_cap: int, filter_bits: int,
                      filter_k: int, t_cap: int) -> None:
    """Raise if the layout's halo cannot cover this mapping geometry."""
    need = required_halo(p_cap=p_cap, filter_bits=filter_bits,
                         filter_k=filter_k, t_cap=t_cap)
    if sharded.layout.halo < need:
        raise ValueError(
            f"shard halo {sharded.layout.halo} < {need} required for "
            f"p_cap={p_cap}, filter_bits={filter_bits}, "
            f"filter_k={filter_k}, t_cap={t_cap}; rebuild the sharded "
            f"index with halo >= {need}")


def _stage_one_shard(ref_row, off_row, hash_row, pos_row, reads, read_lens,
                     *, ref_len, p_cap, t_cap, filter_bits, filter_k,
                     shard_candidates, minimizer_w, minimizer_k):
    """Seed + filter the whole read batch against one shard's slice."""
    f = partial(
        core_mapper.seed_filter_read, ref_row, off_row, ref_len,
        hash_row, pos_row, p_cap=p_cap, t_cap=t_cap,
        filter_bits=filter_bits, filter_k=filter_k,
        max_candidates=shard_candidates, minimizer_w=minimizer_w,
        minimizer_k=minimizer_k)
    sf = jax.vmap(f)(reads, read_lens)
    return sf.distance, sf.position, sf.text, sf.t_len


class ShardedMapExecutor:
    """Compiled scatter/merge/align pipeline for one sharded geometry.

    Holds three jitted programs — the shard stage (``shard_map`` over a
    shard mesh when ``jax.device_count() >= num_shards``, else a
    stacked ``vmap``), the packed-key device merge
    (`repro.shard.merge.merge_linear` under an x64 scope), and the
    align stage (optionally sharded over the same mesh).  Construct
    once per (index geometry, mapping parameters) and call with
    ``(ShardArrays, reads, lens)``; the serve engine caches executors
    exactly like its single-device ones.
    """

    def __init__(self, sharded: ShardedIndex, *,
                 cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 shard_candidates: int = 4,
                 minimizer_w: int | None = None,
                 minimizer_k: int | None = None,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 force_vmap: bool = False,
                 align_sharded: bool = False,
                 trace_hook=None):
        t_cap = p_cap + 2 * cfg.w
        filter_bits = min(filter_bits, p_cap)
        validate_geometry(sharded, p_cap=p_cap, filter_bits=filter_bits,
                          filter_k=filter_k, t_cap=t_cap)
        self.num_shards = sharded.num_shards
        self.filter_k = filter_k
        self.backend = backend
        self.align_sharded = align_sharded
        user_hook = trace_hook
        self._compiled: set = set()  # stage keys that have traced

        def hook(key):
            self._compiled.add(key)
            if user_hook is None:
                return
            try:
                user_hook(key)
            except TypeError:  # legacy no-arg hooks
                user_hook()

        stage = partial(
            _stage_one_shard,
            ref_len=sharded.ref_len, p_cap=p_cap, t_cap=t_cap,
            filter_bits=filter_bits, filter_k=filter_k,
            shard_candidates=shard_candidates,
            minimizer_w=sharded.minimizer_w if minimizer_w is None
            else minimizer_w,
            minimizer_k=sharded.minimizer_k if minimizer_k is None
            else minimizer_k)

        mesh = None if force_vmap else dist_sharding.shard_mesh(
            self.num_shards)
        self.spmd = mesh is not None
        if self.spmd:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            arr_specs = tuple(dist_sharding.stacked_specs(
                sharded.arrays, mesh))

            def block_stage(refs, offs, hashes, poss, reads, lens):
                hook(("scatter",))
                out = stage(refs[0], offs[0], hashes[0], poss[0], reads, lens)
                return jax.tree.map(lambda x: x[None], out)

            self._stage = jax.jit(shard_map(
                block_stage, mesh=mesh,
                in_specs=arr_specs + (P(), P()),
                out_specs=P("shard")))
        else:
            def stacked_stage(refs, offs, hashes, poss, reads, lens):
                hook(("scatter",))
                return jax.vmap(
                    lambda r, o, h, p: stage(r, o, h, p, reads, lens)
                )(refs, offs, hashes, poss)

            self._stage = jax.jit(stacked_stage)

        def align_core(text, reads, lens, t_len, pos, fd):
            from repro import align as align_dispatch

            lens = lens.astype(jnp.int32)
            pat = jnp.where(jnp.arange(p_cap)[None, :] < lens[:, None],
                            reads[:, :p_cap], core_mapper.WILDCARD
                            ).astype(jnp.int8)
            res = align_dispatch.align_batch(
                text, pat, lens, t_len, cfg=cfg, backend=backend,
                p_cap=p_cap, block_bt=block_bt)
            failed = res.failed | (fd > filter_k)
            return MapResult(
                position=jnp.where(failed, -1, pos).astype(jnp.int32),
                distance=jnp.where(failed, -1, res.distance),
                ops=res.ops, n_ops=res.n_ops, failed=failed)

        def align_stage(text, reads, lens, t_len, pos, fd):
            hook(("align",))
            return align_core(text, reads, lens, t_len, pos, fd)

        s = self.num_shards

        def align_stage_sharded(text, reads, lens, t_len, pos, fd):
            # round-robin split of the merged winners into [S, B/S]
            # blocks on the shard mesh; per-read results are
            # independent, so the split (and its padding) is bit-neutral
            hook(("align_shard",))
            b = text.shape[0]
            bs = -(-b // s)  # rows per shard, last block zero-padded

            def blocked(x):
                x = jnp.pad(x, ((0, bs * s - b),)
                            + ((0, 0),) * (x.ndim - 1))
                return x.reshape((s, bs) + x.shape[1:])

            args = tuple(blocked(x)
                         for x in (text, reads, lens, t_len, pos, fd))
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P

                def block(*rows):
                    out = align_core(*[r[0] for r in rows])
                    return jax.tree.map(lambda y: y[None], out)

                out = shard_map(block, mesh=mesh,
                                in_specs=(P("shard"),) * 6,
                                out_specs=P("shard"))(*args)
            else:
                out = jax.vmap(align_core)(*args)
            return jax.tree.map(
                lambda y: y.reshape((bs * s,) + y.shape[2:])[:b], out)

        self._align = jax.jit(
            align_stage_sharded if align_sharded else align_stage)
        self._align_stage_name = ("align_shard" if align_sharded
                                  else "align")
        # packed-key argmin-reduce: winners picked on device, only the
        # [B]-sized merged rows ever needed by the align launch
        self._merge = jax.jit(shard_merge.merge_linear)
        # the argmin collapses the shard axis but leaves its outputs
        # replicated across the mesh; a full-batch align traced on
        # replicated operands re-runs on every device, so the tiny
        # merged rows are committed to one device first.  A mesh-split
        # align partitions the work itself and must see mesh-addressable
        # inputs, so it keeps them replicated.
        self._off_mesh = (None if mesh is None or align_sharded
                          else mesh.devices.flat[0])
        # (stage, t0, t1, attrs) monotonic windows from the last call —
        # the serve engine replays them as child spans of its flush span
        self.last_times: list[tuple[str, float, float, dict]] = []

    def stage(self, arrays: ShardArrays, reads, read_lens
              ) -> ShardStageResult:
        """Run the scatter stage: per-shard winners for the whole batch."""
        fd, pos, text, t_len = self._stage(
            arrays.refs, arrays.offsets, arrays.hashes, arrays.positions,
            jnp.asarray(reads), jnp.asarray(read_lens, jnp.int32))
        return ShardStageResult(distance=fd, position=pos, text=text,
                                t_len=t_len)

    @staticmethod
    def merge_host(stage: ShardStageResult):
        """Reference host merge: lex-min ``(distance, position)`` per read.

        The pre-device-merge implementation, kept as the independently
        coded oracle for the differential suite
        (``tests/test_shard_merge.py``) — the packed-key argmin must
        match it bit for bit, including the low-shard tie-break.
        Overlap-halo duplicates carry identical (distance, position,
        window bytes) in both neighbouring shards, so whichever copy
        argmin lands on yields the same alignment — dedup for free.
        Returns ``(fd, pos, text, t_len, winner_shard)`` numpy arrays.
        """
        fd = np.asarray(stage.distance)
        pos = np.asarray(stage.position)
        m = fd.min(axis=0)
        pm = np.where(fd == m[None, :], pos, POS_SENTINEL)
        win = pm.argmin(axis=0)
        cols = np.arange(fd.shape[1])
        return (m, pm[win, cols], np.asarray(stage.text)[win, cols],
                np.asarray(stage.t_len)[win, cols], win)

    # chaos drills (failover.py) and older callers used ``ex.merge``
    merge = merge_host

    def merge_device(self, stage: ShardStageResult):
        """Packed-key argmin-reduce on device; winners stay device-resident.

        Returns ``(fd, pos, text, t_len, winner_shard)`` as jax arrays —
        same contract and tie-break as `merge_host`, no host round trip.
        """
        with shard_merge.x64_scope():
            out = self._merge(stage.distance, stage.position,
                              stage.text, stage.t_len)
        if self._off_mesh is not None:
            out = jax.device_put(out, self._off_mesh)
        return out

    def start(self, arrays: ShardArrays, reads, read_lens, *,
              timed: bool = True) -> PendingBatch:
        """Dispatch scatter → device merge → align without materializing.

        The returned :class:`PendingBatch` holds device-resident
        results; `finish` blocks and converts.  ``timed=False`` skips
        the inter-stage ``block_until_ready`` syncs (and their spans) —
        the lowest-overhead dispatch for pipelined serving, where
        per-stage attribution is sacrificed for overlap.
        """
        c_sc = ("scatter",) not in self._compiled
        align_key = (self._align_stage_name,)
        c_al = align_key not in self._compiled
        times: list[tuple[str, float, float, dict]] = []
        t0 = time.monotonic()
        st = self.stage(arrays, reads, read_lens)
        if timed:
            jax.block_until_ready(st)
            t1 = time.monotonic()
            times.append(("scatter", t0, t1,
                          {"compile": c_sc, "shards": self.num_shards}))
        fd, pos, text, t_len, _win = self.merge_device(st)
        if timed:
            jax.block_until_ready(fd)
            t2 = time.monotonic()
            times.append(("merge_device", t1, t2,
                          {"shards": self.num_shards}))
        else:
            t2 = time.monotonic()
        res = self._align(text, jnp.asarray(reads),
                          jnp.asarray(read_lens, jnp.int32),
                          t_len, pos, fd)
        return PendingBatch(res=res, times=tuple(times), t_dispatch=t2,
                            tail=(self._align_stage_name,
                                  {"compile": c_al,
                                   "sharded": self.align_sharded}))

    @staticmethod
    def finish(pending: PendingBatch):
        """Materialize a `start` batch → ``(numpy result, stage times)``."""
        res = jax.tree_util.tree_map(np.asarray, pending.res)
        times = pending.times
        if pending.tail is not None:
            name, attrs = pending.tail
            times = times + ((name, pending.t_dispatch, time.monotonic(),
                              attrs),)
        return res, times

    def __call__(self, arrays: ShardArrays, reads, read_lens) -> MapResult:
        """Map one batch: scatter → device merge → batched align."""
        res, times = self.finish(self.start(arrays, reads, read_lens))
        self.last_times = list(times)
        return res


# bounded LRU: a long-running process whose refresh() cycles through
# reference lengths must not accumulate compiled executors forever
_EXECUTORS: OrderedDict[tuple, ShardedMapExecutor] = OrderedDict()
_EXECUTOR_CACHE_CAP = 8


def get_executor(
    sharded: ShardedIndex,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    align_sharded: bool = False,
) -> ShardedMapExecutor:
    """Cached :class:`ShardedMapExecutor` for one (geometry, params) key.

    Shared by `map_batch_sharded` and `failover.map_batch_with_failover`
    so repeated batches (including degraded-mode retries) never
    recompile; the LRU bound evicts executors of abandoned layouts.
    """
    key = (sharded.layout_key, sharded.minimizer_w, sharded.minimizer_k,
           cfg, p_cap, filter_bits, filter_k, shard_candidates,
           backend, block_bt, force_vmap, align_sharded)
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = ShardedMapExecutor(
            sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
            filter_k=filter_k, shard_candidates=shard_candidates,
            backend=backend, block_bt=block_bt, force_vmap=force_vmap,
            align_sharded=align_sharded)
        _EXECUTORS[key] = ex
        while len(_EXECUTORS) > _EXECUTOR_CACHE_CAP:
            _EXECUTORS.popitem(last=False)
    else:
        _EXECUTORS.move_to_end(key)
    return ex


def map_batch_sharded(
    sharded: ShardedIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    block_bt: int | None = None,
    force_vmap: bool = False,
    align_sharded: bool = False,
    pipelined: bool = False,
) -> MapResult:
    """Map a read batch against a sharded reference index.

    ``reads`` is ``[B, >=p_cap] int8`` with ``read_lens [B]`` valid
    lengths; returns the same :class:`repro.core.mapper.MapResult`
    (numpy leaves) as the single-device `core.mapper.map_batch` —
    byte-identical positions, distances, and CIGARs for any shard
    count, with the align stage sharded or not and through the
    pipelined (``start``/``finish``) dispatch path or the timed one.
    Executors are cached per (geometry, parameters).
    """
    ex = get_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, block_bt=block_bt, force_vmap=force_vmap,
        align_sharded=align_sharded)
    if pipelined:
        res, times = ex.finish(ex.start(sharded.arrays, reads, read_lens,
                                        timed=False))
        ex.last_times = list(times)
        return res
    return ex(sharded.arrays, reads, read_lens)
