"""repro.shard — multi-device reference sharding with scatter/merge.

DESIGN.md §11: the reference (linear or variation graph) is cut into
per-device shards with overlap halos (`partition` / `graph_partition`),
reads scatter to every shard for independent seeding + GenASM-DC
filtering under ``shard_map`` (`mapper` / `graph_mapper`), per-shard
winners reduce **on device** by a packed monotone uint64 key argmin
(`merge`; the host lexicographic rule survives as the differential
oracle ``merge_host``), and the winning-window ``align_batch`` call
finishes the winners — optionally sharded over the same mesh
(``align_sharded``) and dispatched without inter-stage host syncs
through the ``start``/``finish`` pipeline surface (``pipelined``).
`failover` routes the scatter stage through
`repro.dist.fault.WorkQueue` leases so a lost shard re-queues instead
of dropping reads.  Output is byte-identical to the single-device
mappers at any shard count.
"""
from . import merge
from .failover import map_batch_with_failover, map_batch_with_failover_graph
from .graph_mapper import (ShardedGraphMapExecutor, get_graph_executor,
                           map_batch_sharded_graph)
from .graph_partition import (EpochedShardedGraphIndex, GraphShardArrays,
                              ShardedGraphIndex, from_epoched_graph,
                              shard_graph_index)
from .mapper import (PendingBatch, ShardedMapExecutor, get_executor,
                     map_batch_sharded, required_halo, validate_geometry)
from .partition import (DEFAULT_HALO, EpochedShardedIndex, ShardArrays,
                        ShardLayout, ShardedIndex, build_sharded_index,
                        from_epoched, plan_layout)

__all__ = [
    "DEFAULT_HALO", "EpochedShardedGraphIndex", "EpochedShardedIndex",
    "GraphShardArrays", "PendingBatch", "ShardArrays", "ShardLayout",
    "ShardedGraphIndex", "ShardedGraphMapExecutor", "ShardedIndex",
    "ShardedMapExecutor", "build_sharded_index", "from_epoched",
    "from_epoched_graph", "get_executor", "get_graph_executor",
    "map_batch_sharded", "map_batch_sharded_graph",
    "map_batch_with_failover", "map_batch_with_failover_graph", "merge",
    "plan_layout", "required_halo",
    "shard_graph_index", "validate_geometry",
]
