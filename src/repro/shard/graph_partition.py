"""Variation-graph sharding: per-device tile ranges + backbone slices.

The graph twin of `partition.py` (SeGraM §6.5: each channel owns the
sub-graph backing its slice of the linear backbone).  A shard owns a
contiguous *backbone* core range; from it we derive, by pure slicing of
the already-built global `repro.graph.index.GraphIndex` arrays:

* the minimizer-table entries whose (global) backbone positions fall in
  the core;
* a haloed ``node_of_backbone`` slice (candidate backbone coordinate →
  node id);
* the contiguous global **tile** range those nodes map to under
  ``node // tile_stride`` — tiles are sliced from the global
  ``tile_gtext``, so per-tile hop-boundary masks (and therefore window
  bytes) are bit-identical to the whole-graph index;
* the ``backbone`` (node → backbone coordinate) slice covering every
  node of those tiles, shipped so the merged winner's GAF path
  translates without touching any other shard.

Candidates stay in global coordinates end-to-end (global backbone
positions in the table, global tile ids, global origin node ids), so
the merge is a pure lexicographic min — no translation step.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.segram.graph import Variant
from repro.graph.index import EpochedGraphIndex, GraphIndex, build_graph_index

from .partition import (DEFAULT_HALO, ShardLayout, _PAD_HASH, _PAD_POS,
                        plan_layout)


class GraphShardArrays(NamedTuple):
    """Device half of a sharded graph index, stacked ``[S, ...]``.

    Row ``i`` is shard ``i``; all ids/positions are global (tile ids via
    ``tile_base``, node ids via ``node_base``, backbone coordinates via
    ``nb_offset`` — each row's arrays are local slices whose first row
    sits at that global coordinate).
    """

    tile_gtext: jnp.ndarray  # [S, Ct, tile_len] uint32 packed local tiles
    tile_valid: jnp.ndarray  # [S, Ct] int32 valid node count per tile
    tile_base: jnp.ndarray  # [S] int32 global tile id of local row 0
    node_of_backbone: jnp.ndarray  # [S, Lb] int32 backbone→node slice
    nb_offset: jnp.ndarray  # [S] int32 global backbone coord of slice row 0
    backbone: jnp.ndarray  # [S, Nb] int32 node→backbone slice
    node_base: jnp.ndarray  # [S] int32 global node id of slice row 0
    hashes: jnp.ndarray  # [S, Mm] uint32 sorted minimizer hashes
    positions: jnp.ndarray  # [S, Mm] int32 GLOBAL backbone positions
    tile_bloom: jnp.ndarray  # [S, Ct, BLOOM_WORDS] uint32 q-gram Blooms
    tile_slack: jnp.ndarray  # [S, Ct] int32 q-gram-lemma screen slack


@dataclass
class ShardedGraphIndex:
    """Host handle: stacked graph shards + the global geometry statics."""

    arrays: GraphShardArrays
    layout: ShardLayout
    ref: np.ndarray  # host reference copy (GAF tlen, refresh)
    tile_len: int
    tile_stride: int
    n_tiles: int  # global tile count
    n_nodes: int  # global linearized-graph node count
    minimizer_w: int
    minimizer_k: int
    window: int
    margin: int

    @property
    def num_shards(self) -> int:
        """Number of graph shards."""
        return self.layout.num_shards

    @property
    def ref_len(self) -> int:
        """Backbone (linear reference) length in bases."""
        return self.layout.ref_len

    @property
    def layout_key(self) -> tuple:
        """Hashable geometry key (partition + tile pitch + padded dims)."""
        a = self.arrays
        return (self.layout.bounds, self.layout.halo, self.tile_len,
                self.tile_stride, int(a.tile_gtext.shape[1]),
                int(a.node_of_backbone.shape[1]), int(a.backbone.shape[1]),
                int(a.hashes.shape[1]))


def shard_graph_index(gidx: GraphIndex, num_shards: int, *,
                      halo: int = DEFAULT_HALO) -> ShardedGraphIndex:
    """Slice a built ``GraphIndex`` into per-device shards.

    Pure slicing of the global arrays — tiles, hop masks, and minimizer
    entries are exactly the whole-graph ones, which is what keeps the
    sharded mapper's windows byte-identical to the single-device path.
    """
    a = gidx.arrays
    L = int(a.node_of_backbone.shape[0])
    n_tiles = int(a.tile_gtext.shape[0])
    n_nodes = int(a.bases.shape[0])
    layout = plan_layout(L, num_shards, halo)
    nob = np.asarray(a.node_of_backbone)
    g_hash = np.asarray(a.idx_hashes)
    g_pos = np.asarray(a.idx_positions)
    backbone = np.asarray(a.backbone)
    tiles = np.asarray(a.tile_gtext)
    tvalid = np.asarray(a.tile_valid)
    tbloom = np.asarray(a.tile_bloom)
    tslack = np.asarray(a.tile_slack)

    rows = []
    for i in range(num_shards):
        lo, hi = layout.core(i)
        blo, bhi = layout.slice_range(i)
        tlo = int(nob[blo]) // gidx.tile_stride
        thi = min(n_tiles, int(nob[bhi - 1]) // gidx.tile_stride + 1)
        node_lo = tlo * gidx.tile_stride
        node_hi = min(n_nodes, (thi - 1) * gidx.tile_stride + gidx.tile_len)
        m = (g_pos >= lo) & (g_pos < hi)
        rows.append(dict(
            tiles=tiles[tlo:thi], tvalid=tvalid[tlo:thi], tile_base=tlo,
            nob=nob[blo:bhi], nb_offset=blo,
            backbone=backbone[node_lo:node_hi], node_base=node_lo,
            hashes=g_hash[m], positions=g_pos[m],
            tbloom=tbloom[tlo:thi], tslack=tslack[tlo:thi]))

    s = num_shards
    ct = max(len(r["tiles"]) for r in rows)
    lb = max(len(r["nob"]) for r in rows)
    nb = max(len(r["backbone"]) for r in rows)
    mm = max(1, max(len(r["hashes"]) for r in rows))
    tile_len = gidx.tile_len
    st_tiles = np.zeros((s, ct, tile_len), np.uint32)
    st_tvalid = np.zeros((s, ct), np.int32)
    st_nob = np.zeros((s, lb), np.int32)
    st_bb = np.full((s, nb), -1, np.int32)
    st_hash = np.full((s, mm), _PAD_HASH, np.uint32)
    st_pos = np.full((s, mm), _PAD_POS, np.int32)
    st_bloom = np.zeros((s, ct, tbloom.shape[-1]), np.uint32)
    st_slack = np.zeros((s, ct), np.int32)
    tile_base = np.zeros(s, np.int32)
    nb_offset = np.zeros(s, np.int32)
    node_base = np.zeros(s, np.int32)
    for i, r in enumerate(rows):
        st_tiles[i, : len(r["tiles"])] = r["tiles"]
        st_tvalid[i, : len(r["tvalid"])] = r["tvalid"]
        st_nob[i, : len(r["nob"])] = r["nob"]
        st_bb[i, : len(r["backbone"])] = r["backbone"]
        st_hash[i, : len(r["hashes"])] = r["hashes"]
        st_pos[i, : len(r["positions"])] = r["positions"]
        st_bloom[i, : len(r["tbloom"])] = r["tbloom"]
        st_slack[i, : len(r["tslack"])] = r["tslack"]
        tile_base[i] = r["tile_base"]
        nb_offset[i] = r["nb_offset"]
        node_base[i] = r["node_base"]
    arrays = GraphShardArrays(
        tile_gtext=jnp.asarray(st_tiles), tile_valid=jnp.asarray(st_tvalid),
        tile_base=jnp.asarray(tile_base), node_of_backbone=jnp.asarray(st_nob),
        nb_offset=jnp.asarray(nb_offset), backbone=jnp.asarray(st_bb),
        node_base=jnp.asarray(node_base), hashes=jnp.asarray(st_hash),
        positions=jnp.asarray(st_pos), tile_bloom=jnp.asarray(st_bloom),
        tile_slack=jnp.asarray(st_slack))
    return ShardedGraphIndex(
        arrays=arrays, layout=layout, ref=np.asarray(gidx.ref, np.int8),
        tile_len=tile_len, tile_stride=gidx.tile_stride, n_tiles=n_tiles,
        n_nodes=n_nodes, minimizer_w=gidx.minimizer_w,
        minimizer_k=gidx.minimizer_k, window=gidx.window, margin=gidx.margin)


class EpochedShardedGraphIndex:
    """Epoch-vector-stamped handle around a ``ShardedGraphIndex``.

    Mirrors `partition.EpochedShardedIndex`: ``refresh()`` rebuilds the
    graph from a new reference/variant set (all epochs bump);
    ``refresh_shard(i)`` re-slices shard ``i`` from the retained host
    ``GraphIndex`` (failover re-materialization, epoch ``i`` bumps).
    ``current()`` returns the hashable ``(layout_key, epoch vector)``
    token the serve cache keys on.
    """

    def __init__(self, sharded: ShardedGraphIndex, source: GraphIndex, *,
                 variants: Sequence[Variant] = (),
                 epochs: Sequence[int] | None = None):
        self._lock = threading.Lock()
        self._index = sharded
        self._source = source
        self._variants = tuple(variants)
        self.epochs = list(epochs) if epochs is not None \
            else [0] * sharded.num_shards
        if len(self.epochs) != sharded.num_shards:
            raise ValueError(
                f"epoch vector has {len(self.epochs)} entries for "
                f"{sharded.num_shards} shards")
        self._build_kw = dict(
            w=sharded.minimizer_w, k=sharded.minimizer_k,
            tile_stride=sharded.tile_stride, window=sharded.window,
            margin=sharded.margin)
        self._halo = sharded.layout.halo

    @property
    def index(self) -> ShardedGraphIndex:
        """The current ``ShardedGraphIndex`` (unsynchronized peek)."""
        return self._index

    def epoch_token(self) -> tuple:
        """Hashable (layout, epoch-vector) cache-key component."""
        with self._lock:
            return (self._index.layout_key, tuple(self.epochs))

    def current(self) -> tuple[ShardedGraphIndex, tuple]:
        """Consistent (index, epoch token) pair for one mapping batch."""
        with self._lock:
            return self._index, (self._index.layout_key, tuple(self.epochs))

    def refresh(self, ref: np.ndarray,
                variants: Sequence[Variant] | None = None,
                **build_kw) -> tuple:
        """Rebuild graph + shards from a new reference; bumps all epochs."""
        kw = {**self._build_kw, **build_kw}
        vs = self._variants if variants is None else tuple(variants)
        source = build_graph_index(ref, vs, **kw)
        new = shard_graph_index(source, self._index.num_shards,
                                halo=self._halo)
        with self._lock:
            self._index = new
            self._source = source
            self._variants = vs
            self._build_kw = kw
            self.epochs = [e + 1 for e in self.epochs]
            return (new.layout_key, tuple(self.epochs))

    def refresh_shard(self, i: int) -> tuple:
        """Re-slice shard ``i`` from the retained host graph index."""
        if not 0 <= i < self._index.num_shards:
            raise IndexError(f"shard {i} out of range "
                             f"(num_shards={self._index.num_shards})")
        fresh = shard_graph_index(self._source, self._index.num_shards,
                                  halo=self._halo)
        a, f = self._index.arrays, fresh.arrays
        with self._lock:
            self._index = ShardedGraphIndex(
                arrays=GraphShardArrays(*[
                    cur.at[i].set(new[i]) for cur, new in zip(a, f)]),
                layout=self._index.layout, ref=self._index.ref,
                tile_len=self._index.tile_len,
                tile_stride=self._index.tile_stride,
                n_tiles=self._index.n_tiles, n_nodes=self._index.n_nodes,
                minimizer_w=self._index.minimizer_w,
                minimizer_k=self._index.minimizer_k,
                window=self._index.window, margin=self._index.margin)
            self.epochs[i] += 1
            return (self._index.layout_key, tuple(self.epochs))


def from_epoched_graph(egi: EpochedGraphIndex | GraphIndex, num_shards: int,
                       *, halo: int = DEFAULT_HALO
                       ) -> EpochedShardedGraphIndex:
    """Shard an existing (epoched) graph index, reusing its built arrays."""
    if isinstance(egi, EpochedGraphIndex):
        gidx = egi.index
        variants = egi._variants
    else:
        gidx = egi
        variants = ()
    return EpochedShardedGraphIndex(
        shard_graph_index(gidx, num_shards, halo=halo), gidx,
        variants=variants)
