"""Shard failover: lease-routed scatter + align so a lost shard re-queues.

The serve path normally launches the scatter stage as one program over
all shards; this module is the degraded-mode driver for when shards can
*fail independently* (a device drops, a host OOMs).  Each shard's stage
runs as its own single-shard program routed through the PR-1
`repro.dist.fault.WorkQueue` lease protocol:

* every shard id is a work item; a claim leases it for ``lease_s``;
* a shard whose stage raises (or whose worker dies and lets the lease
  expire) is **re-queued, not dropped** — the handler re-materializes
  the shard from the epoched index (``refresh_shard``, which bumps that
  shard's epoch-vector entry) and the next claim retries it;
* reads are only answered after *every* shard contributed its
  candidates, so no read silently loses the shard that owned its true
  mapping locus.

Since PR 10 the merge is the packed-key **device** reduction
(`repro.shard.merge`; span ``merge_device``) and the align stage can
fail independently too: with ``align_fault_hook`` the winning windows
split into per-owner-shard chunks on a second lease queue, so a shard
lost *between merge and align* — the window the pipelined serve path
opens — re-queues its chunk instead of dropping those reads.
``pipelined=True`` dispatches merge → align without the inter-stage
host sync, mirroring the engine's double-buffered mode.

``fault_hook(shard_id, attempt)`` / ``align_fault_hook(shard_id,
attempt)`` exist for tests and chaos drills: they run before each
shard stage / align chunk and may raise to simulate a lost device.
`map_batch_with_failover_graph` is the same driver for the
variation-graph workload (screen → stage → device merge → align).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.core.mapper import MapResult
from repro.dist.fault import WorkQueue

from . import merge as shard_merge
from .graph_partition import EpochedShardedGraphIndex, GraphShardArrays
from .mapper import ShardStageResult, get_executor
from .partition import EpochedShardedIndex, ShardArrays


def _row(arrays: ShardArrays, i: int) -> ShardArrays:
    """A one-shard [1, ...] view of row ``i`` of the stacked arrays."""
    return ShardArrays(*[a[i: i + 1] for a in arrays])


def _graph_row(arrays: GraphShardArrays, i: int) -> GraphShardArrays:
    """A one-shard [1, ...] view of row ``i`` of the stacked graph arrays."""
    return GraphShardArrays(*[a[i: i + 1] for a in arrays])


def _run_shard_queue(s, *, esi, lease_s, max_attempts, fault_hook, tr,
                     span_name, work, **span_attrs):
    """Lease-queue driver: run ``work(shard_id)`` once per shard with retry.

    Returns ``{shard_id: work result}`` after every shard completed;
    re-materializes + re-queues a shard whose ``work`` (or
    ``fault_hook``) raises, giving up only after ``max_attempts``.
    """
    q = WorkQueue(s, lease_s=lease_s)
    attempts = [0] * s
    parts: dict[int, object] = {}
    while not q.finished:
        item = q.claim()
        if item is None:
            time.sleep(0.001)
            continue
        attempts[item] += 1
        try:
            with tr.span(span_name, shard=item, attempt=attempts[item],
                         **span_attrs):
                if fault_hook is not None:
                    fault_hook(item, attempts[item])
                parts[item] = work(item)
        except Exception as e:
            if attempts[item] >= max_attempts:
                raise RuntimeError(
                    f"shard {item} failed {attempts[item]} times in "
                    f"{span_name}; last error: {e}") from e
            esi.refresh_shard(item)  # re-materialize before the retry
            q.fail(item)
            tr.event("shard_requeued", shard=item, attempt=attempts[item],
                     stage=span_name, error=type(e).__name__)
            continue
        q.complete(item)
    return parts


def _chunked_align(owner, align_one, template, b, *, s, esi, lease_s,
                   max_attempts, align_fault_hook, tr):
    """Align the winners in per-owner-shard chunks on a lease queue.

    ``owner[b]`` is each read's winning shard; chunk ``i`` aligns the
    reads shard ``i`` owns (``align_one(row_idx) -> numpy tree``) and a
    chunk whose shard dies between merge and align re-queues instead of
    dropping its reads.  Results scatter back into ``template``-shaped
    arrays, so the assembled batch is byte-identical to the one-shot
    align — ``align_batch`` is per-row independent.
    """
    chunks = [np.nonzero(owner == i)[0] for i in range(s)]

    def work(i):
        idx = chunks[i]
        if idx.size == 0:
            return None
        return idx, align_one(idx)

    parts = _run_shard_queue(
        s, esi=esi, lease_s=lease_s, max_attempts=max_attempts,
        fault_hook=align_fault_hook, tr=tr, span_name="align_shard",
        work=work)

    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = [np.zeros((b,) + lf.shape[1:], lf.dtype) for lf in leaves]
    for part in parts.values():
        if part is None:
            continue
        idx, res = part
        for dst, src in zip(out, jax.tree_util.tree_leaves(res)):
            dst[idx] = src
    return jax.tree_util.tree_unflatten(treedef, out)


def map_batch_with_failover(
    esi: EpochedShardedIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    lease_s: float = 60.0,
    max_attempts: int = 3,
    fault_hook=None,
    align_fault_hook=None,
    pipelined: bool = False,
    tracer=None,
) -> MapResult:
    """Map a batch with per-shard retry semantics over a lease queue.

    Produces the same :class:`repro.core.mapper.MapResult` as
    `shard.mapper.map_batch_sharded` (numpy leaves) — shard stages are
    deterministic, so a re-materialized shard contributes identical
    candidates and the merged output is unchanged by failures.  Raises
    ``RuntimeError`` only after a shard fails ``max_attempts`` times.

    ``tracer`` (a `repro.obs.trace.Tracer`) records one ``scatter`` span
    per shard attempt (attrs: ``shard``, ``attempt``), a
    ``shard_requeued`` instant per lease failure, and the
    ``merge_device`` / ``align`` (or per-chunk ``align_shard``) tail
    spans — the flight recorder for chaos drills.
    """
    from repro.obs.trace import NULL_TRACER

    tr = tracer if tracer is not None else NULL_TRACER
    sharded, _ = esi.current()
    s = sharded.num_shards
    b = int(np.asarray(reads).shape[0])
    # shared keyed cache (mapper.get_executor): repeated degraded-mode
    # batches reuse the compiled stage/align programs instead of
    # retracing per call
    ex = get_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, force_vmap=True)

    def scatter_one(item):
        cur, _ = esi.current()
        st = ex.stage(_row(cur.arrays, item), reads, read_lens)
        return jax.tree_util.tree_map(lambda x: np.asarray(x)[0], st)

    parts = _run_shard_queue(
        s, esi=esi, lease_s=lease_s, max_attempts=max_attempts,
        fault_hook=fault_hook, tr=tr, span_name="scatter",
        work=scatter_one)

    with tr.span("merge_device", shards=s, pipelined=pipelined):
        stacked = ShardStageResult(*[
            jnp.asarray(np.stack([parts[i][f] for i in range(s)]))
            for f in range(len(ShardStageResult._fields))])
        fd, pos, text, t_len, win = ex.merge_device(stacked)
        if not pipelined:
            jax.block_until_ready(fd)

    reads_j = jnp.asarray(reads)
    lens_j = jnp.asarray(read_lens, jnp.int32)
    if align_fault_hook is None:
        with tr.span("align"):
            res = ex._align(text, reads_j, lens_j, t_len, pos, fd)
            return jax.tree_util.tree_map(np.asarray, res)

    owner = np.asarray(win)
    fd, pos, text, t_len = (np.asarray(a) for a in (fd, pos, text, t_len))
    reads_np = np.asarray(reads)
    lens_np = np.asarray(read_lens, np.int32)

    def align_one(idx):
        res = ex._align(
            jnp.asarray(text[idx]), jnp.asarray(reads_np[idx]),
            jnp.asarray(lens_np[idx]), jnp.asarray(t_len[idx]),
            jnp.asarray(pos[idx]), jnp.asarray(fd[idx]))
        return jax.tree_util.tree_map(np.asarray, res)

    # template from a 1-row probe: chunk outputs scatter into [B] arrays
    template = align_one(np.arange(1))
    return _chunked_align(
        owner, align_one, template, b, s=s, esi=esi, lease_s=lease_s,
        max_attempts=max_attempts, align_fault_hook=align_fault_hook,
        tr=tr)


def map_batch_with_failover_graph(
    esi: EpochedShardedGraphIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    prefilter: bool | None = None,
    lease_s: float = 60.0,
    max_attempts: int = 3,
    fault_hook=None,
    align_fault_hook=None,
    pipelined: bool = False,
    tracer=None,
):
    """Graph-workload twin of `map_batch_with_failover`.

    Per shard: q-gram screen + compacted candidate stage as its own
    lease-queued program (``scatter`` spans; ``fault_hook`` faults it),
    then the packed ``(distance, origin, tile)`` device merge and the
    winner align — chunked per owner shard on a second lease queue when
    ``align_fault_hook`` is given, so a shard lost between merge and
    align re-queues.  Byte-identical to
    `shard.graph_mapper.map_batch_sharded_graph` under any failure
    sequence that stays within ``max_attempts``.
    """
    from repro.graph.mapper import tile_rung, unmapped_result
    from repro.obs.trace import NULL_TRACER

    from .graph_mapper import get_graph_executor

    tr = tracer if tracer is not None else NULL_TRACER
    sharded, _ = esi.current()
    s = sharded.num_shards
    reads_j = jnp.asarray(reads)
    lens_j = jnp.asarray(read_lens, jnp.int32)
    b = int(reads_j.shape[0])
    ex = get_graph_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, force_vmap=True, prefilter=prefilter)

    def stage_one(item):
        # screen + stage for one shard; the rung must match the fleet
        # rule (worst shard's survivor count), so the screen runs per
        # shard but the rung is picked after all shards report
        cur, _ = esi.current()
        row = _graph_row(cur.arrays, item)
        pf = ex._pf(*row, reads_j, lens_j)
        n_keep = int(np.asarray(pf.n_keep)[0].sum())
        return esi.epochs[item], pf, n_keep

    screened = _run_shard_queue(
        s, esi=esi, lease_s=lease_s, max_attempts=max_attempts,
        fault_hook=fault_hook, tr=tr, span_name="scatter",
        work=stage_one)

    slots = b * shard_candidates
    n_cap = tile_rung(max(screened[i][2] for i in range(s)), slots)
    if n_cap == 0:
        return jax.tree_util.tree_map(
            np.asarray, unmapped_result(b, cfg=cfg, p_cap=p_cap))

    def candidates_one(item):
        cur, _ = esi.current()
        row = _graph_row(cur.arrays, item)
        # a refreshed shard (epoch bumped since the screen pass)
        # recomputes its deterministic screen before the stage
        pf = screened[item][1] if esi.epochs[item] == screened[item][0] \
            else ex._pf(*row, reads_j, lens_j)
        st = ex._stage_for(n_cap)(*row, reads_j, lens_j, pf)
        return jax.tree_util.tree_map(lambda x: np.asarray(x)[0], st)

    parts = _run_shard_queue(
        s, esi=esi, lease_s=lease_s, max_attempts=max_attempts,
        fault_hook=None, tr=tr, span_name="scatter", work=candidates_one,
        phase="candidates")

    fields = type(parts[0])._fields
    with tr.span("merge_device", shards=s, pipelined=pipelined):
        stacked = type(parts[0])(*[
            jnp.asarray(np.stack([getattr(parts[i], f) for i in range(s)]))
            for f in fields])
        merged = ex.merge_device(stacked)
        if not pipelined:
            jax.block_until_ready(merged.distance)

    if align_fault_hook is None:
        with tr.span("align"):
            res = ex._align(merged, reads_j, lens_j)
            return jax.tree_util.tree_map(np.asarray, res)

    # owner shard via the same packed key the device merge used — numpy
    # uint64 needs no x64 flag, so this host copy is exact
    owner = np.argmin(shard_merge.pack_graph_key(
        np.stack([np.asarray(parts[i].distance) for i in range(s)]),
        np.stack([np.asarray(parts[i].origin) for i in range(s)]),
        np.stack([np.asarray(parts[i].tile) for i in range(s)])), axis=0)
    merged_np = jax.tree_util.tree_map(np.asarray, merged)
    reads_np = np.asarray(reads)
    lens_np = np.asarray(read_lens, np.int32)

    def align_one(idx):
        sub = jax.tree_util.tree_map(lambda x: jnp.asarray(x[idx]),
                                     merged_np)
        res = ex._align(sub, jnp.asarray(reads_np[idx]),
                        jnp.asarray(lens_np[idx]))
        return jax.tree_util.tree_map(np.asarray, res)

    template = align_one(np.arange(1))
    return _chunked_align(
        owner, align_one, template, b, s=s, esi=esi, lease_s=lease_s,
        max_attempts=max_attempts, align_fault_hook=align_fault_hook,
        tr=tr)
