"""Shard failover: lease-routed scatter so a lost shard re-queues.

The serve path normally launches the scatter stage as one program over
all shards; this module is the degraded-mode driver for when shards can
*fail independently* (a device drops, a host OOMs).  Each shard's stage
runs as its own single-shard program routed through the PR-1
`repro.dist.fault.WorkQueue` lease protocol:

* every shard id is a work item; a claim leases it for ``lease_s``;
* a shard whose stage raises (or whose worker dies and lets the lease
  expire) is **re-queued, not dropped** — the handler re-materializes
  the shard from the epoched index (``refresh_shard``, which bumps that
  shard's epoch-vector entry) and the next claim retries it;
* reads are only answered after *every* shard contributed its
  candidates, so no read silently loses the shard that owned its true
  mapping locus.

``fault_hook(shard_id, attempt)`` exists for tests and chaos drills: it
runs before each shard stage and may raise to simulate a lost device.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.core.mapper import MapResult
from repro.dist.fault import WorkQueue

from .mapper import ShardStageResult, get_executor
from .partition import EpochedShardedIndex, ShardArrays


def _row(arrays: ShardArrays, i: int) -> ShardArrays:
    """A one-shard [1, ...] view of row ``i`` of the stacked arrays."""
    return ShardArrays(*[a[i: i + 1] for a in arrays])


def map_batch_with_failover(
    esi: EpochedShardedIndex,
    reads,
    read_lens,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    shard_candidates: int = 4,
    backend: str | None = None,
    lease_s: float = 60.0,
    max_attempts: int = 3,
    fault_hook=None,
    tracer=None,
) -> MapResult:
    """Map a batch with per-shard retry semantics over a lease queue.

    Produces the same :class:`repro.core.mapper.MapResult` as
    `shard.mapper.map_batch_sharded` (numpy leaves) — shard stages are
    deterministic, so a re-materialized shard contributes identical
    candidates and the merged output is unchanged by failures.  Raises
    ``RuntimeError`` only after a shard fails ``max_attempts`` times.

    ``tracer`` (a `repro.obs.trace.Tracer`) records one ``scatter`` span
    per shard attempt (attrs: ``shard``, ``attempt``), a
    ``shard_requeued`` instant per lease failure, and the ``merge`` /
    ``align`` tail spans — the flight recorder for chaos drills.
    """
    from repro.obs.trace import NULL_TRACER

    tr = tracer if tracer is not None else NULL_TRACER
    sharded, _ = esi.current()
    s = sharded.num_shards
    # shared keyed cache (mapper.get_executor): repeated degraded-mode
    # batches reuse the compiled stage/align programs instead of
    # retracing per call
    ex = get_executor(
        sharded, cfg=cfg, p_cap=p_cap, filter_bits=filter_bits,
        filter_k=filter_k, shard_candidates=shard_candidates,
        backend=backend, force_vmap=True)

    q = WorkQueue(s, lease_s=lease_s)
    attempts = [0] * s
    parts: dict[int, tuple] = {}
    while not q.finished:
        item = q.claim()
        if item is None:
            time.sleep(0.001)
            continue
        attempts[item] += 1
        try:
            with tr.span("scatter", shard=item, attempt=attempts[item]):
                if fault_hook is not None:
                    fault_hook(item, attempts[item])
                cur, _ = esi.current()
                st = ex.stage(_row(cur.arrays, item), reads, read_lens)
                parts[item] = jax.tree_util.tree_map(
                    lambda x: np.asarray(x)[0], st)
        except Exception as e:
            if attempts[item] >= max_attempts:
                raise RuntimeError(
                    f"shard {item} failed {attempts[item]} times; last "
                    f"error: {e}") from e
            esi.refresh_shard(item)  # re-materialize before the retry
            q.fail(item)
            tr.event("shard_requeued", shard=item, attempt=attempts[item],
                     error=type(e).__name__)
            continue
        q.complete(item)

    with tr.span("merge", shards=s):
        stacked = ShardStageResult(*[
            jnp.asarray(np.stack([parts[i][f] for i in range(s)]))
            for f in range(len(ShardStageResult._fields))])
        fd, pos, text, t_len, _ = ex.merge(stacked)
    with tr.span("align"):
        res = ex._align(jnp.asarray(text), jnp.asarray(reads),
                        jnp.asarray(read_lens, jnp.int32),
                        jnp.asarray(t_len), jnp.asarray(pos),
                        jnp.asarray(fd))
        res = jax.tree_util.tree_map(np.asarray, res)
    return res
