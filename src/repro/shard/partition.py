"""Reference partitioning: per-device shards with overlap halos.

The dissertation scales GenASM/SeGraM by giving every accelerator
channel a contiguous slice of the reference plus the index entries that
land in it (GenASM §4, SeGraM §6.5); each channel seeds and filters
independently and a cheap merge picks the global winner.  This module is
that layout for JAX devices:

* ``ShardLayout`` cuts ``[0, ref_len)`` into ``num_shards`` contiguous
  *core* ranges.  Shard ``i`` materializes the haloed slice
  ``[lo_i - halo, hi_i + halo)`` so every filter region and alignment
  window anchored in its core exists fully inside the slice — no
  mapping is lost at a shard boundary, and windows that straddle a cut
  appear (byte-identically) in both neighbours, to be deduped at merge.
* The minimizer table is built (or reused) **globally** — frequency
  filtering sees global counts, exactly like the paper's offline
  pre-processing — then partitioned by position: shard ``i`` owns the
  entries with ``lo_i <= pos < hi_i``.  Positions stay in *global*
  coordinates, so per-shard candidates merge without translation.
* Everything is stacked along a leading ``[num_shards, ...]`` axis and
  padded to common shapes, the convention `repro.dist.sharding.
  stacked_specs` resolves to a ``P("shard")`` placement for
  ``shard_map`` execution.

``EpochedShardedIndex`` / ``EpochedShardedGraphIndex`` mirror the
single-device epoch handles, but the epoch is a **vector** (one counter
per shard) and ``current()`` returns a hashable *epoch token* combining
the layout and the vector — `serve/cache.py` keys results on it, so a
single-shard refresh (failover re-materialization) can never alias a
cache entry from a different shard state.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.bitvector import SENTINEL
from repro.core.minimizer_index import EpochedIndex, ReferenceIndex
from repro.core.segram.minimizer import build_index

DEFAULT_HALO = 1024
_PAD_HASH = np.uint32(0xFFFFFFFF)  # sorts last; no valid seed hashes it
_PAD_POS = np.int32(2 ** 30)


class ShardLayout(NamedTuple):
    """Contiguous core partition of ``[0, ref_len)`` plus the halo width.

    ``bounds`` has ``num_shards + 1`` entries; shard ``i`` owns core
    ``[bounds[i], bounds[i+1])`` and materializes the slice
    ``[max(0, bounds[i] - halo), min(ref_len, bounds[i+1] + halo))``.
    """

    bounds: tuple[int, ...]
    halo: int
    ref_len: int

    @property
    def num_shards(self) -> int:
        """Number of shards in the layout."""
        return len(self.bounds) - 1

    def core(self, i: int) -> tuple[int, int]:
        """Global ``[lo, hi)`` core range owned by shard ``i``."""
        return self.bounds[i], self.bounds[i + 1]

    def slice_range(self, i: int) -> tuple[int, int]:
        """Global ``[lo, hi)`` range of shard ``i``'s haloed slice."""
        lo, hi = self.core(i)
        return max(0, lo - self.halo), min(self.ref_len, hi + self.halo)

    def shard_of(self, pos: int) -> int:
        """Index of the shard whose core contains global position ``pos``."""
        return int(np.searchsorted(np.asarray(self.bounds), pos,
                                   side="right") - 1)


def plan_layout(ref_len: int, num_shards: int,
                halo: int = DEFAULT_HALO) -> ShardLayout:
    """Equal-size contiguous core partition of a ``ref_len``-bp reference."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if halo < 0:
        raise ValueError(f"halo must be >= 0, got {halo}")
    bounds = tuple(round(i * ref_len / num_shards)
                   for i in range(num_shards + 1))
    if len(set(bounds)) != num_shards + 1:
        raise ValueError(
            f"reference of {ref_len} bp is too short for {num_shards} "
            f"shards (empty core range)")
    return ShardLayout(bounds=bounds, halo=halo, ref_len=ref_len)


class ShardArrays(NamedTuple):
    """Device half of a sharded linear index, stacked ``[S, ...]``.

    Row ``i`` is shard ``i``; rows are padded to common shapes (refs
    with sentinel bases, tables with a sorts-last hash), and
    ``positions`` are *global* reference coordinates.
    """

    refs: jnp.ndarray  # [S, Lm] int8 haloed slices (sentinel padded)
    offsets: jnp.ndarray  # [S] int32 global coord of each slice's base 0
    hashes: jnp.ndarray  # [S, Mm] uint32 sorted minimizer hashes
    positions: jnp.ndarray  # [S, Mm] int32 GLOBAL minimizer positions


@dataclass
class ShardedIndex:
    """Host handle: stacked shard arrays + layout + seeding parameters."""

    arrays: ShardArrays
    layout: ShardLayout
    minimizer_w: int
    minimizer_k: int
    freq_frac: float = 0.0002

    @property
    def num_shards(self) -> int:
        """Number of reference shards."""
        return self.layout.num_shards

    @property
    def ref_len(self) -> int:
        """Global reference length in bases."""
        return self.layout.ref_len

    @property
    def layout_key(self) -> tuple:
        """Hashable geometry key (partition bounds + padded array dims)."""
        return (self.layout.bounds, self.layout.halo, self.layout.ref_len,
                int(self.arrays.refs.shape[1]),
                int(self.arrays.hashes.shape[1]))


def _partition_table(hashes: np.ndarray, positions: np.ndarray,
                     layout: ShardLayout) -> list[tuple[np.ndarray,
                                                        np.ndarray]]:
    """Split a sorted global (hash, position) table by core ownership.

    Filtering rows preserves the sort (by hash, then position), so each
    shard's subset is directly ``searchsorted``-able.
    """
    out = []
    for i in range(layout.num_shards):
        lo, hi = layout.core(i)
        m = (positions >= lo) & (positions < hi)
        out.append((hashes[m], positions[m]))
    return out


def _stack_shards(ref: np.ndarray, layout: ShardLayout,
                  tables: Sequence[tuple[np.ndarray, np.ndarray]]
                  ) -> ShardArrays:
    s = layout.num_shards
    ranges = [layout.slice_range(i) for i in range(s)]
    lm = max(hi - lo for lo, hi in ranges)
    mm = max(1, max(len(h) for h, _ in tables))
    refs = np.full((s, lm), SENTINEL, np.int8)
    hashes = np.full((s, mm), _PAD_HASH, np.uint32)
    positions = np.full((s, mm), _PAD_POS, np.int32)
    offsets = np.zeros(s, np.int32)
    for i, (lo, hi) in enumerate(ranges):
        refs[i, : hi - lo] = ref[lo:hi]
        offsets[i] = lo
        h, p = tables[i]
        hashes[i, : len(h)] = h
        positions[i, : len(p)] = p
    return ShardArrays(refs=jnp.asarray(refs), offsets=jnp.asarray(offsets),
                       hashes=jnp.asarray(hashes),
                       positions=jnp.asarray(positions))


def build_sharded_index(
    ref: np.ndarray,
    num_shards: int,
    *,
    w: int = 10,
    k: int = 15,
    freq_frac: float = 0.0002,
    halo: int = DEFAULT_HALO,
    hashes: np.ndarray | None = None,
    positions: np.ndarray | None = None,
) -> ShardedIndex:
    """Partition a reference (and its global minimizer table) into shards.

    The minimizer table is built globally (global frequency filter, as
    in the paper's offline pre-processing) unless an existing global
    ``hashes``/``positions`` pair is passed — `from_epoched` reuses the
    single-device index's table so 1-shard and N-shard serving seed
    from literally the same entries.
    """
    ref = np.asarray(ref, np.int8)
    layout = plan_layout(len(ref), num_shards, halo)
    if hashes is None or positions is None:
        idx = build_index(ref, w=w, k=k, freq_frac=freq_frac)
        hashes, positions = idx.hashes, idx.positions
    tables = _partition_table(np.asarray(hashes), np.asarray(positions),
                              layout)
    return ShardedIndex(arrays=_stack_shards(ref, layout, tables),
                        layout=layout, minimizer_w=w, minimizer_k=k,
                        freq_frac=freq_frac)


class EpochedShardedIndex:
    """Epoch-vector-stamped handle around a ``ShardedIndex``.

    One epoch counter per shard: ``refresh()`` (new reference) bumps
    every counter, ``refresh_shard(i)`` (failover re-materialization of
    a lost device's slice) bumps only shard ``i``'s.  ``current()``
    returns ``(index, token)`` where the token is the hashable
    ``(layout_key, epoch vector)`` pair — the serve cache keys on the
    whole token, so shard-local epochs can never alias across layouts
    or across different shards' refresh histories (the
    `serve/cache.py` collision bug this type exists to prevent).
    """

    def __init__(self, index: ShardedIndex, ref: np.ndarray,
                 epochs: Sequence[int] | None = None):
        self._lock = threading.Lock()
        self._index = index
        self._ref = np.asarray(ref, np.int8)
        self.epochs = list(epochs) if epochs is not None \
            else [0] * index.num_shards
        if len(self.epochs) != index.num_shards:
            raise ValueError(
                f"epoch vector has {len(self.epochs)} entries for "
                f"{index.num_shards} shards")
        self._build_kw = dict(w=index.minimizer_w, k=index.minimizer_k,
                              freq_frac=index.freq_frac,
                              halo=index.layout.halo)

    @property
    def index(self) -> ShardedIndex:
        """The current ``ShardedIndex`` (unsynchronized peek)."""
        return self._index

    def epoch_token(self) -> tuple:
        """Hashable (layout, epoch-vector) cache-key component."""
        with self._lock:
            return (self._index.layout_key, tuple(self.epochs))

    def current(self) -> tuple[ShardedIndex, tuple]:
        """Consistent (index, epoch token) pair for one mapping batch."""
        with self._lock:
            return self._index, (self._index.layout_key, tuple(self.epochs))

    def refresh(self, ref: np.ndarray, **build_kw) -> tuple:
        """Re-partition from a new reference; bumps every shard's epoch."""
        kw = {**self._build_kw, **build_kw}
        new = build_sharded_index(ref, self._index.num_shards, **kw)
        with self._lock:
            self._index = new
            self._ref = np.asarray(ref, np.int8)
            self._build_kw = kw
            self.epochs = [e + 1 for e in self.epochs]
            return (new.layout_key, tuple(self.epochs))

    def refresh_shard(self, i: int) -> tuple:
        """Re-materialize shard ``i`` from the retained host reference.

        Failover path: a shard whose device was lost is rebuilt in
        place (same layout, same global table) and only its epoch
        counter bumps — results cached against the other shards'
        entries stay addressable under the new token's vector only if
        the cache chooses to; keying on the whole vector keeps it
        conservative and correct.
        """
        if not 0 <= i < self._index.num_shards:
            raise IndexError(f"shard {i} out of range "
                             f"(num_shards={self._index.num_shards})")
        idx = build_index(self._ref, w=self._index.minimizer_w,
                          k=self._index.minimizer_k,
                          freq_frac=self._index.freq_frac)
        layout = self._index.layout
        lo, hi = layout.core(i)
        slo, shi = layout.slice_range(i)
        a = self._index.arrays
        m = (idx.positions >= lo) & (idx.positions < hi)
        h, p = idx.hashes[m], idx.positions[m]
        mm = a.hashes.shape[1]
        row_h = np.full(mm, _PAD_HASH, np.uint32)
        row_p = np.full(mm, _PAD_POS, np.int32)
        row_h[: len(h)] = h[:mm]
        row_p[: len(p)] = p[:mm]
        row_r = np.full(a.refs.shape[1], SENTINEL, np.int8)
        row_r[: shi - slo] = self._ref[slo:shi]
        with self._lock:
            self._index = ShardedIndex(
                arrays=ShardArrays(
                    refs=a.refs.at[i].set(jnp.asarray(row_r)),
                    offsets=a.offsets,
                    hashes=a.hashes.at[i].set(jnp.asarray(row_h)),
                    positions=a.positions.at[i].set(jnp.asarray(row_p))),
                layout=layout, minimizer_w=self._index.minimizer_w,
                minimizer_k=self._index.minimizer_k,
                freq_frac=self._index.freq_frac)
            self.epochs[i] += 1
            return (self._index.layout_key, tuple(self.epochs))


def from_epoched(epi: EpochedIndex | ReferenceIndex, num_shards: int, *,
                 halo: int = DEFAULT_HALO,
                 w: int | None = None, k: int | None = None,
                 freq_frac: float | None = None) -> EpochedShardedIndex:
    """Shard an existing (epoched) single-device index.

    Reuses the host copy of the reference *and* the already-built
    global minimizer table, so the sharded index seeds from exactly the
    entries the single-device path seeds from (a requirement for
    byte-identical 1-vs-N output, since frequency filtering depends on
    global counts).
    """
    if isinstance(epi, EpochedIndex):
        kw = epi._build_kw
        w = kw["w"] if w is None else w
        k = kw["k"] if k is None else k
        freq_frac = kw.get("freq_frac", 0.0002) if freq_frac is None \
            else freq_frac
        ridx = epi.index
    else:
        ridx = epi
        if w is None or k is None:
            raise ValueError("sharding a bare ReferenceIndex needs explicit "
                             "w/k (it does not record its build params)")
        freq_frac = 0.0002 if freq_frac is None else freq_frac
    ref = np.asarray(ridx.ref, np.int8)
    sharded = build_sharded_index(
        ref, num_shards, w=w, k=k, freq_frac=freq_frac, halo=halo,
        hashes=np.asarray(ridx.hashes), positions=np.asarray(ridx.positions))
    return EpochedShardedIndex(sharded, ref)
