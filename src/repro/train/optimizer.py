"""AdamW with optional bf16 moments + global-norm clipping + schedules.

Moments in bf16 halve the optimizer-state HBM (the margin that fits the
340B/398B configs on v5e, DESIGN.md §5); the update math runs in fp32.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "bfloat16"  # or "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(cfg: AdamWConfig, params):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(
        jax.tree_util.tree_reduce(
            lambda a, l: a + jnp.sum(jnp.square(l.astype(jnp.float32))), tree, 0.0
        )
    )


def apply(cfg: AdamWConfig, params, opt_state, grads):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {"grad_norm": gn, "lr": lr}
