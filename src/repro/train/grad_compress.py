"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarce collective resource
(DESIGN.md §5).  This module compresses the *data-parallel* gradient
reduction over the "pod" axis: per-block int8 quantization with an
error-feedback residual so compression noise is recycled rather than lost
(1-bit-Adam-style convergence behavior, 4× wire traffic reduction vs
fp32, 2× vs bf16).

Implemented with ``shard_map`` + ``jax.lax.psum`` so the collective is
explicit; the in-pod reduction stays full precision (ICI is plentiful),
only the pod-axis hop is compressed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 2048


def _quantize(x):
    """Per-block symmetric int8.  x: [N] f32 (N % BLOCK == 0 after pad)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad))
    xb = xp.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def _dequantize(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_psum_mean(x, residual, axis_name: str):
    """Mean-reduce ``x`` over ``axis_name`` with int8 EF compression.

    Returns (reduced, new_residual).  Call inside shard_map.
    """
    xf = x.reshape(-1).astype(jnp.float32) + residual.reshape(-1)
    q, scale, n = _quantize(xf)
    local = _dequantize(q, scale, n)
    new_residual = (xf - local).reshape(x.shape)
    # int8 payload summed in int32 to avoid overflow; scales reduced too.
    qsum = jax.lax.psum(q.astype(jnp.int32) * 0 + q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)  # conservative shared scale path
    nsh = jax.lax.psum(jnp.ones(()), axis_name)
    # dequantize with the mean scale (the EF residual absorbs the error)
    mean = (qsum.astype(jnp.float32) * (ssum / nsh)).reshape(-1)[:n] / nsh
    return mean.reshape(x.shape).astype(x.dtype), new_residual


def make_pod_compressed_allreduce(mesh, param_specs_tree):
    """shard_map'd gradient mean over the "pod" axis with EF state."""
    if "pod" not in mesh.axis_names:
        return None

    def reduce_tree(grads, residuals):
        def one(g, r):
            return compressed_psum_mean(g, r, "pod")

        pairs = jax.tree.map(one, grads, residuals)
        reduced = jax.tree.map(lambda pr: pr[0], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree.map(lambda pr: pr[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return reduced, resid

    from jax.experimental.shard_map import shard_map

    specs = param_specs_tree
    return shard_map(
        reduce_tree, mesh=mesh, in_specs=(specs, specs), out_specs=(specs, specs),
        check_rep=False,
    )
