"""Train-step builder: microbatch accumulation + remat + AdamW + sharding.

The returned ``train_step(params, opt_state, batch)`` is what the
multi-pod dry-run lowers and what ``launch/train.py`` runs: gradients are
accumulated over ``microbatches`` sequential slices of the global batch
(a ``lax.scan``), each slice forward/backward under layer remat, then one
optimizer step.  Donation on (params, opt_state) makes the update
in-place in HBM.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from . import optimizer as opt_mod


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    adamw: opt_mod.AdamWConfig = opt_mod.AdamWConfig()
    sp: bool = False  # sequence-parallel activation constraints


def _split_micro(batch, n: int):
    """[B, ...] -> [n, B/n, ...] per leaf."""
    return jax.tree.map(
        lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch
    )


def build_train_step(cfg, tcfg: TrainConfig, mesh=None):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``."""

    def loss_of(params, mb):
        loss, metrics = model_zoo.loss_fn(cfg, params, mb, mesh=mesh, sp=tcfg.sp)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params, opt_state, batch):
        n = tcfg.microbatches
        if n > 1:
            micro = _split_micro(batch, n)

            def acc(carry, mb):
                gacc, lacc, aacc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss, aacc + metrics["acc"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, acc_m), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0), jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            acc_m = acc_m / n
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            acc_m = metrics["acc"]

        new_params, new_opt, om = opt_mod.apply(tcfg.adamw, params, opt_state, grads)
        metrics = {"loss": loss, "acc": acc_m, **om}
        return new_params, new_opt, metrics

    return train_step


def init_state(cfg, tcfg: TrainConfig, key):
    params = model_zoo.init(cfg, key)
    opt_state = opt_mod.init(tcfg.adamw, params)
    return params, opt_state
