"""Serving-step builders: prefill and decode with sharded caches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model_zoo


def build_prefill_step(cfg):
    def prefill_step(params, batch):
        return model_zoo.prefill_fn(cfg, params, batch)

    return prefill_step


def build_decode_step(cfg):
    def decode_step(params, state, batch, pos):
        return model_zoo.decode_fn(cfg, params, state, batch, pos)

    return decode_step


def greedy_generate(cfg, params, prompt_tokens, *, steps: int, max_len: int):
    """Small-model greedy decoding used by examples/tests (CPU scale)."""
    b, s0 = prompt_tokens.shape
    state = model_zoo.decode_state_init(cfg, b, max_len)
    tok = prompt_tokens[:, :1]
    out = [tok]
    pos = 0
    # feed prompt then generate
    for i in range(s0 - 1):
        _, state = model_zoo.decode_fn(cfg, params, state,
                                       {"tokens": prompt_tokens[:, i: i + 1]},
                                       jnp.int32(pos))
        pos += 1
    tok = prompt_tokens[:, s0 - 1: s0]
    for _ in range(steps):
        logits, state = model_zoo.decode_fn(cfg, params, state, {"tokens": tok},
                                            jnp.int32(pos))
        pos += 1
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
