"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables.

    PYTHONPATH=src python -m repro.launch.report > experiments_tables.md
"""
import json
from pathlib import Path

res = json.loads((Path(__file__).resolve().parents[3] / "dryrun_results.json").read_text())


def fmt_bytes(b):
    return f"{b / 1e9:.1f}"


def dryrun_table():
    rows = ["| cell | mesh | chips | compile s | args GB/dev | temp GB/dev | "
            "coll ops | HLO GF/dev (raw) |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        r = res[key]
        if "error" in r:
            rows.append(f"| {r['arch']}×{r['shape']} | {r['mesh']} | — | ERROR | | | | |")
            continue
        rows.append(
            f"| {r['arch']}×{r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r.get('compile_s', 0):.0f} | {fmt_bytes(r['memory']['argument_bytes'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} | "
            f"{r['collectives']['n_ops']} | "
            f"{r['cost']['flops_per_device_raw'] / 1e9:.1f} |")
    return "\n".join(rows)


def roofline_table():
    rows = ["| cell | mesh | compute s | memory s | collective s | bottleneck | "
            "roofline s/step | MFU bound | useful ratio (6ND/HLO) |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(res):
        r = res[key]
        if "analytic" not in r:
            continue
        if r["mesh"] != "16x16":
            continue  # roofline table is single-pod per the assignment
        a = r["analytic"]
        rows.append(
            f"| {r['arch']}×{r['shape']} | {r['mesh']} | {a['compute_s']:.2e} | "
            f"{a['memory_s']:.2e} | {a['collective_s']:.2e} | {a['bottleneck']} | "
            f"{a['roofline_s']:.2e} | {a['mfu_bound']:.2f} | "
            f"{a['useful_ratio_6nd']:.2f} |")
    return "\n".join(rows)


def multi_table():
    rows = ["| cell | 16x16 temp GB | 2x16x16 temp GB | 2x16x16 coll ops | "
            "2x16x16 link GB (corrected) |",
            "|---|---|---|---|---|"]
    singles = {k: v for k, v in res.items() if v.get("mesh") == "16x16"}
    for key in sorted(singles):
        r = singles[key]
        mk = key.replace("16x16", "2x16x16")
        m = res.get(mk)
        if not m or "memory" not in m:
            continue
        rows.append(
            f"| {r['arch']}×{r['shape']} | {fmt_bytes(r['memory']['temp_bytes'])} | "
            f"{fmt_bytes(m['memory']['temp_bytes'])} | {m['collectives']['n_ops']} | "
            f"{m['collectives']['link_bytes_corrected'] / 1e9:.0f} |")
    return "\n".join(rows)


print("## DRYRUN\n")
print(dryrun_table())
print("\n## ROOFLINE\n")
print(roofline_table())
print("\n## MULTI\n")
print(multi_table())
