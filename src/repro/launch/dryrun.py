import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: ``jax.jit(step, in_shardings=…).lower(**input_specs)``
``.compile()`` on the production meshes (16×16 single-pod, 2×16×16
multi-pod), then record ``memory_analysis()``, ``cost_analysis()``, the
parsed collective schedule, and the analytic roofline terms
(EXPERIMENTS.md §Dry-run / §Roofline).

Resumable: results accrue in ``dryrun_results.json``; rerun with
``--skip-done`` after interruption.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, cells, get_config, get_shape
from repro.dist import sharding as shd
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo
from repro.train import loop as train_loop
from repro.train import optimizer as opt_mod
from repro.train import serve as serve_mod

RESULTS = Path(__file__).resolve().parents[3] / "dryrun_results.json"


def microbatches_for(cfg, shape) -> int:
    """Gradient-accumulation depth: keep per-microbatch boundary activations
    ~1 GB/device (DESIGN.md §5 memory plan)."""
    if shape.kind != "train":
        return 1
    big = cfg.d_model >= 8192 or cfg.n_layers >= 90
    return 8 if big else (4 if cfg.d_model >= 4096 else 2)


def shardify(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *, compile_: bool = True):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_struct = jax.eval_shape(
        lambda k: model_zoo.init(cfg, k), key_struct)
    pspecs = shd.param_specs(params_struct, mesh)
    pshard = shardify(mesh, pspecs)
    specs = model_zoo.input_specs(cfg, shape)
    bshard = shardify(mesh, shd.batch_specs(specs["batch"], mesh))

    with mesh:
        if shape.kind == "train":
            micro = microbatches_for(cfg, shape)
            tcfg = train_loop.TrainConfig(
                microbatches=micro,
                sp=cfg.d_model >= 8192 or cfg.n_layers >= 90,
            )
            step = train_loop.build_train_step(cfg, tcfg, mesh)
            opt_struct = jax.eval_shape(
                partial(opt_mod.init, tcfg.adamw), params_struct)
            ospecs = {
                "step": P(),
                "m": pspecs,
                "v": pspecs,
            }
            oshard = shardify(mesh, ospecs)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_struct, opt_struct, specs["batch"])
            loop_trip = cfg.n_blocks * micro
        elif shape.kind == "prefill":
            step = serve_mod.build_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_struct, specs["batch"])
            loop_trip = cfg.n_blocks
        else:  # decode
            step = serve_mod.build_decode_step(cfg)
            sshard = shardify(mesh, shd.state_specs(specs["state"], mesh))
            jitted = jax.jit(
                step,
                in_shardings=(pshard, sshard, bshard, NamedSharding(mesh, P())),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_struct, specs["state"], specs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32),
            )
            loop_trip = cfg.n_blocks

        lower_s = time.time() - t0
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": chips, "lower_s": round(lower_s, 1),
        }
        if not compile_:
            return rec

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total": int(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            "flops_per_device_raw": float(ca.get("flops", 0.0)),
            "bytes_per_device_raw": float(ca.get("bytes accessed", 0.0)),
        }
        pod_size = 2 if multi_pod else 1
        coll = rf.parse_collectives(compiled.as_text(), loop_trip=loop_trip,
                                    pod_size=pod_size, n_devices=chips)
        rec["collectives"] = {
            "n_ops": coll["n_ops"],
            "per_kind_bytes": {k: float(v) for k, v in coll["per_kind"].items()},
            "link_bytes_corrected": float(coll["link_bytes"]),
            "cross_pod_bytes": float(coll.get("cross_pod_bytes", 0.0)),
            "intra_pod_bytes": float(coll.get("intra_pod_bytes", 0.0)),
            "loop_trip_correction": loop_trip,
        }

        # analytic roofline (primary; see roofline.py docstring)
        if shape.kind == "train":
            an = rf.train_analytic(cfg, shape, chips,
                                   microbatches=microbatches_for(cfg, shape))
        else:
            an = rf.serve_analytic(cfg, shape, chips,
                                   prefill=shape.kind == "prefill")
        t = rf.terms(an.flops, an.hbm_bytes, an.coll_bytes, chips)
        rec["analytic"] = {
            "flops_global": an.flops, "hbm_bytes_global": an.hbm_bytes,
            "coll_bytes_global": an.coll_bytes, **t,
            "model_flops_6nd": an.notes.get("model_flops_6nd", 0.0),
            "useful_ratio_6nd": (
                an.notes.get("model_flops_6nd", 0.0) / an.flops if an.flops else 0.0),
            "params_total": an.notes.get("params_total", 0.0),
        }
        return rec


def load_results() -> dict:
    if RESULTS.exists():
        return json.loads(RESULTS.read_text())
    return {}


def save_results(res: dict):
    RESULTS.write_text(json.dumps(res, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]

    res = load_results()
    for arch in archs:
        shapes = [args.shape] if args.shape else cells(arch)
        for shape_name in shapes:
            for mp in meshes:
                key = f"{arch}|{shape_name}|{'2x16x16' if mp else '16x16'}"
                if args.skip_done and key in res and "error" not in res[key]:
                    print(f"skip {key}")
                    continue
                print(f"=== {key} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mp,
                                     compile_=not args.no_compile)
                    print(json.dumps(
                        {k: rec[k] for k in ("lower_s", "compile_s", "memory")
                         if k in rec}), flush=True)
                except Exception as e:  # record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print("ERROR:", rec["error"], flush=True)
                res[key] = rec
                save_results(res)
    # summary
    errs = [k for k, v in res.items() if "error" in v]
    print(f"\n{len(res)} cells recorded, {len(errs)} errors")
    for k in errs:
        print("  FAIL:", k, res[k]["error"][:120])


if __name__ == "__main__":
    main()
