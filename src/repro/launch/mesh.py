"""Production mesh construction (assignment spec).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state.  Single pod:
16×16 = 256 chips ("data", "model"); multi-pod: 2×16×16 = 512 chips
("pod", "data", "model") — the "pod" axis is the cross-pod DCN/ICI axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes (batch + FSDP sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)
