"""End-to-end training driver: ``--arch <id>`` + shape + mesh.

CPU-scale runs use reduced configs (``--smoke``); on TPU pods the full
configs run with the production mesh.  Fault tolerance: periodic async
checkpoints + resume-from-latest (dist/fault.py)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig, reduced
from repro.dist.fault import Heartbeat
from repro.models import model_zoo
from repro.train import loop as train_loop
from repro.train.optimizer import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param example)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        n_heads=max(args.d_model // 64, 4),
                        n_kv_heads=max(args.d_model // 128, 2),
                        head_dim=64, d_ff=args.d_model * 3, vocab=8192)
        if args.layers:
            over["n_layers"] = args.layers * len(cfg.pattern)
        cfg = reduced(cfg, **over)

    tcfg = train_loop.TrainConfig(
        microbatches=args.micro,
        adamw=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
    )
    params, opt_state = train_loop.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    step_fn = jax.jit(train_loop.build_train_step(cfg, tcfg),
                      donate_argnums=(0, 1))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step():
        start = mgr.latest_step()
        params = mgr.restore(start, params)
        print(f"resumed from step {start}")

    rng = np.random.default_rng(7)
    # synthetic-but-learnable stream: a small pool of sequences cycles, so
    # the loss curve demonstrates optimization (random tokens would floor at
    # ln(vocab)); swap in genomics/pipeline or a token corpus in production.
    pool = [rng.integers(0, cfg.vocab, size=(args.batch, args.seq))
            for _ in range(4)]
    hb = Heartbeat()
    t0 = time.time()
    for step in range(start, args.steps):
        toks = pool[step % len(pool)]
        batch = {
            "tokens": jnp.asarray(toks, jnp.int32),
            "targets": jnp.asarray(np.roll(toks, -1, axis=1), jnp.int32),
            "mask": jnp.ones((args.batch, args.seq), jnp.float32),
        }
        if model_zoo.is_encdec(cfg):
            fd = cfg.frontend_dim or cfg.d_model
            batch["frames"] = jnp.asarray(
                rng.normal(0, 0.02, (args.batch, args.seq, fd)), jnp.float32)
        elif cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            batch["prefix_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (args.batch, cfg.frontend_len or 16, fd)),
                jnp.float32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        slow = hb.beat()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['acc']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}"
                  + (" [straggler]" if slow else ""), flush=True)
        if mgr and (step + 1) % args.save_every == 0:
            mgr.save(step + 1, params)
    if mgr:
        mgr.save(args.steps, params, blocking=True)
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps - start) / max(dt, 1e-9):.2f} steps/s)")


if __name__ == "__main__":
    main()
