"""Batched read-mapping service driver (the paper's workload, end-to-end).

Stateless batches through the lease-based work queue (straggler/failure
reassignment), host prefetch overlapping device compute, PAF output.
On a pod this runs one process per host with reads sharded by
process_index (genomics/pipeline.py)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapper, minimizer_index
from repro.core.genasm import GenASMConfig
from repro.dist.fault import WorkQueue
from repro.genomics import encode, io, pipeline, simulate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref-len", type=int, default=20_000)
    ap.add_argument("--reads", type=int, default=64)
    ap.add_argument("--read-len", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--profile", default="illumina",
                    choices=list(simulate.PROFILES))
    ap.add_argument("--out", default=None, help="PAF output path")
    ap.add_argument("--lease-s", type=float, default=600.0,
                    help="work-queue lease; expired leases are stolen")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas GenASM-DC kernel path")
    args = ap.parse_args(argv)

    prof = simulate.PROFILES[args.profile]
    ref = simulate.random_reference(args.ref_len, seed=1)
    print(f"indexing reference ({args.ref_len} bp)...")
    idx = minimizer_index.build_reference_index(ref, w=8, k=12)
    rs = simulate.simulate_reads(ref, n_reads=args.reads,
                                 read_len=args.read_len, profile=prof, seed=2)
    cap = ((args.read_len + 63) // 64) * 64 + 64
    cfg = GenASMConfig(use_kernel=args.use_kernel)

    map_fn = jax.jit(lambda r, l: mapper.map_batch(
        idx, r, l, cfg=cfg, p_cap=cap + 64, filter_bits=128,
        filter_k=max(8, int(args.read_len * prof.error_rate * 1.5)),
        minimizer_w=8, minimizer_k=12))

    pi, pc = jax.process_index(), jax.process_count()
    n_shard = len(range(pi, args.reads, pc))  # reads this process owns
    batches = list(pipeline.ReadBatches(
        rs.reads, batch=args.batch, cap=cap,
        process_index=pi, process_count=pc))
    q = WorkQueue(len(batches), lease_s=args.lease_s)
    rows = []
    t0 = time.time()
    mapped = 0
    while True:
        b = q.claim()
        if b is None:
            break
        _, arr, lens = batches[b]
        res = map_fn(jnp.asarray(arr), jnp.asarray(lens))
        pos = np.asarray(res.position)
        dist = np.asarray(res.distance)
        ops = np.asarray(res.ops)
        n_ops = np.asarray(res.n_ops)
        for i in range(len(pos)):
            # global read id under process_index striding (pipeline.ReadBatches)
            gid = pi + (b * args.batch + i) * pc
            if gid >= args.reads or lens[i] == 0:
                continue
            if pos[i] >= 0:
                mapped += 1
                rows.append({
                    "qname": f"read{gid}", "qlen": int(lens[i]), "qstart": 0,
                    "qend": int(lens[i]), "strand": "+", "tname": "ref",
                    "tlen": args.ref_len, "tstart": int(pos[i]),
                    "tend": int(pos[i]) + int(lens[i]), "nmatch": int(lens[i]) - int(dist[i]),
                    "alnlen": int(lens[i]), "mapq": 60,
                    "cigar": io.cigar_string(ops[i], int(n_ops[i])),
                })
        q.complete(b)
    dt = time.time() - t0
    correct = sum(
        1 for r in rows
        if abs(r["tstart"] - rs.true_pos[int(r["qname"][4:])]) <= 16)
    print(f"mapped {mapped}/{n_shard} reads in {dt:.2f}s "
          f"({n_shard / dt if dt else 0.0:.1f} reads/s); "
          f"position-correct: {correct}/{mapped}")
    if args.out:
        io.write_paf(args.out, rows)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
