"""Read-mapping service driver (the paper's workload, end-to-end).

Both serving modes sit on the same ``repro.serve`` micro-batching engine
(length-bucketed padding, per-bucket compiled executors, result cache —
DESIGN.md §8), so they produce identical output for the same read set:

* **offline** (default) — drain a fixed read set through the lease-based
  work queue (straggler/failure reassignment, DESIGN.md §6); each claimed
  quantum's reads are submitted to the engine.
* **``--online``** — synthetic open-loop Poisson arrivals through the
  engine's admission queue (`serve/session.py`), reporting reads/s and
  tail latency.

Both compose with the workload axis (DESIGN.md §10): ``--mode linear``
emits PAF against a linear reference, ``--mode graph`` builds a
variation-graph index and emits GAF (node path + CIGAR) through the
``graph_lax``/``graph_pallas`` backends — and with the sharding axis
(DESIGN.md §11): ``--num-shards N`` partitions the reference index
across N devices (`repro.shard` scatter/merge), byte-identical output.

The observability plane (DESIGN.md §12) attaches with two flags:
``--trace-out trace.json`` traces every flush and writes a
Perfetto/Chrome ``trace_event`` file plus the per-stage Amdahl
attribution table on exit; ``--http-port N`` serves ``/metrics``,
``/healthz``, ``/trace``, and ``/attrib`` from a daemon thread while
the run is live (port 0 = ephemeral).

On a pod this runs one process per host with reads sharded by
process_index.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import minimizer_index
from repro.core.genasm import GenASMConfig
from repro.dist.fault import WorkQueue
from repro.serve import EngineConfig, ServeEngine, Session, poisson_load
from repro.genomics import io, simulate


def paf_row(gid: int, res, ref_len: int) -> dict:
    """PAF row dict for one mapped read.

    Carries the global read id in ``"gid"`` (not a PAF column — strip via
    `strip_gids` before `io.write_paf`), so qnames can be arbitrary
    instead of being parsed back into ids.
    """
    L = res.read_len
    return {
        "gid": gid,
        "qname": f"read{gid}", "qlen": L, "qstart": 0,
        "qend": L, "strand": "+", "tname": "ref",
        "tlen": ref_len, "tstart": res.position,
        "tend": res.position + L, "nmatch": L - res.distance,
        "alnlen": L, "mapq": 60,
        "cigar": io.cigar_string(res.ops, res.n_ops),
    }


def gaf_row(gid: int, res) -> dict:
    """GAF row dict for one graph-mapped read (node path + CIGAR).

    ``"tstart"`` (backbone coordinate of the first aligned node) rides
    along for position accounting — neither writer emits it.
    """
    L = res.read_len
    pstr, plen = io.gaf_path(res.path if res.path is not None else ())
    return {
        "gid": gid,
        "qname": f"read{gid}", "qlen": L, "qstart": 0,
        "qend": L, "strand": "+", "path": pstr,
        "plen": plen, "pstart": 0, "pend": plen,
        "nmatch": L - res.distance, "alnlen": int(res.n_ops), "mapq": 60,
        "tstart": res.position,
        "cigar": io.cigar_string(res.ops, res.n_ops),
    }


def strip_gids(rows: list[dict]) -> list[dict]:
    return [{k: v for k, v in r.items() if k != "gid"} for r in rows]


def _run_offline(engine: ServeEngine, reads, shard_ids, *, batch: int,
                 lease_s: float, row_fn) -> list[dict]:
    """Work-queue path: claim a quantum of read ids, submit it, complete."""
    quanta = [shard_ids[i: i + batch] for i in range(0, len(shard_ids), batch)]
    q = WorkQueue(len(quanta), lease_s=lease_s)
    rows: dict[int, dict] = {}  # keyed by gid: stolen twins overwrite, not dup
    while True:
        b = q.claim()
        if b is None:
            if q.finished:
                break
            time.sleep(0.01)  # all leases live; back off and retry
            continue
        sess = Session(engine)
        for gid in quanta[b]:
            sess.submit(reads[gid], meta=int(gid))
        for gid, res in sess.drain():
            if res.position >= 0:
                rows[gid] = row_fn(gid, res)
        q.complete(b)
    return [rows[g] for g in sorted(rows)]


def _run_online(engine: ServeEngine, reads, shard_ids, *, rate_rps: float,
                seed: int, row_fn) -> tuple[list[dict], object]:
    """Poisson open-loop path through the engine's admission queue."""
    rep = poisson_load(engine, [reads[g] for g in shard_ids],
                       rate_rps=rate_rps, seed=seed,
                       metas=[int(g) for g in shard_ids])
    rows = [row_fn(gid, res) for gid, res in rep.results
            if res.position >= 0]
    return sorted(rows, key=lambda r: r["gid"]), rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ref-len", type=int, default=20_000)
    ap.add_argument("--reads", type=int, default=64)
    ap.add_argument("--read-len", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--profile", default="illumina",
                    choices=list(simulate.PROFILES))
    ap.add_argument("--out", default=None, help="PAF/GAF output path")
    ap.add_argument("--lease-s", type=float, default=600.0,
                    help="work-queue lease; expired leases are stolen")
    ap.add_argument("--mode", default="linear", choices=("linear", "graph"),
                    help="linear reference → PAF, or variation graph → GAF "
                         "(DESIGN.md §10)")
    ap.add_argument("--variants", type=int, default=None,
                    help="--mode graph: simulated variant count "
                         "(default ref_len // 200)")
    ap.add_argument("--align-backend", default="auto",
                    help="repro.align backend: auto|ref|lax|pallas_dc|"
                         "pallas_dc_v2|graph_lax|graph_pallas (auto = Pallas "
                         "on TPU/GPU, lax on CPU, graph twins under --mode "
                         "graph; env REPRO_ALIGN_BACKEND overrides auto)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="deprecated alias for --align-backend pallas_dc")
    ap.add_argument("--num-shards", type=int, default=1,
                    help="shard the reference index over N devices "
                         "(repro.shard scatter/merge; works on CPU via "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N"
                         ", falling back to a vmapped single-device "
                         "execution with identical output when fewer "
                         "devices exist); PAF/GAF is byte-identical to "
                         "--num-shards 1")
    ap.add_argument("--align-sharded", action="store_true",
                    help="with --num-shards > 1: split the winning-window "
                         "align stage over the shard mesh too "
                         "(byte-identical output)")
    ap.add_argument("--pipelined", action="store_true",
                    help="with --num-shards > 1: double-buffer flushes — "
                         "overlap batch i's align with batch i+1's "
                         "scatter dispatch (byte-identical output)")
    ap.add_argument("--online", action="store_true",
                    help="open-loop Poisson arrivals instead of the "
                         "offline work-queue drain")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="--online arrival rate (reads/s)")
    ap.add_argument("--buckets", default="160,320,640,1280",
                    help="length-bucket ladder of pattern caps")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="micro-batch flush deadline")
    ap.add_argument("--trace-out", default=None,
                    help="trace every flush and write Perfetto/Chrome "
                         "trace_event JSON here (plus the per-stage "
                         "Amdahl table on exit)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve /metrics /healthz /trace /attrib /roofline "
                         "on this port while running (0 = ephemeral)")
    args = ap.parse_args(argv)

    prof = simulate.PROFILES[args.profile]
    ref = simulate.random_reference(args.ref_len, seed=1)
    rs = simulate.simulate_reads(ref, n_reads=args.reads,
                                 read_len=args.read_len, profile=prof, seed=2)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    need = ((args.read_len + 63) // 64) * 64 + 64  # offline driver's old cap
    if max(buckets) < need:  # never trim reads the single-cap path held
        buckets += (need,)
    if args.use_kernel and args.align_backend != "auto":
        ap.error("--use-kernel is a deprecated alias for --align-backend "
                 "pallas_dc; don't combine it with an explicit "
                 "--align-backend")
    backend = "pallas_dc" if args.use_kernel else args.align_backend
    genasm = GenASMConfig()

    if args.mode == "graph":
        from repro.graph import index as graph_index

        n_var = args.variants if args.variants is not None \
            else max(args.ref_len // 200, 4)
        variants = simulate.simulate_variants(
            ref, n_snp=n_var // 2, n_ins=n_var // 4, n_del=n_var // 4, seed=3)
        print(f"indexing variation graph ({args.ref_len} bp backbone, "
              f"{len(variants)} variants)...")
        epi = graph_index.build_epoched_graph_index(
            ref, variants, w=8, k=12,
            window=max(buckets) + 2 * genasm.w)  # largest bucket's t_cap
        row_fn, writer = gaf_row, io.write_gaf
    else:
        print(f"indexing reference ({args.ref_len} bp)...")
        epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
        row_fn = lambda gid, res: paf_row(gid, res, args.ref_len)  # noqa: E731
        writer = io.write_paf

    cfg = EngineConfig(
        buckets=buckets, max_batch=args.batch,
        max_delay_s=args.max_delay_ms / 1e3,
        genasm=genasm,
        align_backend=backend,
        workload=args.mode,
        filter_k=max(8, int(args.read_len * prof.error_rate * 1.5)),
        num_shards=args.num_shards,
        align_sharded=args.align_sharded,
        pipelined=args.pipelined,
        minimizer_w=8, minimizer_k=12)

    pi, pc = jax.process_index(), jax.process_count()
    shard_ids = np.arange(pi, args.reads, pc)  # this host's disjoint slice

    tracer = None
    roofline = None
    if args.trace_out or args.http_port is not None:
        from repro.obs import RooflineManager, Tracer

        tracer = Tracer()
        roofline = RooflineManager(tracer=tracer)

    obs_server = None
    with ServeEngine(epi, cfg, tracer=tracer, roofline=roofline) as engine:
        if roofline is not None:
            roofline.metrics = engine.metrics
        if args.http_port is not None:
            from repro.obs.http import ObsServer

            obs_server = ObsServer(metrics=engine.metrics, tracer=tracer,
                                   roofline=roofline, port=args.http_port)
            print(f"obs endpoints at {obs_server.url} "
                  f"(/metrics /healthz /trace /attrib /roofline)")
        print(f"align backend: {engine.align_backend}")
        t0 = time.time()
        if args.online:
            rows, rep = _run_online(engine, rs.reads, shard_ids,
                                    rate_rps=args.rate, seed=7, row_fn=row_fn)
            print(f"online: {rep.reads_per_s:.1f} reads/s, "
                  f"p50 {rep.p50_ms:.1f} ms, p99 {rep.p99_ms:.1f} ms")
        else:
            rows = _run_offline(engine, rs.reads, shard_ids,
                                batch=args.batch, lease_s=args.lease_s,
                                row_fn=row_fn)
        dt = time.time() - t0
        m = engine.metrics.snapshot()
        hit_rate = engine.cache.hit_rate
    if obs_server is not None:
        obs_server.close()
    if tracer is not None:
        from repro.obs import build_ledger, render_report

        print(render_report(build_ledger(tracer.log).report()))
        if roofline is not None:
            # measure=False: no cost_analysis compiles at shutdown
            for row in roofline.report(measure=False)["kernels"]:
                print(f"roofline {row['kernel']}: "
                      f"{row['achieved_ops_per_s'] / 1e9:.2f} Gop/s, "
                      f"intensity {row['intensity']:.2f} op/B, "
                      f"{row['pct_of_roof']:.2%} of roof")
        if args.trace_out:
            tracer.log.export_chrome(args.trace_out)
            print(f"wrote {args.trace_out}")

    mapped = len(rows)
    correct = sum(
        1 for r in rows if abs(r["tstart"] - rs.true_pos[r["gid"]]) <= 16)
    occ = m.get("batch_occupancy_mean", 0.0)
    useful = m.get("bases_useful", 0.0)
    waste = m.get("bases_padded_read", 0.0)
    print(f"mapped {mapped}/{len(shard_ids)} reads in {dt:.2f}s "
          f"({len(shard_ids) / dt if dt else 0.0:.1f} reads/s); "
          f"position-correct: {correct}/{mapped}")
    print(f"batch occupancy {occ:.2f}, padded-base waste "
          f"{waste / max(useful + waste, 1):.1%}, "
          f"cache hit rate {hit_rate:.1%}")
    if args.out:
        writer(args.out, strip_gids(rows))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
