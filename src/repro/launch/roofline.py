"""Roofline-term derivation from the compiled dry-run (TPU v5e targets).

Terms (per device, seconds):
    compute    = FLOPs / peak_flops    (bf16 peak per chip; v5e 197e12)
    memory     = HBM bytes / hbm_bw    (v5e 819e9)
    collective = Σ link-bytes / link_bw  (per ICI link, ring-weighted;
                 v5e 50e9)

The device constants come from `repro.obs.roofline.DeviceSpec` (the
bundled ``tpu_v5e.json`` — the numbers that used to be hardcoded here);
the module-level ``PEAK_FLOPS``/``HBM_BW``/``LINK_BW`` names remain as
the loaded values for existing callers.

Two sources, reported side by side (EXPERIMENTS.md §Roofline):

* **measured**: ``compiled.cost_analysis()`` flops/bytes + collective
  operand bytes parsed from the compiled HLO text.  CAVEAT (verified on
  this backend): XLA cost analysis counts a ``while`` body ONCE, so
  scan-over-layers/microbatch/KV-block loops undercount.  Parsed
  collectives inside loop-body computations are corrected by the known
  trip counts; flops/bytes get the same documented correction factor.

* **analytic**: exact component model of our own architectures
  (matmul dims, attention S², MoE capacity, SSM scans, remat ×2 forward,
  optimizer traffic).  This is the primary number for §Perf iteration —
  it is exact for our code and responds to sharding/schedule changes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.obs.roofline import DeviceSpec

_V5E = DeviceSpec.load("tpu_v5e")
PEAK_FLOPS = _V5E.peak_flops  # bf16 / chip (v5e)
HBM_BW = _V5E.hbm_bw  # bytes/s / chip
LINK_BW = _V5E.link_bw  # bytes/s / ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8": 1}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_bytes(line: str) -> int:
    """Sum of result-shape bytes of a collective instruction line."""
    total = 0
    lhs = line.split("=")[0] + "=" + line.split("=")[1].split("(")[0]
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _group_crosses_pod(line: str, pod_size: int, n_devices: int) -> bool | None:
    """Decode an iota replica_groups pattern; True if any group spans pods.

    Pattern ``[A,B]<=[d0,..]T(p)``: groups = iota(N).reshape(d).transpose(p)
    .reshape(A,B).  Pod membership = device_id // (N // pod_size).
    """
    m = _GROUPS_RE.search(line)
    if not m:
        return None
    a, b = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    v = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        v = v.transpose([int(x) for x in m.group(4).split(",")])
    groups = v.reshape(a, b)
    per_pod = n_devices // pod_size
    pods = groups // per_pod
    return bool(np.any(pods.max(axis=1) != pods.min(axis=1)))


_RING_FACTOR = {
    # ring-cost weight per op kind: bytes moved per link ≈ weight × payload
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str, loop_trip: int = 1, *, pod_size: int = 1,
                      n_devices: int = 512) -> dict:
    """Collective payload bytes from compiled HLO, loop-body corrected.

    ``loop_trip``: multiplier applied to collectives inside while-body
    computations (identified by computation-name heuristics: region/body/
    cond/while substrings) — the known scan trip count.

    With ``pod_size > 1`` the replica-group iota patterns are decoded and
    payloads classified as intra-pod (ICI) vs pod-crossing (DCN): the
    cross-pod class is the scarce resource the §Perf iterations target.
    """
    per_kind: dict[str, float] = {}
    cross_pod = 0.0
    intra_pod = 0.0
    count = 0
    cur_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*\(.*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            cur_comp = m.group(1)
            continue
        cm = _COLL_RE.search(stripped)
        if cm and "=" in stripped:
            kind = cm.group(1)
            b = _op_bytes(stripped)
            inside_loop = any(t in cur_comp for t in ("while", "body", "region", "cond"))
            mult = loop_trip if inside_loop else 1
            per_kind[kind] = per_kind.get(kind, 0.0) + b * mult
            count += 1
            if pod_size > 1:
                crosses = _group_crosses_pod(stripped, pod_size, n_devices)
                if crosses:
                    cross_pod += b * mult * _RING_FACTOR[kind]
                else:
                    intra_pod += b * mult * _RING_FACTOR[kind]
    link_bytes = sum(_RING_FACTOR[k] * v for k, v in per_kind.items())
    return {"per_kind": per_kind, "n_ops": count, "link_bytes": link_bytes,
            "cross_pod_bytes": cross_pod, "intra_pod_bytes": intra_pod}


# ------------------------------------------------------------- analytic ---

@dataclass
class Analytic:
    flops: float = 0.0  # global
    hbm_bytes: float = 0.0  # global
    coll_bytes: float = 0.0  # global payload over the slowest-link class
    notes: dict = field(default_factory=dict)


def param_count(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the config."""
    d, hd = cfg.d_model, cfg.hd
    per_block_total = per_block_active = 0.0
    for slot, kind in enumerate(cfg.pattern):
        if kind == "attn":
            a = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
            per_block_total += a
            per_block_active += a
        elif kind == "mamba":
            di = cfg.mamba.expand * d
            a = d * 2 * di + di * d + di * (cfg.mamba.d_state * 2 + d // 16) + \
                (d // 16) * di
            per_block_total += a
            per_block_active += a
        elif kind == "rwkv":
            a = 5 * d * d + d * d  # time-mix projections + output
            per_block_total += a
            per_block_active += a
        # mlp/moe
        if kind == "rwkv":
            m = d * cfg.d_ff * 2 + d * d
            per_block_total += m
            per_block_active += m
        elif cfg.moe is not None and slot in cfg.moe_slots:
            n_mats = 3 if cfg.act == "silu_glu" else 2
            per_block_total += cfg.moe.n_experts * n_mats * d * cfg.moe.d_ff_expert
            per_block_active += cfg.moe.top_k * n_mats * d * cfg.moe.d_ff_expert
        else:
            n_mats = 3 if cfg.act == "silu_glu" else 2
            per_block_total += n_mats * d * cfg.d_ff
            per_block_active += n_mats * d * cfg.d_ff
    total = per_block_total * cfg.n_blocks
    active = per_block_active * cfg.n_blocks
    if cfg.enc_layers:
        enc = cfg.enc_layers * (d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
                                + 2 * d * cfg.d_ff)
        xattn = cfg.n_layers * d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        total += enc + xattn
        active += enc + xattn
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def train_analytic(cfg, shape, chips: int, *, microbatches: int = 1,
                   remat: bool = True) -> Analytic:
    """Global FLOPs/bytes/collectives for one train step."""
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    total, active = param_count(cfg)
    emb = cfg.padded_vocab * cfg.d_model
    matmul_params = active - emb * (1 if cfg.tie_embeddings else 2) * 0  # matmul path incl. head
    # matmul flops: fwd 2·N·D; bwd 4·N·D; remat refwd 2·N·D
    mult = (2 + 4 + (2 if remat else 0))
    flops = mult * matmul_params * tokens
    # attention scores: 2·S²·hd·H per layer fwd (causal halves it), ×(fwd+bwd+remat)
    n_attn = cfg.pattern.count("attn") * cfg.n_blocks + cfg.enc_layers + (
        cfg.n_layers if cfg.enc_layers else 0)
    win = min(cfg.sliding_window or S, S)
    score = 2 * 2 * B * S * win * cfg.n_heads * cfg.hd * 0.5
    flops += (3 + (1 if remat else 0)) * score * n_attn
    # lm head + loss
    flops += (2 + 4) * tokens * cfg.d_model * cfg.padded_vocab

    # HBM bytes (per step, global): weights traffic ×(fwd+bwd+remat refwd)
    # ×microbatches (FSDP regather per microbatch), bf16 compute copies.
    wbytes = total * 2 * (3 if remat else 2) * microbatches
    # optimizer: read p,m,v,g + write p,m,v (fp32 p/g, bf16 moments)
    obytes = total * (4 + 4 + 2 + 2) + total * (4 + 2 + 2)
    # activations: layer-boundary saves + recompute reads (bf16)
    act = cfg.n_layers * tokens * cfg.d_model * 2 * (4 if remat else 6)
    an = Analytic()
    an.flops = flops
    an.hbm_bytes = wbytes + obytes + act
    # collectives: FSDP all-gather params (bf16) fwd+bwd per microbatch +
    # grad reduce-scatter (fp32) + TP activation all-reduce 2/layer (bf16)
    fsdp = total * 2 * 2 * microbatches + total * 4
    tp_ar = 2 * cfg.n_layers * tokens * cfg.d_model * 2 * 2  # ring ≈ 2× payload
    an.coll_bytes = fsdp + tp_ar
    an.notes = {"params_total": total, "params_active": active,
                "model_flops_6nd": 6 * active * tokens}
    return an


def serve_analytic(cfg, shape, chips: int, *, prefill: bool) -> Analytic:
    B, S = shape.global_batch, shape.seq_len
    total, active = param_count(cfg)
    an = Analytic()
    if prefill:
        tokens = B * S
        an.flops = 2 * active * tokens
        n_attn = cfg.pattern.count("attn") * cfg.n_blocks + cfg.enc_layers + (
            cfg.n_layers if cfg.enc_layers else 0)
        win = min(cfg.sliding_window or S, S)
        an.flops += 2 * B * S * win * cfg.n_heads * cfg.hd * 0.5 * n_attn * 2
        an.hbm_bytes = total * 2 + tokens * cfg.d_model * 2 * cfg.n_layers * 2
        an.coll_bytes = total * 2 + 2 * cfg.n_layers * tokens * cfg.d_model * 2 * 2
        an.notes = {"model_flops_6nd": 2 * active * tokens}
        return an
    # decode: one token for the whole batch
    tokens = B
    an.flops = 2 * active * tokens
    # KV/state read is the decode bottleneck
    n_attn = cfg.pattern.count("attn") * cfg.n_blocks
    win = min(cfg.sliding_window or S, S)
    kv = n_attn * B * win * cfg.n_kv_heads * cfg.hd * 2 * 2
    state = 0.0
    if "mamba" in cfg.pattern:
        di = cfg.mamba.expand * cfg.d_model
        state += cfg.pattern.count("mamba") * cfg.n_blocks * B * di * \
            cfg.mamba.d_state * 4 * 2
    if "rwkv" in cfg.pattern:
        dh = cfg.d_model // cfg.n_heads
        state += cfg.n_layers * B * cfg.n_heads * dh * dh * 4 * 2
    an.flops += n_attn * 2 * B * win * cfg.n_heads * cfg.hd * 2
    an.hbm_bytes = total * 2 + kv + state
    an.coll_bytes = total * 2 * 0 + 2 * cfg.n_layers * B * cfg.d_model * 2 * 2
    an.notes = {"model_flops_6nd": 2 * active * tokens, "kv_bytes": kv + state}
    return an


def terms(flops, hbm, coll, chips: int) -> dict:
    """Global quantities -> per-chip roofline seconds."""
    c = flops / chips / PEAK_FLOPS
    m = hbm / chips / HBM_BW
    l = coll / chips / LINK_BW
    dom = max(("compute", c), ("memory", m), ("collective", l), key=lambda t: t[1])
    return {
        "compute_s": c, "memory_s": m, "collective_s": l,
        "bottleneck": dom[0],
        "roofline_s": max(c, m, l),
        "mfu_bound": c / max(c, m, l, 1e-30),
    }
