"""Host-side numpy oracles for the Bitap/GenASM family (test ground truth).

Small, obviously-correct dynamic programming implementations.  Used by the
test suite (including hypothesis property tests) and by accuracy analyses;
never on the hot path.
"""
from __future__ import annotations

import numpy as np


def levenshtein_prefix(pattern: np.ndarray, text: np.ndarray) -> int:
    """min over text prefixes of the edit distance to the full pattern.

    Matches GenASM's anchored semi-global semantics: the alignment starts at
    ``text[0]`` (leading deletions cost) and trailing text is free.
    """
    m, n = len(pattern), len(text)
    prev = np.arange(n + 1)
    best = m  # j = 0 column: all insertions
    for i in range(1, m + 1):
        cur = np.empty(n + 1, np.int64)
        cur[0] = i
        cost = (pattern[i - 1] != text).astype(np.int64)
        for j in range(1, n + 1):
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost[j - 1])
        prev = cur
        if i == m:
            best = int(prev.min())
    return best


def levenshtein(a: np.ndarray, b: np.ndarray) -> int:
    """Plain (global, NW) unit-cost edit distance."""
    m, n = len(a), len(b)
    prev = np.arange(n + 1)
    for i in range(1, m + 1):
        cur = np.empty(n + 1, np.int64)
        cur[0] = i
        cost = (a[i - 1] != b).astype(np.int64)
        for j in range(1, n + 1):
            cur[j] = min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + cost[j - 1])
        prev = cur
    return int(prev[n])


def check_cigar(ops: np.ndarray, n_ops: int, pattern: np.ndarray, text: np.ndarray,
                distance: int) -> str | None:
    """Validate a packed CIGAR against the pair.  Returns None or an error string.

    Invariants: M consumes one of each and chars match; X consumes one of
    each and chars differ; I consumes pattern only; D consumes text only;
    the full pattern is consumed; #X + #I + #D == distance.
    """
    pi = ti = edits = 0
    for s in range(int(n_ops)):
        op = int(ops[s])
        if op == 0:  # M
            if pi >= len(pattern) or ti >= len(text):
                return f"M out of range at step {s}"
            if pattern[pi] != text[ti]:
                return f"M mismatch at step {s}: p[{pi}]={pattern[pi]} t[{ti}]={text[ti]}"
            pi += 1
            ti += 1
        elif op == 1:  # X
            if pi >= len(pattern) or ti >= len(text):
                return f"X out of range at step {s}"
            if pattern[pi] == text[ti]:
                return f"X on equal chars at step {s}"
            pi += 1
            ti += 1
            edits += 1
        elif op == 2:  # I
            if pi >= len(pattern):
                return f"I out of range at step {s}"
            pi += 1
            edits += 1
        elif op == 3:  # D
            if ti >= len(text):
                return f"D out of range at step {s}"
            ti += 1
            edits += 1
        else:
            return f"bad op {op} at step {s}"
    if pi != len(pattern):
        return f"pattern not fully consumed: {pi} != {len(pattern)}"
    if edits != distance:
        return f"edit count {edits} != reported distance {distance}"
    return None


def graph_edit_distance_anchored(pattern: np.ndarray, nodes: np.ndarray,
                                 preds: list[list[int]],
                                 start: int = 0) -> int:
    """Anchored semi-global sequence-to-graph distance oracle.

    The first consumed node must be ``start`` (leading skipped graph
    would cost deletions, exactly the linear ``levenshtein_prefix``
    anchor), the pattern is fully consumed, trailing graph is free.
    Ground truth for the windowed graph backends' anchored semantics.
    """
    m = len(pattern)
    n = len(nodes)
    INF = 10 ** 9
    # A[j][i] = min edits: pattern[:j] consumed, node i consumed last,
    # node-consuming ops walking a path that began at `start`
    A = np.full((m + 1, n), INF, np.int64)
    for j in range(m + 1):
        for i in range(n):
            best = INF
            cost = 0 if j > 0 and pattern[j - 1] == nodes[i] else 1
            if i == start:
                best = j + 1  # j leading insertions, then delete `start`
                if j > 0:
                    best = min(best, (j - 1) + cost)  # … then match/subst
            if j > 0 and A[j - 1][i] < INF:
                best = min(best, A[j - 1][i] + 1)  # insertion at i
            for p in preds[i]:
                if j > 0 and A[j - 1][p] < INF:
                    best = min(best, A[j - 1][p] + cost)  # match/subst edge
                if A[j][p] < INF:
                    best = min(best, A[j][p] + 1)  # deletion of node i
            A[j][i] = best
    return int(min(A[m].min(), m))  # all-insertions consumes no node


def graph_edit_distance(pattern: np.ndarray, nodes: np.ndarray,
                        preds: list[list[int]]) -> int:
    """Sequence-to-graph semi-global distance oracle (PaSGAL semantics).

    ``nodes``: one base per linearized node (topological order);
    ``preds[i]``: predecessor node ids of node i.  The alignment may start
    at any node and end anywhere; pattern fully consumed.
    DP over (node, pattern position) with edges following predecessors.
    """
    m = len(pattern)
    n = len(nodes)
    INF = 10 ** 9
    # dist[i][j] = min edits aligning pattern[:j] ending at node i (node i consumed last)
    # We use the standard formulation: D[j][i] over pattern rows.
    D = np.full((m + 1, n), INF, np.int64)
    D[0, :] = 0  # start anywhere with empty pattern (leading text free = start anywhere)
    for j in range(1, m + 1):
        # insertion (consume pattern only): D[j][i] = D[j-1][i] + 1
        D[j, :] = D[j - 1, :] + 1
        # propagate along edges for match/subs/deletion, in topological order
        for i in range(n):
            best = D[j, i]
            cost = 0 if pattern[j - 1] == nodes[i] else 1
            if not preds[i]:
                cand = (0 if j == 1 or True else INF)
                # starting fresh at node i: pattern[:j-1] must be insertions
                best = min(best, (j - 1) + cost)
            for p in preds[i]:
                best = min(best, D[j - 1, p] + cost)  # match/subs over edge
                best = min(best, D[j, p] + 1)  # deletion of node p->i path char
            # Also allow starting at node i even when it has predecessors
            best = min(best, (j - 1) + cost)
            D[j, i] = best
        # deletion sweep needs a second pass for within-rank chains (topological
        # order makes one pass sufficient for DAGs as preds precede i)
    return int(D[m, :].min())
