"""Myers' 1999 bit-parallel edit distance — the Edlib software baseline.

The paper's Use Case 3 (§4.10.4) compares GenASM against Edlib, whose core
is Myers' bitvector algorithm.  We implement the multi-word (blocked)
variant in JAX so the benchmark compares *algorithms* on identical
hardware.  Bit convention differs from Bitap: bit ``j`` ↔ pattern position
``j`` (LSB = pattern[0]) and 1 = match in ``PEq``.

Supports the global (NW) score and the semi-global search score
(min over text end positions, free text start), per Hyyrö's formulation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .bitvector import WORD_BITS, n_words


def _peq(pattern: jnp.ndarray, m_bits: int) -> jnp.ndarray:
    """[5, nw] uint32; bit j of PEq[c] = 1 iff pattern[j] == c (wildcard matches all)."""
    nw = n_words(m_bits)
    p = pattern.astype(jnp.int32)
    chars = jnp.arange(5, dtype=jnp.int32)
    m = (p[None, :] == chars[:, None]) | (p[None, :] == 4)
    m = m.astype(jnp.uint32).reshape(5, nw, WORD_BITS)
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(m * weights, axis=-1, dtype=jnp.uint32)


def _add_with_carry(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Multi-word add (little-endian word axis -1), dropping the final carry."""

    def step(cin, ab):
        aw, bw = ab
        s1 = aw + bw
        c1 = (s1 < aw).astype(jnp.uint32)
        s2 = s1 + cin
        c2 = (s2 < s1).astype(jnp.uint32)
        return c1 | c2, s2

    _, out = lax.scan(step, jnp.uint32(0), (a, b))
    return out


def _shl1_in(x: jnp.ndarray, bit_in) -> jnp.ndarray:
    carry = x >> 31
    shifted = x << 1
    incoming = jnp.concatenate(
        [jnp.asarray(bit_in, jnp.uint32)[None], carry[:-1]], axis=0
    )
    return shifted | incoming


@partial(jax.jit, static_argnames=("m_bits", "mode"))
def myers_distance(text: jnp.ndarray, pattern: jnp.ndarray, m_len, *, m_bits: int,
                   mode: str = "global"):
    """Edit distance by Myers' algorithm.

    ``text``: [n] int8; ``pattern``: [m_bits] int8 (pad with wildcard —
    wildcards bias the score by matching everything, so callers must pass
    ``m_len`` = real pattern length; the score is read at bit ``m_len-1``).

    ``mode``: "global" (NW distance of pattern vs full text) or "semiglobal"
    (min over text prefixes, free start — Edlib's HW/search-ish mode).
    Returns int32 distance.
    """
    nw = n_words(m_bits)
    peq = _peq(pattern, m_bits)
    score_word = (m_len - 1) // WORD_BITS
    score_off = ((m_len - 1) % WORD_BITS).astype(jnp.uint32)

    Pv0 = jnp.full((nw,), 0xFFFFFFFF, jnp.uint32)
    Mv0 = jnp.zeros((nw,), jnp.uint32)
    carry_in = jnp.uint32(1) if mode == "global" else jnp.uint32(0)

    def step(state, c):
        Pv, Mv, score = state
        Eq = peq[c]
        Xv = Eq | Mv
        Xh = (_add_with_carry(Eq & Pv, Pv) ^ Pv) | Eq
        Ph = Mv | ~(Xh | Pv)
        Mh = Pv & Xh
        ph_bit = (jnp.take(Ph, score_word) >> score_off) & 1
        mh_bit = (jnp.take(Mh, score_word) >> score_off) & 1
        score = score + ph_bit.astype(jnp.int32) - mh_bit.astype(jnp.int32)
        Ph = _shl1_in(Ph, carry_in)
        Mh = _shl1_in(Mh, jnp.uint32(0))
        Pv = Mh | ~(Xv | Ph)
        Mv = Ph & Xv
        return (Pv, Mv, score), score

    init = (Pv0, Mv0, m_len.astype(jnp.int32))
    (_, _, final), scores = lax.scan(step, init, text.astype(jnp.int32))
    if mode == "global":
        return final
    return jnp.minimum(jnp.min(scores), m_len.astype(jnp.int32))
