"""GenASM-TB: the paper's Bitap-compatible traceback (Algorithm 2).

Walks the per-(text position, distance) intermediate bitvectors emitted by
GenASM-DC from the MSB (pattern[0]) toward the LSB, following the chain of
0s and reverting the DC bitwise operations.  Emits packed CIGAR ops:

    0 = M (match)   1 = X (substitution)   2 = I (insertion)   3 = D (deletion)
    -1 = padding

The check order implements the paper's "partial support for complex scoring
schemes": with ``affine=True`` a gap extension (previous op was I/D and the
same gap can continue) is preferred, mimicking the affine gap model; the
remaining priority is match > substitution > insertion > deletion.

The walk is data-dependent and sequential per alignment (the ASIC uses an
FSM); here it is a fixed-trip ``fori_loop`` so it vmaps across thousands of
alignments — on TPU the batch axis is the vector axis (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .bitvector import get_bit
from .genasm_dc import TB_DEL, TB_INS, TB_MATCH

OP_M, OP_X, OP_I, OP_D = 0, 1, 2, 3
OP_PAD = -1


@partial(jax.jit, static_argnames=("w", "o", "k", "affine"))
def window_tb(
    tb: jnp.ndarray,
    d_start: jnp.ndarray,
    cap_p: jnp.ndarray,
    *,
    w: int,
    o: int,
    k: int,
    affine: bool = True,
):
    """Traceback over one window.

    ``tb``: ``[w, k+1, 3, nw] uint32`` from :func:`window_dc`.
    ``d_start``: window minimum distance (int32).
    ``cap_p``: pattern commit cap — ``min(w - o, remaining pattern)``.

    Returns ``(pc, tc, err_used, ops [2*(w-o)] int8, n_ops, stuck)``.
    """
    max_steps = 2 * (w - o)
    cap_t = jnp.int32(w - o)
    cap_p = jnp.asarray(cap_p, jnp.int32)

    def body(_, st):
        patternI, textI, curError, prev_op, pc, tc, n_ops, ops, stuck = st
        active = (pc < cap_p) & (tc < cap_t) & (patternI >= 0) & (~stuck)

        ti = jnp.clip(textI, 0, w - 1)
        de = jnp.clip(curError, 0, k)
        vec = tb[ti, de]  # [3, nw]
        mvec, ivec, dvec = vec[TB_MATCH], vec[TB_INS], vec[TB_DEL]
        pi = jnp.clip(patternI, 0, w - 1)
        mbit = get_bit(mvec, pi) == 0
        ibit = get_bit(ivec, pi) == 0
        dbit = get_bit(dvec, pi) == 0
        # substitution vector = shl1(deletion vector): bit pi of S is bit
        # pi-1 of D, and the shifted-in LSB is 0 (always "available").
        sbit = jnp.where(pi == 0, True, get_bit(dvec, jnp.maximum(pi - 1, 0)) == 0)

        has_err = curError > 0
        m_ok = mbit
        s_ok = sbit & has_err
        i_ok = ibit & has_err
        d_ok = dbit & has_err

        if affine:
            cands = jnp.stack(
                [
                    i_ok & (prev_op == OP_I),
                    d_ok & (prev_op == OP_D),
                    m_ok,
                    s_ok,
                    i_ok,
                    d_ok,
                ]
            )
            codes = jnp.array([OP_I, OP_D, OP_M, OP_X, OP_I, OP_D], jnp.int32)
        else:
            cands = jnp.stack([m_ok, s_ok, i_ok, d_ok])
            codes = jnp.array([OP_M, OP_X, OP_I, OP_D], jnp.int32)

        any_ok = jnp.any(cands)
        op = codes[jnp.argmax(cands)]
        new_stuck = stuck | (active & ~any_ok)
        take = active & any_ok

        consume_p = take & ((op == OP_M) | (op == OP_X) | (op == OP_I))
        consume_t = take & ((op == OP_M) | (op == OP_X) | (op == OP_D))
        err_dec = take & (op != OP_M)

        ops = ops.at[n_ops].set(jnp.where(take, op.astype(jnp.int8), ops[n_ops]))
        return (
            patternI - consume_p.astype(jnp.int32),
            textI + consume_t.astype(jnp.int32),
            curError - err_dec.astype(jnp.int32),
            jnp.where(take, op, prev_op),
            pc + consume_p.astype(jnp.int32),
            tc + consume_t.astype(jnp.int32),
            n_ops + take.astype(jnp.int32),
            ops,
            new_stuck,
        )

    st0 = (
        jnp.int32(w - 1),  # patternI: MSB = pattern[0]
        jnp.int32(0),  # textI
        d_start.astype(jnp.int32),
        jnp.int32(OP_PAD),  # prev_op
        jnp.int32(0),  # pc
        jnp.int32(0),  # tc
        jnp.int32(0),  # n_ops
        jnp.full((max_steps,), OP_PAD, jnp.int8),
        jnp.asarray(False),
    )
    patternI, textI, curError, _, pc, tc, n_ops, ops, stuck = lax.fori_loop(
        0, max_steps, body, st0
    )
    err_used = d_start.astype(jnp.int32) - curError
    return pc, tc, err_used, ops, n_ops, stuck


@partial(jax.jit, static_argnames=("w", "o", "k", "affine"))
def window_tb_r(
    store_r: jnp.ndarray,
    sub_text: jnp.ndarray,
    pm: jnp.ndarray,
    d_start: jnp.ndarray,
    cap_p: jnp.ndarray,
    *,
    w: int,
    o: int,
    k: int,
    affine: bool = True,
):
    """Traceback over R-only storage (kernel v2 path, §Perf #3).

    ``store_r``: [w+1, k+1, nw] from :func:`window_dc_r` / kernel v2;
    ``pm``: [5, nw] pattern bitmasks of the sub-pattern.  Check-vector
    derivation: D=R(i+1,d−1), S=shl1(D), I=shl1(R(i,d−1)),
    M=shl1(R(i+1,d)) | PM[text[i]].
    """
    max_steps = 2 * (w - o)
    cap_t = jnp.int32(w - o)
    cap_p = jnp.asarray(cap_p, jnp.int32)

    def bit_or_true_at0(vec, b):
        # bit b of shl1(vec): shifted-in 0 at b == 0 (always "available")
        return jnp.where(b == 0, True,
                         get_bit(vec, jnp.maximum(b - 1, 0)) == 0)

    def body(_, st):
        patternI, textI, curError, prev_op, pc, tc, n_ops, ops, stuck = st
        active = (pc < cap_p) & (tc < cap_t) & (patternI >= 0) & (~stuck)
        ti = jnp.clip(textI, 0, w - 1)
        de = jnp.clip(curError, 0, k)
        dem1 = jnp.clip(curError - 1, 0, k)
        pi = jnp.clip(patternI, 0, w - 1)

        r_next_d = store_r[ti + 1, de]  # R(i+1, d)
        r_next_dm1 = store_r[ti + 1, dem1]  # R(i+1, d-1)
        r_here_dm1 = store_r[ti, dem1]  # R(i, d-1)
        pm_bit = get_bit(pm[sub_text[ti]], pi) == 0

        mbit = pm_bit & bit_or_true_at0(r_next_d, pi)
        ibit = bit_or_true_at0(r_here_dm1, pi)
        dbit = get_bit(r_next_dm1, pi) == 0
        sbit = bit_or_true_at0(r_next_dm1, pi)

        has_err = curError > 0
        m_ok = mbit
        s_ok = sbit & has_err
        i_ok = ibit & has_err
        d_ok = dbit & has_err

        if affine:
            cands = jnp.stack([
                i_ok & (prev_op == OP_I), d_ok & (prev_op == OP_D),
                m_ok, s_ok, i_ok, d_ok,
            ])
            codes = jnp.array([OP_I, OP_D, OP_M, OP_X, OP_I, OP_D], jnp.int32)
        else:
            cands = jnp.stack([m_ok, s_ok, i_ok, d_ok])
            codes = jnp.array([OP_M, OP_X, OP_I, OP_D], jnp.int32)

        any_ok = jnp.any(cands)
        op = codes[jnp.argmax(cands)]
        new_stuck = stuck | (active & ~any_ok)
        take = active & any_ok
        consume_p = take & ((op == OP_M) | (op == OP_X) | (op == OP_I))
        consume_t = take & ((op == OP_M) | (op == OP_X) | (op == OP_D))
        err_dec = take & (op != OP_M)
        ops = ops.at[n_ops].set(jnp.where(take, op.astype(jnp.int8), ops[n_ops]))
        return (
            patternI - consume_p.astype(jnp.int32),
            textI + consume_t.astype(jnp.int32),
            curError - err_dec.astype(jnp.int32),
            jnp.where(take, op, prev_op),
            pc + consume_p.astype(jnp.int32),
            tc + consume_t.astype(jnp.int32),
            n_ops + take.astype(jnp.int32),
            ops,
            new_stuck,
        )

    st0 = (
        jnp.int32(w - 1), jnp.int32(0), d_start.astype(jnp.int32),
        jnp.int32(OP_PAD), jnp.int32(0), jnp.int32(0), jnp.int32(0),
        jnp.full((max_steps,), OP_PAD, jnp.int8), jnp.asarray(False),
    )
    patternI, textI, curError, _, pc, tc, n_ops, ops, stuck = lax.fori_loop(
        0, max_steps, body, st0)
    err_used = d_start.astype(jnp.int32) - curError
    return pc, tc, err_used, ops, n_ops, stuck


def cigar_counts(ops: jnp.ndarray, n_ops: jnp.ndarray):
    """Counts of (M, X, I, D) over the valid prefix of a packed op buffer."""
    idx = jnp.arange(ops.shape[-1])
    valid = idx < n_ops[..., None]
    out = []
    for code in (OP_M, OP_X, OP_I, OP_D):
        out.append(jnp.sum(valid & (ops == code), axis=-1))
    return jnp.stack(out, axis=-1)


def cigar_score(ops: jnp.ndarray, n_ops: jnp.ndarray, *, match=2, subs=-4, gap_open=-4, gap_extend=-2):
    """Affine-gap score of a packed CIGAR (Minimap2-style defaults)."""
    idx = jnp.arange(ops.shape[-1])
    valid = idx < n_ops[..., None]
    prev = jnp.concatenate([jnp.full(ops.shape[:-1] + (1,), OP_PAD, ops.dtype), ops[..., :-1]], -1)
    is_gap = (ops == OP_I) | (ops == OP_D)
    opens = is_gap & (ops != prev)
    # minimap2 convention: a gap of length L costs open + L·extend
    s = (
        match * jnp.sum(valid & (ops == OP_M), -1)
        + subs * jnp.sum(valid & (ops == OP_X), -1)
        + gap_open * jnp.sum(valid & opens, -1)
        + gap_extend * jnp.sum(valid & is_gap, -1)
    )
    return s
