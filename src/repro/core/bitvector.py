"""Multi-word bitvector algebra for the Bitap family (GenASM-DC/TB, Myers).

Conventions (see DESIGN.md §7):
  * A bitvector of ``n_bits`` is stored as ``uint32[nw]`` little-endian words:
    ``w[0]`` holds bits 0..31, global bit ``g`` lives at word ``g // 32``,
    offset ``g % 32``.  ``n_bits`` must be a multiple of 32.
  * Pattern character ``j`` maps to bit ``n_bits - 1 - j`` (MSB = pattern[0]),
    exactly as in the paper's Figure 4-2.
  * Base alphabet: A=0 C=1 G=2 T=3.  Id 4 is dual-purpose: as a *pattern*
    char it is the WILDCARD (matches every text char); as a *text* char it is
    the SENTINEL (matched only by wildcards).  A single rule implements both:
    ``match(p, c) = (p == c) | (p == 4)``.
"""
from __future__ import annotations

import jax.numpy as jnp

WORD_BITS = 32
NUM_CHARS = 5  # A, C, G, T, sentinel/wildcard
WILDCARD = 4
SENTINEL = 4


def n_words(n_bits: int) -> int:
    if n_bits % WORD_BITS != 0:
        raise ValueError(f"n_bits must be a multiple of {WORD_BITS}, got {n_bits}")
    return n_bits // WORD_BITS


def ones(shape) -> jnp.ndarray:
    """All-ones bitvector(s); trailing axis is the word axis."""
    return jnp.full(shape, 0xFFFFFFFF, dtype=jnp.uint32)


def shl1(x: jnp.ndarray) -> jnp.ndarray:
    """Shift the whole multi-word bitvector left by one, shifting in a 0.

    ``x``: ``[..., nw] uint32``.  Cross-word carries propagate from word
    ``j-1``'s MSB into word ``j``'s LSB.
    """
    carry = x >> 31
    shifted = x << 1
    incoming = jnp.concatenate(
        [jnp.zeros(x.shape[:-1] + (1,), jnp.uint32), carry[..., :-1]], axis=-1
    )
    return shifted | incoming


def msb(x: jnp.ndarray) -> jnp.ndarray:
    """Most significant bit (bit ``n_bits-1``) of ``[..., nw]`` bitvector(s)."""
    return (x[..., -1] >> 31) & 1


def get_bit(x: jnp.ndarray, pos) -> jnp.ndarray:
    """Bit at dynamic position ``pos`` of ``[..., nw]`` bitvector(s) -> uint32 0/1.

    ``pos`` may be a traced scalar; gathers along the word axis.
    """
    word = pos // WORD_BITS
    off = (pos % WORD_BITS).astype(jnp.uint32) if hasattr(pos, "astype") else pos % WORD_BITS
    w = jnp.take(x, word, axis=-1)
    return (w >> off) & 1


def pattern_bitmasks(pattern: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Build the PM table for a (sub-)pattern.

    ``pattern``: ``[..., L] int8/int32`` with ``L == n_bits`` (pad with
    WILDCARD to reach ``n_bits``).  Returns ``[..., NUM_CHARS, nw] uint32``
    where ``PM[c]`` has bit ``n_bits-1-j`` equal to **0** iff pattern char
    ``j`` matches text char ``c`` (0 = match, as in Bitap).
    """
    nw = n_words(n_bits)
    if pattern.shape[-1] != n_bits:
        raise ValueError(f"pattern length {pattern.shape[-1]} != n_bits {n_bits}")
    p = pattern.astype(jnp.int32)
    rev = p[..., ::-1]  # rev[g] = pattern char at bit g
    chars = jnp.arange(NUM_CHARS, dtype=jnp.int32)
    # match[..., c, g]
    m = (rev[..., None, :] == chars[:, None]) | (rev[..., None, :] == WILDCARD)
    mm = (~m).astype(jnp.uint32)  # 1 = mismatch
    mm = mm.reshape(mm.shape[:-1] + (nw, WORD_BITS))
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return jnp.sum(mm * weights, axis=-1, dtype=jnp.uint32)
