"""Genome graphs for SeGraM (paper §2.5, §6.5).

A graph is a topologically-ordered DAG with one base per node (the paper's
nodes hold short sequences; one-base nodes are the same graph after
splitting, and make hopBits uniform).  Successor edges within a bounded
hop window are encoded as per-node **hopBits** (paper Figure 6-9): bit
``h`` of ``succ_bits[i]`` set ⇔ node ``i + h + 1`` is a successor of ``i``.
The linearization keeps variant branches adjacent to their backbone
position so real variation graphs have small hop distances; edges beyond
``HOP_LIMIT`` would need graph re-chunking (the paper picks the hop limit
so this does not occur; construction asserts it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

HOP_LIMIT = 16


class Variant(NamedTuple):
    """pos: 0-based backbone position; kind: 'snp' | 'ins' | 'del'.

    snp: ``alt`` (len ≥ 1) replaces ref base(s) at pos.
    ins: ``alt`` inserted *after* backbone position pos.
    del: ``span`` backbone bases deleted starting at pos.
    """

    pos: int
    kind: str
    alt: tuple = ()
    span: int = 1


@dataclass
class GenomeGraph:
    bases: np.ndarray  # [N] int8, topological order
    succ_bits: np.ndarray  # [N] uint32 hopBits (successors)
    backbone: np.ndarray  # [N] int32 backbone coordinate of each node (-1 for alt)
    node_of_backbone: np.ndarray  # [L] int32 node id of each backbone position

    @property
    def n_nodes(self) -> int:
        return int(self.bases.shape[0])


def build_graph(ref: np.ndarray, variants: list[Variant] = ()) -> GenomeGraph:
    """Build a variation graph from a linear reference + variant list."""
    L = len(ref)
    # nodes assembled in backbone order; alt nodes inserted adjacent
    bases: list[int] = []
    backbone: list[int] = []
    edges: list[tuple[int, int]] = []
    node_of_backbone = np.full(L, -1, np.int64)

    by_pos: dict[int, list[Variant]] = {}
    for v in variants:
        by_pos.setdefault(v.pos, []).append(v)

    prev_tails: list[int] = []  # node ids whose successor is the next backbone node
    pending_del: dict[int, list[int]] = {}  # backbone pos -> node ids jumping here
    for p in range(L):
        nid = len(bases)
        bases.append(int(ref[p]))
        backbone.append(p)
        node_of_backbone[p] = nid
        for t in prev_tails:
            edges.append((t, nid))
        for t in pending_del.pop(p, []):
            edges.append((t, nid))
        prev_tails = [nid]
        for v in by_pos.get(p, []):
            if v.kind == "snp":
                alt_id = len(bases)
                bases.append(int(v.alt[0]))
                backbone.append(-1)
                # same predecessors as nid
                for (a, b) in [e for e in edges if e[1] == nid]:
                    edges.append((a, alt_id))
                prev_tails.append(alt_id)
            elif v.kind == "ins":
                prev = nid
                for ab in v.alt:
                    alt_id = len(bases)
                    bases.append(int(ab))
                    backbone.append(-1)
                    edges.append((prev, alt_id))
                    prev = alt_id
                prev_tails.append(prev)
            elif v.kind == "del":
                tgt = p + v.span + 1
                if tgt < L:
                    pending_del.setdefault(tgt, []).append(nid)
            else:
                raise ValueError(v.kind)

    n = len(bases)
    succ = np.zeros(n, np.uint32)
    for a, b in edges:
        hop = b - a - 1
        if hop < 0:
            raise ValueError("graph not topologically ordered")
        if hop >= HOP_LIMIT:
            raise ValueError(
                f"edge hop {hop + 1} exceeds HOP_LIMIT={HOP_LIMIT}; re-chunk the graph"
            )
        succ[a] |= np.uint32(1) << np.uint32(hop)
    return GenomeGraph(
        bases=np.array(bases, np.int8),
        succ_bits=succ,
        backbone=np.array(backbone, np.int32),
        node_of_backbone=node_of_backbone.astype(np.int32),
    )


def linear_graph(ref: np.ndarray) -> GenomeGraph:
    """Degenerate graph (pure backbone) — BitAlign on it must equal linear Bitap."""
    return build_graph(ref, [])


def extract_subgraph(g: GenomeGraph, start_node: int, length: int):
    """Fixed-size window of the linearized graph for one candidate region.

    Returns (bases [length] int8 sentinel-padded, succ_bits [length] uint32
    masked at the boundary).
    """
    n = g.n_nodes
    s = max(0, min(start_node, n))
    e = min(n, s + length)
    bases = np.full(length, 4, np.int8)
    succ = np.zeros(length, np.uint32)
    bases[: e - s] = g.bases[s:e]
    succ[: e - s] = g.succ_bits[s:e]
    # mask successor bits that point past the window end
    for i in range(max(0, e - s - HOP_LIMIT), e - s):
        room = e - s - i - 1
        succ[i] &= np.uint32((1 << max(room, 0)) - 1)
    return bases, succ


def predecessors(g: GenomeGraph) -> list[list[int]]:
    """Adjacency (predecessor lists) for the numpy DP oracle."""
    preds: list[list[int]] = [[] for _ in range(g.n_nodes)]
    for i in range(g.n_nodes):
        bits = int(g.succ_bits[i])
        h = 0
        while bits:
            if bits & 1:
                preds[i + h + 1].append(i)
            bits >>= 1
            h += 1
    return preds
