"""Genome graphs for SeGraM (paper §2.5, §6.5).

A graph is a topologically-ordered DAG with one base per node (the paper's
nodes hold short sequences; one-base nodes are the same graph after
splitting, and make hopBits uniform).  Successor edges within a bounded
hop window are encoded as per-node **hopBits** (paper Figure 6-9): bit
``h`` of ``succ_bits[i]`` set ⇔ node ``i + h + 1`` is a successor of ``i``.
The linearization keeps variant branches adjacent to their backbone
position so real variation graphs have small hop distances; edges beyond
``HOP_LIMIT`` would need graph re-chunking (the paper picks the hop limit
so this does not occur; construction raises so the caller can re-chunk —
`repro.graph.index` does exactly that for its tiled index).

Construction is linear in nodes + edges: predecessor lists are tracked
while the linearization is emitted (a SNP branch copies the predecessor
list its backbone twin was just given), and hopBits are accumulated with
one vectorized scatter at the end.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

HOP_LIMIT = 16


class Variant(NamedTuple):
    """pos: 0-based backbone position; kind: 'snp' | 'ins' | 'del'.

    snp: ``alt`` (len ≥ 1) replaces the ref base at pos (len > 1 spells a
    branch of chained nodes, e.g. an MNP allele).
    ins: ``alt`` inserted *after* backbone position pos.
    del: ``span`` backbone bases deleted starting at pos.
    """

    pos: int
    kind: str
    alt: tuple = ()
    span: int = 1


@dataclass
class GenomeGraph:
    bases: np.ndarray  # [N] int8, topological order
    succ_bits: np.ndarray  # [N] uint32 hopBits (successors)
    backbone: np.ndarray  # [N] int32 backbone coordinate of each node (-1 for alt)
    node_of_backbone: np.ndarray  # [L] int32 node id of each backbone position

    @property
    def n_nodes(self) -> int:
        return int(self.bases.shape[0])


def build_graph(ref: np.ndarray, variants: list[Variant] = ()) -> GenomeGraph:
    """Build a variation graph from a linear reference + variant list.

    Raises ``ValueError`` for malformed variants: an empty ``snp`` alt, a
    deletion whose landing position ``pos + span + 1`` falls past the
    reference end (it would silently vanish otherwise), or any edge whose
    hop distance exceeds ``HOP_LIMIT``.
    """
    L = len(ref)
    # nodes assembled in backbone order; alt nodes inserted adjacent
    bases: list[int] = []
    backbone: list[int] = []
    src: list[int] = []  # edge sources
    dst: list[int] = []  # edge targets
    node_of_backbone = np.full(L, -1, np.int64)

    by_pos: dict[int, list[Variant]] = {}
    for v in variants:
        by_pos.setdefault(v.pos, []).append(v)

    prev_tails: list[int] = []  # node ids whose successor is the next backbone node
    pending_del: dict[int, list[int]] = {}  # backbone pos -> node ids jumping here
    for p in range(L):
        nid = len(bases)
        bases.append(int(ref[p]))
        backbone.append(p)
        node_of_backbone[p] = nid
        preds = prev_tails + pending_del.pop(p, [])
        for t in preds:
            src.append(t)
            dst.append(nid)
        prev_tails = [nid]
        for v in by_pos.get(p, []):
            if v.kind == "snp":
                if not v.alt:
                    raise ValueError(f"snp at {p} needs a non-empty alt")
                # branch carrying the alt allele: the first alt node shares
                # nid's predecessor list (tracked above — no edge rescans),
                # further alt bases chain behind it
                prev = -1
                for j, ab in enumerate(v.alt):
                    alt_id = len(bases)
                    bases.append(int(ab))
                    backbone.append(-1)
                    for a in (preds if j == 0 else [prev]):
                        src.append(a)
                        dst.append(alt_id)
                    prev = alt_id
                prev_tails.append(prev)
            elif v.kind == "ins":
                prev = nid
                for ab in v.alt:
                    alt_id = len(bases)
                    bases.append(int(ab))
                    backbone.append(-1)
                    src.append(prev)
                    dst.append(alt_id)
                    prev = alt_id
                prev_tails.append(prev)
            elif v.kind == "del":
                tgt = p + v.span + 1
                if tgt >= L:
                    raise ValueError(
                        f"del at {p} (span {v.span}) lands at backbone "
                        f"{tgt}, past the reference end {L}; trim the "
                        f"variant or extend the reference")
                pending_del.setdefault(tgt, []).append(nid)
            else:
                raise ValueError(v.kind)

    n = len(bases)
    succ = np.zeros(n, np.uint32)
    if src:
        a = np.asarray(src, np.int64)
        b = np.asarray(dst, np.int64)
        hop = b - a - 1
        if hop.min() < 0:
            raise ValueError("graph not topologically ordered")
        if hop.max() >= HOP_LIMIT:
            w = int(hop.argmax())
            raise ValueError(
                f"edge {int(a[w])}->{int(b[w])} hop {int(hop[w]) + 1} "
                f"exceeds HOP_LIMIT={HOP_LIMIT}; re-chunk the graph")
        np.bitwise_or.at(succ, a, np.uint32(1) << hop.astype(np.uint32))
    return GenomeGraph(
        bases=np.array(bases, np.int8),
        succ_bits=succ,
        backbone=np.array(backbone, np.int32),
        node_of_backbone=node_of_backbone.astype(np.int32),
    )


def linear_graph(ref: np.ndarray) -> GenomeGraph:
    """Degenerate graph (pure backbone) — BitAlign on it must equal linear Bitap."""
    return build_graph(ref, [])


def hop_boundary_mask(length: int, valid_len) -> jnp.ndarray:
    """The one boundary-masking rule for subgraph windows.

    Returns ``[length] uint32``: entry ``i`` keeps hop bit ``h`` iff the
    target node ``i + h + 1`` stays below ``valid_len`` (the window/graph
    end).  ``valid_len`` may be a traced scalar; every window extractor —
    host-side :func:`extract_subgraph`, device-side ``segram._window``,
    and the tile builder in `repro.graph.index` — applies this mask so
    out-of-window hops cannot disagree between paths.
    """
    room = jnp.clip(
        jnp.asarray(valid_len, jnp.int32) - 1 - jnp.arange(length), 0, 32)
    return jnp.where(
        room >= 32, jnp.uint32(0xFFFFFFFF),
        (jnp.uint32(1) << room.astype(jnp.uint32)) - 1)


def extract_subgraph(g: GenomeGraph, start_node: int, length: int):
    """Fixed-size window of the linearized graph for one candidate region.

    Returns (bases [length] int8 sentinel-padded, succ_bits [length] uint32
    masked at the boundary).
    """
    n = g.n_nodes
    s = max(0, min(start_node, n))
    e = min(n, s + length)
    bases = np.full(length, 4, np.int8)
    succ = np.zeros(length, np.uint32)
    bases[: e - s] = g.bases[s:e]
    succ[: e - s] = g.succ_bits[s:e]
    succ &= np.asarray(hop_boundary_mask(length, e - s))
    return bases, succ


def predecessors(g: GenomeGraph) -> list[list[int]]:
    """Adjacency (predecessor lists) for the numpy DP oracle."""
    preds: list[list[int]] = [[] for _ in range(g.n_nodes)]
    for i in range(g.n_nodes):
        bits = int(g.succ_bits[i])
        h = 0
        while bits:
            if bits & 1:
                preds[i + h + 1].append(i)
            bits >>= 1
            h += 1
    return preds
