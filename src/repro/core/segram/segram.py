"""SeGraM: end-to-end sequence-to-graph mapping (paper Figure 6-1).

Pipeline per read: MinSeed (minimizer lookup → candidate subgraph
regions, Figure 6-5) → BitAlign DC over each candidate subgraph → pick
the best → BitAlign TB for the CIGAR + path.  Batched over reads with
vmap; sharding over the data axes happens in the launcher.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bitalign import bitalign_dc, bitalign_tb
from .graph import HOP_LIMIT, GenomeGraph, hop_boundary_mask
from .minimizer import MinimizerIndex, build_index, seed_candidates


class SeGraMIndex(NamedTuple):
    bases: jnp.ndarray  # [N] int8 linearized graph
    succ_bits: jnp.ndarray  # [N] uint32
    node_of_backbone: jnp.ndarray  # [L] int32
    idx_hashes: jnp.ndarray  # sorted minimizer hashes (backbone)
    idx_positions: jnp.ndarray  # backbone positions


def preprocess(ref: np.ndarray, g: GenomeGraph, *, w: int = 10, k: int = 15,
               ) -> SeGraMIndex:
    """Offline pre-processing (paper §6.5): graph arrays + minimizer index."""
    idx = build_index(ref, w=w, k=k)
    return SeGraMIndex(
        bases=jnp.asarray(g.bases),
        succ_bits=jnp.asarray(g.succ_bits),
        node_of_backbone=jnp.asarray(g.node_of_backbone),
        idx_hashes=jnp.asarray(idx.hashes),
        idx_positions=jnp.asarray(idx.positions),
    )


def _window(index: SeGraMIndex, start_node, length: int):
    """Device-side subgraph window with boundary-masked hopBits."""
    n = index.bases.shape[0]
    s = jnp.clip(start_node, 0, jnp.maximum(n - length, 0))
    bases = jax.lax.dynamic_slice(index.bases, (s,), (length,))
    succ = jax.lax.dynamic_slice(index.succ_bits, (s,), (length,))
    return bases, succ & hop_boundary_mask(length, length), s


@partial(jax.jit, static_argnames=("m_bits", "k", "win_len", "max_candidates",
                                   "minimizer_w", "minimizer_k"))
def map_read(
    index: SeGraMIndex,
    read: jnp.ndarray,
    read_len,
    *,
    m_bits: int = 128,
    k: int = 16,
    win_len: int = 192,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
):
    """Map one read to the graph.  Returns a dict of mapping results."""
    starts, votes = seed_candidates(
        read[:],
        index.idx_hashes,
        index.idx_positions,
        w=minimizer_w,
        k=minimizer_k,
        max_candidates=max_candidates,
    )
    # backbone coordinate -> node id, with margin for leading variation
    L = index.node_of_backbone.shape[0]
    starts_bb = jnp.clip(starts - HOP_LIMIT, 0, L - 1)
    start_nodes = index.node_of_backbone[starts_bb]

    pat = jnp.where(jnp.arange(m_bits) < read_len, read[:m_bits], 4).astype(jnp.int8)

    def eval_cand(sn):
        bases, succ, s0 = _window(index, sn, win_len)
        dists, store = bitalign_dc(bases, succ, pat, read_len, m_bits=m_bits, k=k)
        best = jnp.argmin(dists)
        return dists[best], best, s0, store, succ

    d_all, n_all, s0_all, store_all, succ_all = jax.vmap(eval_cand)(start_nodes)
    d_all = jnp.where(votes > 0, d_all, k + 1)
    ci = jnp.argmin(d_all)
    d = d_all[ci]
    ops, n_ops, nodes, stuck = bitalign_tb(
        store_all[ci], succ_all[ci], n_all[ci], jnp.minimum(d, k), read_len,
        m_bits=m_bits, k=k,
    )
    failed = (d > k) | stuck
    return {
        "distance": jnp.where(failed, -1, d).astype(jnp.int32),
        "node": (s0_all[ci] + n_all[ci]).astype(jnp.int32),
        "ops": ops,
        "n_ops": n_ops,
        "path": jnp.where(nodes >= 0, nodes + s0_all[ci], -1),
        "failed": failed,
    }


def map_batch(index: SeGraMIndex, reads: jnp.ndarray, read_lens: jnp.ndarray, **kw):
    f = partial(map_read, index, **kw)
    return jax.vmap(f)(reads, read_lens)
