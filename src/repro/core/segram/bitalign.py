"""BitAlign: bitvector-based sequence-to-graph alignment (paper §6.7, §6.8.2).

Generalizes GenASM-DC to a DAG: scanning the linearized subgraph in
*reverse topological order*, the "previous text character" bitvectors are
the AND-combination of all successors' status bitvectors within the hop
window (0 = match, so AND is the union of matching paths — exactly the
paper's hopBits combine in Figure 6-9).  A ring buffer holds the last
``HOP_LIMIT`` nodes' R matrices, mirroring the hop-queue in the BitAlign
PE design (Figure 6-8).

Traceback re-derives the chosen successor at each step from the stored
per-node status bitvectors (the information the ASIC keeps in TB-SRAMs):
an op that consumes a graph node is valid only if some successor's R
continues the 0-chain, and the successor taken is recorded as the path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..bitvector import get_bit, msb, n_words, ones, pattern_bitmasks, shl1
from ..genasm_tb import OP_D, OP_I, OP_M, OP_PAD, OP_X
from .graph import HOP_LIMIT


def _tail_mask(p_len, m_bits: int) -> jnp.ndarray:
    """[nw] uint32: ones with the low ``m_bits - p_len`` bits cleared.

    Word-aligned patterns shorter than ``m_bits`` are handled by treating
    the wildcard tail as *pre-matched everywhere*: every status bitvector
    keeps its low ``pad`` bits at 0, so the tail never consumes graph
    nodes (no sentinel-chain surgery at subgraph boundaries needed).
    """
    nw = n_words(m_bits)
    pad = (jnp.int32(m_bits) - jnp.asarray(p_len, jnp.int32))
    bits_below = jnp.clip(pad - 32 * jnp.arange(nw, dtype=jnp.int32), 0, 32)
    low = jnp.where(
        bits_below >= 32,
        jnp.uint32(0xFFFFFFFF),
        (jnp.uint32(1) << bits_below.astype(jnp.uint32)) - 1,
    )
    return ~low


@partial(jax.jit, static_argnames=("m_bits", "k"))
def bitalign_dc(bases: jnp.ndarray, succ_bits: jnp.ndarray, pattern: jnp.ndarray,
                p_len, *, m_bits: int, k: int):
    """DC over a linearized subgraph.

    ``bases``: [N] int8 (4 = sentinel pad);  ``succ_bits``: [N] uint32
    hopBits;  ``pattern``: [m_bits] int8 wildcard-padded; ``p_len`` its
    real length.

    Returns ``(dists [N] int32, store [N, k+1, 4, nw] uint32)`` where
    ``dists[i]`` is the min d ≤ k aligning the full pattern to a path
    starting at node i (k+1 if none) and ``store`` holds (R, M, I, D).
    """
    nw = n_words(m_bits)
    pm = pattern_bitmasks(pattern, m_bits)
    H = HOP_LIMIT
    tail = _tail_mask(p_len, m_bits)  # [nw]
    tail_full = jnp.broadcast_to(tail, (k + 1, nw))

    def step(hist, inputs):
        # hist: [H, k+1, nw] — hist[h] = R of node i+1+h
        base, sb = inputs
        hop_ok = ((sb >> jnp.arange(H, dtype=jnp.uint32)) & 1).astype(bool)  # [H]
        masked = jnp.where(hop_ok[:, None, None], hist, tail_full[None])
        comb = masked[0]
        for h in range(1, H):
            comb = comb & masked[h]  # [k+1, nw]; ones when no successor
        cur_pm = pm[base]
        R0 = shl1(comb[0]) | cur_pm
        rows = [R0]
        Ms, Is, Ds = [R0], [ones((nw,))], [ones((nw,))]
        for d in range(1, k + 1):
            D = comb[d - 1]
            S = shl1(comb[d - 1])
            I = shl1(rows[d - 1])
            M = shl1(comb[d]) | cur_pm
            rows.append(D & S & I & M)
            Ms.append(M)
            Is.append(I)
            Ds.append(D)
        R = jnp.stack(rows)  # [k+1, nw]
        st = jnp.stack([R, jnp.stack(Ms), jnp.stack(Is), jnp.stack(Ds)], axis=1)
        new_hist = jnp.concatenate([R[None], hist[:-1]], axis=0)
        m = msb(R)
        found = m == 0
        d_i = jnp.where(jnp.any(found), jnp.argmax(found), k + 1).astype(jnp.int32)
        return new_hist, (d_i, st)

    hist0 = jnp.broadcast_to(tail_full, (H, k + 1, nw))
    _, (dists_rev, store_rev) = lax.scan(
        step, hist0, (bases[::-1].astype(jnp.int32), succ_bits[::-1])
    )
    return dists_rev[::-1], store_rev[::-1]


@partial(jax.jit, static_argnames=("m_bits", "k", "max_steps"))
def bitalign_tb(store: jnp.ndarray, succ_bits: jnp.ndarray, start_node, d_start,
                p_len, *, m_bits: int, k: int, max_steps: int | None = None):
    """Graph traceback from ``start_node`` with ``d_start`` errors.

    ``store``: [N, k+1, 4, nw] from :func:`bitalign_dc` (R, M, I, D).
    Returns ``(ops [steps] int8, n_ops int32, nodes [steps] int32, stuck bool)``
    where ``nodes[s]`` is the graph node consumed at step s (-1 for I ops).
    """
    H = HOP_LIMIT
    n = store.shape[0]
    if max_steps is None:
        max_steps = m_bits + k
    hop_rng = jnp.arange(H)

    def succ_ok(node, d_next, bit_next, succ_mask):
        pos = jnp.clip(node + 1 + hop_rng, 0, n - 1)
        Rn = store[pos, jnp.clip(d_next, 0, k), 0]  # [H, nw]
        bits = jax.vmap(lambda v: get_bit(v, jnp.clip(bit_next, 0, m_bits - 1)))(Rn)
        in_range = (node + 1 + hop_rng) < n
        return succ_mask & (bits == 0) & in_range & (d_next >= 0) & (bit_next >= 0)

    def body(_, st):
        node, b, d, pc, n_ops, ops, nodes, stuck, done = st
        active = (~done) & (~stuck)
        ni = jnp.clip(node, 0, n - 1)
        vec = store[ni, jnp.clip(d, 0, k)]  # [4, nw]
        M, I, D = vec[1], vec[2], vec[3]
        pi = jnp.clip(b, 0, m_bits - 1)
        mbit = get_bit(M, pi) == 0
        ibit = get_bit(I, pi) == 0
        dbit = get_bit(D, pi) == 0
        sbit = jnp.where(pi == 0, True, get_bit(D, jnp.maximum(pi - 1, 0)) == 0)
        has_err = d > 0

        succ_mask = (
            (succ_bits[ni] >> hop_rng.astype(jnp.uint32)) & 1
        ).astype(bool)
        last_p = pc >= p_len - 1  # this op consumes the final pattern char
        ok_m_h = succ_ok(node, d, b - 1, succ_mask)
        ok_s_h = succ_ok(node, d - 1, b - 1, succ_mask)
        ok_d_h = succ_ok(node, d - 1, b, succ_mask)
        m_ok = mbit & (last_p | jnp.any(ok_m_h))
        s_ok = sbit & has_err & (last_p | jnp.any(ok_s_h))
        i_ok = ibit & has_err
        d_ok = dbit & has_err & jnp.any(ok_d_h)

        cands = jnp.stack([m_ok, s_ok, i_ok, d_ok])
        codes = jnp.array([OP_M, OP_X, OP_I, OP_D], jnp.int32)
        any_ok = jnp.any(cands)
        sel = jnp.argmax(cands)
        op = codes[sel]
        take = active & any_ok
        new_stuck = stuck | (active & ~any_ok)

        hops = jnp.stack([ok_m_h, ok_s_h, ok_d_h, ok_d_h])[sel]
        h_star = jnp.argmax(hops)
        consume_node = take & ((op == OP_M) | (op == OP_X) | (op == OP_D))
        consume_pat = take & ((op == OP_M) | (op == OP_X) | (op == OP_I))
        err_dec = take & (op != OP_M)

        ends_walk = consume_pat & last_p
        next_node = jnp.where(consume_node & ~ends_walk, node + 1 + h_star, node)
        ops = ops.at[n_ops].set(jnp.where(take, op.astype(jnp.int8), ops[n_ops]))
        nodes = nodes.at[n_ops].set(
            jnp.where(take & consume_node, node, jnp.where(take, -1, nodes[n_ops]))
        )
        new_pc = pc + consume_pat.astype(jnp.int32)
        new_done = done | (take & (new_pc >= p_len))
        return (
            next_node.astype(jnp.int32),
            b - consume_pat.astype(jnp.int32),
            d - err_dec.astype(jnp.int32),
            new_pc,
            n_ops + take.astype(jnp.int32),
            ops,
            nodes,
            new_stuck,
            new_done,
        )

    st0 = (
        jnp.asarray(start_node, jnp.int32),
        jnp.int32(m_bits - 1),
        jnp.asarray(d_start, jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
        jnp.full((max_steps,), OP_PAD, jnp.int8),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.asarray(False),
        p_len <= 0,
    )
    _, _, _, _, n_ops, ops, nodes, stuck, done = lax.fori_loop(0, max_steps, body, st0)
    return ops, n_ops, nodes, stuck | (~done)


def bitalign(bases, succ_bits, pattern, p_len, *, m_bits: int, k: int,
             traceback: bool = True):
    """Distance (+ optional CIGAR/path) for pattern vs subgraph, free start node.

    Returns dict(distance, start_node, ops, n_ops, nodes, failed).
    """
    dists, store = bitalign_dc(bases, succ_bits, pattern, p_len, m_bits=m_bits, k=k)
    best = jnp.argmin(dists)
    d = dists[best]
    out = {
        "distance": jnp.where(d > k, -1, d).astype(jnp.int32),
        "start_node": best.astype(jnp.int32),
        "failed": d > k,
    }
    if traceback:
        ops, n_ops, nodes, stuck = bitalign_tb(
            store, succ_bits, best, jnp.minimum(d, k), p_len, m_bits=m_bits, k=k
        )
        out.update(ops=ops, n_ops=n_ops, nodes=nodes,
                   failed=out["failed"] | stuck)
    return out
