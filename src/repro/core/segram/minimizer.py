"""MinSeed: minimizer-based indexing & seeding (paper §6.1, §6.5, §6.6).

(w, k)-minimizers: in every window of ``w`` consecutive k-mers the one with
the smallest hash is sampled.  The reference index is a sorted
(hash, position) table built offline (the paper's pre-processing step);
queries are JAX ``searchsorted`` lookups, so seeding runs sharded on
device.  Frequency filtering discards the most frequent minimizers
(paper: top 0.02%), exactly like MinSeed's filter stage.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def kmer_codes(seq: jnp.ndarray, k: int) -> jnp.ndarray:
    """Packed 2-bit k-mer codes for every position (length n-k+1).

    Positions whose k-mer touches a non-ACGT char get code 0xFFFFFFFF
    (excluded from minimizers).
    """
    n = seq.shape[-1]
    idx = jnp.arange(n - k + 1)[:, None] + jnp.arange(k)[None, :]
    kmers = seq[idx].astype(jnp.uint32)  # [n-k+1, k]
    valid = jnp.all(kmers < 4, axis=-1)
    shifts = jnp.uint32(2) * jnp.arange(k - 1, -1, -1, dtype=jnp.uint32)
    code = jnp.sum((kmers & 3) << shifts, axis=-1, dtype=jnp.uint32)
    return jnp.where(valid, code, jnp.uint32(0xFFFFFFFF))


def hash32(x: jnp.ndarray) -> jnp.ndarray:
    """Invertible 32-bit mix (murmur3 finalizer) — the minimizer ordering."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


@partial(jax.jit, static_argnames=("w", "k"))
def minimizers(seq: jnp.ndarray, *, w: int, k: int):
    """Minimizer sampling (paper Figure 6-4).

    Returns ``(is_min [n-k+1] bool, hashes [n-k+1] uint32)``: positions that
    are the minimum-hash k-mer of at least one w-window.
    """
    codes = kmer_codes(seq, k)
    h = jnp.where(codes == jnp.uint32(0xFFFFFFFF), jnp.uint32(0xFFFFFFFF), hash32(codes))
    n_k = h.shape[0]
    n_win = n_k - w + 1
    widx = jnp.arange(n_win)[:, None] + jnp.arange(w)[None, :]
    wh = h[widx]  # [n_win, w]
    arg = jnp.argmin(wh, axis=-1) + jnp.arange(n_win)
    is_min = jnp.zeros((n_k,), bool).at[arg].set(True)
    is_min = is_min & (h != jnp.uint32(0xFFFFFFFF))
    return is_min, h


class MinimizerIndex(NamedTuple):
    """Sorted minimizer table (host-built, device-queryable)."""

    hashes: np.ndarray  # [M] uint32 sorted
    positions: np.ndarray  # [M] int32 reference positions
    freq_cap: int


def build_index(ref: np.ndarray, *, w: int = 10, k: int = 15,
                freq_frac: float = 0.0002) -> MinimizerIndex:
    """Offline index construction (paper §6.5) with frequency filtering."""
    is_min, h = jax.jit(partial(minimizers, w=w, k=k))(jnp.asarray(ref))
    is_min = np.asarray(is_min)
    h = np.asarray(h)
    pos = np.nonzero(is_min)[0].astype(np.int32)
    hh = h[pos]
    order = np.argsort(hh, kind="stable")
    hh, pos = hh[order], pos[order]
    # frequency filter: drop hashes occurring more than cap times
    uniq, counts = np.unique(hh, return_counts=True)
    if len(uniq):
        cap = max(1, int(np.quantile(counts, 1.0 - freq_frac)))
        bad = uniq[counts > cap]
        keep = ~np.isin(hh, bad)
        hh, pos = hh[keep], pos[keep]
    else:
        cap = 1
    return MinimizerIndex(hashes=hh, positions=pos, freq_cap=cap)


@partial(jax.jit, static_argnames=("w", "k", "max_seeds", "max_candidates"))
def seed_candidates(
    read: jnp.ndarray,
    idx_hashes: jnp.ndarray,
    idx_positions: jnp.ndarray,
    *,
    w: int = 10,
    k: int = 15,
    max_seeds: int = 64,
    max_candidates: int = 8,
):
    """MinSeed query: read minimizers → candidate mapping locations.

    Candidate region start = ref_pos − read_pos (paper Figure 6-5), then
    diagonal votes are bucketed and the ``max_candidates`` most-supported
    diagonals returned.  Returns ``(starts [max_candidates] int32,
    votes [max_candidates] int32)``; empty slots have votes == 0.
    """
    is_min, h = minimizers(read, w=w, k=k)
    n_k = h.shape[0]
    score = jnp.where(is_min, h, jnp.uint32(0xFFFFFFFF))
    order = jnp.argsort(score)[:max_seeds]  # take up to max_seeds minimizers
    seed_pos = order.astype(jnp.int32)
    seed_hash = h[order]
    seed_valid = is_min[order]

    lo = jnp.searchsorted(idx_hashes, seed_hash, side="left")
    hi = jnp.searchsorted(idx_hashes, seed_hash, side="right")
    # take up to 4 index hits per seed
    hit_off = jnp.arange(4)[None, :]
    hit_idx = jnp.clip(lo[:, None] + hit_off, 0, idx_positions.shape[0] - 1)
    hit_ok = (lo[:, None] + hit_off < hi[:, None]) & seed_valid[:, None]
    ref_pos = idx_positions[hit_idx]
    diag = jnp.where(hit_ok, ref_pos - seed_pos[:, None], jnp.int32(-(2 ** 30)))
    diag = diag.reshape(-1)

    # bucket diagonals (tolerance via >> 5) and vote
    bucket = jnp.where(diag <= -(2 ** 29), jnp.int32(-(2 ** 30)), diag >> 5)
    sortb = jnp.sort(bucket)
    uniq_mask = jnp.concatenate([jnp.array([True]), sortb[1:] != sortb[:-1]])
    run_id = jnp.cumsum(uniq_mask) - 1
    votes = jnp.zeros((diag.shape[0],), jnp.int32).at[run_id].add(
        (sortb > -(2 ** 29)).astype(jnp.int32)
    )
    starts_sorted = jnp.zeros((diag.shape[0],), jnp.int32).at[run_id].max(
        jnp.where(sortb > -(2 ** 29), sortb << 5, -(2 ** 30))
    )
    top = jnp.argsort(-votes)[:max_candidates]
    return jnp.maximum(starts_sorted[top], 0), votes[top]
