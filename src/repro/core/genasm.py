"""GenASM: chained divide-and-conquer alignment (DC + TB per window).

This is the paper's full read-alignment dataflow (Figure 4-3): the text
region and query pattern are cut into overlapping windows (W=64, O=24 by
default); per window GenASM-DC generates the intermediate bitvectors and
GenASM-TB commits up to ``W-O`` characters of traceback; windows repeat
until the pattern is consumed.  Everything is shape-static so the whole
aligner vmaps over batches of (candidate text region, read) pairs and
pjit/shard_maps over the data axes.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .bitvector import SENTINEL, WILDCARD
from .genasm_tb import OP_PAD, window_tb
from . import genasm_dc


class GenASMConfig(NamedTuple):
    """Window geometry (paper defaults W=64, O=24, k_window=O).

    Backend selection (pure-lax vs the Pallas kernels) is *not* part of
    this config — it belongs to `repro.align`'s dispatch layer, which
    keys its executor/autotune caches on the backend name separately.
    """

    w: int = 64
    o: int = 24
    k: int = 24
    affine: bool = True
    store_r: bool = False  # v2 TB store: R rows only (3× less TB traffic)

    @property
    def commit(self) -> int:
        return self.w - self.o

    def n_windows(self, max_pattern_len: int) -> int:
        return -(-max_pattern_len // self.commit) + 2

    def ops_cap(self, p_cap: int) -> int:
        """CIGAR ops/path buffer width every backend emits at ``p_cap``.

        Each of the ``n_windows`` steps commits at most ``2·commit`` ops
        (all-insertion worst case).  Shared by the align backends and the
        graph mapper's zero-survivor short-circuit, whose canned result
        must be shaped exactly like a real align launch's.
        """
        return self.n_windows(p_cap) * 2 * self.commit


class AlignResult(NamedTuple):
    distance: jnp.ndarray  # int32 total edit distance (approx. per paper)
    ops: jnp.ndarray  # [cap] int8 packed CIGAR (-1 padded)
    n_ops: jnp.ndarray  # int32
    text_consumed: jnp.ndarray  # int32
    failed: jnp.ndarray  # bool — a window had no alignment within k
    # graph backends only: [cap] int32 window-relative node offset consumed
    # by each op (-1 for I/padding); None for the linear backends
    nodes: jnp.ndarray | None = None


def pad_pattern(pattern: jnp.ndarray, p_len, cap: int, cfg: GenASMConfig):
    """Pad/trim a pattern buffer to ``cap + w`` with wildcards after ``p_len``."""
    size = cap + cfg.w
    buf = jnp.full((size,), WILDCARD, jnp.int8)
    buf = lax.dynamic_update_slice(buf, pattern.astype(jnp.int8)[: size], (0,))
    idx = jnp.arange(size)
    return jnp.where(idx < p_len, buf, WILDCARD).astype(jnp.int8)


def pad_text(text: jnp.ndarray, t_len, cap: int, cfg: GenASMConfig):
    """Pad/trim a text buffer to ``cap + w`` with sentinels after ``t_len``."""
    size = cap + cfg.w
    buf = jnp.full((size,), SENTINEL, jnp.int8)
    buf = lax.dynamic_update_slice(buf, text.astype(jnp.int8)[: size], (0,))
    idx = jnp.arange(size)
    return jnp.where(idx < t_len, buf, SENTINEL).astype(jnp.int8)


def window_commit(carry, *, d_min, pc, tc, err, n_ops, stuck, p_len, k):
    """Advance the window-scan carry by one DC+TB window's outcome.

    The single source of the commit rules (fail/stall masking, advance
    gating, completion): both the per-alignment scan here and the
    batched kernel driver in `repro.align.batched` call this, which is
    what makes their outputs bit-identical.  All operands may be scalars
    (per-lane under vmap) or ``[B]`` vectors — the logic broadcasts.

    Returns ``(new_carry, n_emit)`` where ``n_emit`` is the number of
    CIGAR ops this window actually contributes (0 for done/failed lanes).
    """
    cur_p, cur_t, dist, failed, done = carry
    win_fail = d_min > k
    this_fail = (win_fail | stuck) & (~done)
    adv_p = jnp.where(done | this_fail, 0, pc)
    adv_t = jnp.where(done | this_fail, 0, tc)
    n_emit = jnp.where(done | this_fail, 0, n_ops)
    dist = dist + jnp.where(done | this_fail, 0, err)
    new_done = done | this_fail | (cur_p + adv_p >= p_len)
    return (cur_p + adv_p, cur_t + adv_t, dist, failed | this_fail,
            new_done), n_emit


@partial(jax.jit, static_argnames=("cfg", "p_cap", "emit_cigar"))
def align(
    text: jnp.ndarray,
    pattern: jnp.ndarray,
    p_len: jnp.ndarray,
    t_len: jnp.ndarray,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int | None = None,
    emit_cigar: bool = True,
) -> AlignResult:
    """Align ``pattern[:p_len]`` against ``text[:t_len]`` anchored at text[0].

    ``text``/``pattern`` are fixed-size int8 buffers (contents past the
    lengths are ignored).  Semi-global: the pattern must be fully consumed,
    trailing text is free.  Vmap over leading axes for batches.
    """
    if p_cap is None:
        p_cap = int(pattern.shape[-1])
    n_win = cfg.n_windows(p_cap)
    max_steps = 2 * cfg.commit
    w, o, k = cfg.w, cfg.o, cfg.k

    pat = pad_pattern(pattern, p_len, p_cap, cfg)
    txt = pad_text(text, t_len, p_cap + n_win * cfg.commit, cfg)

    if cfg.store_r:
        dc_fn = lambda st, sp: genasm_dc.window_dc_r(st, sp, w=w, k=k)
    else:
        dc_fn = lambda st, sp: genasm_dc.window_dc(st, sp, w=w, k=k)

    def window_step(carry, _):
        cur_p, cur_t = carry[0], carry[1]
        sub_p = lax.dynamic_slice(pat, (cur_p,), (w,))
        sub_t = lax.dynamic_slice(txt, (cur_t,), (w,))
        d_min, tb = dc_fn(sub_t, sub_p)
        cap_p = jnp.minimum(jnp.int32(cfg.commit), p_len - cur_p)
        if cfg.store_r:
            from .bitvector import pattern_bitmasks
            from .genasm_tb import window_tb_r

            pm = pattern_bitmasks(sub_p, w)
            pc, tc, err, ops, n_ops, stuck = window_tb_r(
                tb, sub_t, pm, jnp.minimum(d_min, k), cap_p, w=w, o=o, k=k,
                affine=cfg.affine)
        else:
            pc, tc, err, ops, n_ops, stuck = window_tb(
                tb, jnp.minimum(d_min, k), cap_p, w=w, o=o, k=k,
                affine=cfg.affine)
        new_carry, n_emit = window_commit(
            carry, d_min=d_min, pc=pc, tc=tc, err=err, n_ops=n_ops,
            stuck=stuck, p_len=p_len, k=k)
        return new_carry, (ops, n_emit)

    init = (jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.asarray(False), p_len <= 0)
    (fin_p, fin_t, dist, failed, done), (ops_w, n_ops_w) = lax.scan(
        window_step, init, None, length=n_win
    )
    failed = failed | (~done)

    if emit_cigar:
        cap = n_win * max_steps
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(n_ops_w)[:-1]])
        step_idx = jnp.arange(max_steps)[None, :]
        valid = step_idx < n_ops_w[:, None]
        pos = jnp.where(valid, offsets[:, None] + step_idx, cap)
        out = jnp.full((cap,), OP_PAD, jnp.int8)
        out = out.at[pos.reshape(-1)].set(ops_w.reshape(-1), mode="drop")
        n_total = jnp.sum(n_ops_w)
    else:
        out = jnp.full((1,), OP_PAD, jnp.int8)
        n_total = jnp.sum(n_ops_w)

    return AlignResult(
        distance=jnp.where(failed, jnp.int32(-1), dist),
        ops=out,
        n_ops=n_total,
        text_consumed=fin_t,
        failed=failed,
    )


def align_batch(texts, patterns, p_lens, t_lens, *, cfg=GenASMConfig(), emit_cigar=True):
    """vmap'd :func:`align` over a batch of pairs."""
    f = partial(align, cfg=cfg, emit_cigar=emit_cigar)
    return jax.vmap(f)(texts, patterns, p_lens, t_lens)
