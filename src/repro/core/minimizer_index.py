"""Device-resident reference index for the linear mapper."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .segram.minimizer import build_index


class ReferenceIndex(NamedTuple):
    ref: jnp.ndarray  # [L] int8 reference bases
    hashes: jnp.ndarray  # [M] uint32 sorted minimizer hashes
    positions: jnp.ndarray  # [M] int32


def build_reference_index(ref: np.ndarray, *, w: int = 10, k: int = 15,
                          freq_frac: float = 0.0002) -> ReferenceIndex:
    idx = build_index(ref, w=w, k=k, freq_frac=freq_frac)
    return ReferenceIndex(
        ref=jnp.asarray(ref.astype(np.int8)),
        hashes=jnp.asarray(idx.hashes),
        positions=jnp.asarray(idx.positions),
    )
