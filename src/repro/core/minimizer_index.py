"""Device-resident reference index for the linear mapper."""
from __future__ import annotations

import threading
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from .segram.minimizer import build_index


class ReferenceIndex(NamedTuple):
    ref: jnp.ndarray  # [L] int8 reference bases
    hashes: jnp.ndarray  # [M] uint32 sorted minimizer hashes
    positions: jnp.ndarray  # [M] int32


def build_reference_index(ref: np.ndarray, *, w: int = 10, k: int = 15,
                          freq_frac: float = 0.0002) -> ReferenceIndex:
    idx = build_index(ref, w=w, k=k, freq_frac=freq_frac)
    return ReferenceIndex(
        ref=jnp.asarray(ref.astype(np.int8)),
        hashes=jnp.asarray(idx.hashes),
        positions=jnp.asarray(idx.positions),
    )


class EpochedIndex:
    """Epoch-stamped handle around a ``ReferenceIndex``.

    The serving layer keys its result cache on ``(read digest, epoch)``
    (`serve/cache.py`), so swapping in a rebuilt reference must be
    observable: ``refresh()`` replaces the index and bumps ``epoch``,
    which atomically invalidates every result cached against the old
    reference.  The handle is cheap to share — readers grab
    ``(index, epoch)`` pairs under the lock via ``current()``.
    """

    def __init__(self, index: ReferenceIndex, *, w: int, k: int,
                 epoch: int = 0, freq_frac: float = 0.0002):
        # w/k are required: ReferenceIndex doesn't carry its build params,
        # and defaulting them here would silently desync refresh() (and any
        # consumer validating seeding params) from how `index` was built
        self._lock = threading.Lock()
        self._index = index
        self.epoch = epoch
        self._build_kw = dict(w=w, k=k, freq_frac=freq_frac)

    @property
    def index(self) -> ReferenceIndex:
        return self._index

    def current(self) -> tuple[ReferenceIndex, int]:
        """Consistent (index, epoch) pair for one mapping batch."""
        with self._lock:
            return self._index, self.epoch

    def refresh(self, ref: np.ndarray, **build_kw) -> int:
        """Rebuild the index from a new reference; returns the new epoch."""
        kw = {**self._build_kw, **build_kw}
        new = build_reference_index(ref, **kw)
        with self._lock:
            self._index = new
            self._build_kw = kw
            self.epoch += 1
            return self.epoch


def build_epoched_index(ref: np.ndarray, *, w: int = 10, k: int = 15,
                        freq_frac: float = 0.0002) -> EpochedIndex:
    """Build a reference index wrapped in an epoch-stamped serving handle."""
    return EpochedIndex(
        build_reference_index(ref, w=w, k=k, freq_frac=freq_frac),
        w=w, k=k, freq_frac=freq_frac)  # records the actual build params
