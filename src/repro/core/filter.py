"""Use case 2: pre-alignment filtering (paper §4.8, §4.10.3).

GenASM-DC (no traceback) computes the *exact* semi-global distance of a
short read against each candidate region; candidates above the edit
threshold are rejected before the expensive alignment step.  Because the
distance is exact (not an approximation like Shouji's), the false-accept
rate is ~0 by construction — the paper's headline accuracy result.

The q-gram primitives below serve the *tile pre-filter* tier in front of
that exact filter (the survey's cheap-screen-before-exact-filter
cascade): per-tile Bloom filters over the tile's q-grams let the graph
mapper reject candidate tiles that cannot contain a ≤k mapping with one
vectorized count — no GenASM-DC launch at all.  Soundness comes from the
q-gram lemma: a pattern of length m within edit distance k of some text
shares at least ``(m - q + 1) - q·k`` q-grams with it, so a tile whose
Bloom filter confirms fewer (minus a slack term for q-grams the graph
linearization cannot represent as substrings) is provably distance > k.
Bloom false positives and wildcard-touching q-grams only *raise* the
confirmed count, keeping the screen one-sided.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitvector import SENTINEL, WILDCARD
from .genasm_dc import bitap_search
from .segram.minimizer import hash32, kmer_codes

QGRAM_Q = 8  # q-gram width of the tile screen (2-bit packed, 16 bits)
BLOOM_BITS = 4096  # per-tile Bloom width: 128 uint32 words
BLOOM_WORDS = BLOOM_BITS // 32
# numpy, not jnp: a device constant here would initialize the jax
# backend at import time, locking the device count before test/launch
# code can set XLA_FLAGS (e.g. forced host-device meshes).
_INVALID = np.uint32(0xFFFFFFFF)


def qgram_codes(seq: jnp.ndarray, q: int = QGRAM_Q) -> jnp.ndarray:
    """Packed 2-bit q-gram codes per position (``0xFFFFFFFF`` where the
    window touches a non-ACGT char) — `kmer_codes` at the screen's q."""
    return kmer_codes(seq, q)


def _bloom_probes(codes: jnp.ndarray):
    """Two bit positions per code from one murmur-mixed hash."""
    h = hash32(codes)
    return h & jnp.uint32(BLOOM_BITS - 1), \
        (h >> 13) & jnp.uint32(BLOOM_BITS - 1)


def qgram_bloom(bases: jnp.ndarray, n_valid, *, q: int = QGRAM_Q
                ) -> jnp.ndarray:
    """[n] int8 bases → ``[BLOOM_WORDS]`` uint32 Bloom of its q-grams.

    Only windows fully inside the first ``n_valid`` chars are inserted;
    windows touching non-ACGT chars (sentinel padding) are skipped —
    queries count those read-side as hits, so skipping stays sound.
    """
    codes = qgram_codes(bases, q)
    npos = codes.shape[0]
    ok = (jnp.arange(npos) + q <= n_valid) & (codes != _INVALID)
    bits = jnp.zeros((BLOOM_BITS + 1,), bool)
    for probe in _bloom_probes(codes):
        bits = bits.at[jnp.where(ok, probe, BLOOM_BITS)].set(True)
    packed = bits[:BLOOM_BITS].reshape(BLOOM_WORDS, 32)
    shifts = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(jnp.where(packed, shifts[None, :], jnp.uint32(0)),
                   axis=-1, dtype=jnp.uint32)


def qgram_hits(codes: jnp.ndarray, pos_ok: jnp.ndarray, bloom: jnp.ndarray
               ) -> jnp.ndarray:
    """Count query q-grams the Bloom filter *may* contain.

    ``codes``/``pos_ok`` are ``[..., P]`` (uint32 codes, bool real-window
    mask), ``bloom`` is ``[..., BLOOM_WORDS]`` with identical leading
    dims.  Invalid (wildcard-touching) codes count as hits — the screen
    must never undercount against a text that could match them.
    """
    may = codes == _INVALID
    hit = jnp.ones_like(may)
    for probe in _bloom_probes(codes):
        word = jnp.take_along_axis(bloom, (probe >> 5).astype(jnp.int32),
                                   axis=-1)
        hit = hit & (((word >> (probe & 31)) & 1) != 0)
    return jnp.sum((hit | may) & pos_ok, axis=-1, dtype=jnp.int32)


def qgram_min_hits(n_pos, k: int, slack, *, q: int = QGRAM_Q):
    """q-gram-lemma lower bound on confirmed q-grams at distance ≤ k.

    ``n_pos`` is the pattern's real q-gram count (``m - q + 1``), each
    edit can destroy at most ``q`` of them, and ``slack`` bounds the
    q-grams a matching graph path may spell across hop>1 edges (chains
    that are not substrings of the tile linearization, hence absent from
    the Bloom filter).  Non-positive bounds mean "cannot prune".
    """
    return n_pos - q * k - slack


@partial(jax.jit, static_argnames=("m_bits", "k"))
def filter_candidates(texts: jnp.ndarray, reads: jnp.ndarray, read_lens, *,
                      m_bits: int, k: int):
    """Batch pre-alignment filter.

    ``texts``: [B, n] int8 candidate regions (sentinel-padded by caller to
    at least read_len + k + pad).  ``reads``: [B, m_bits] int8
    wildcard-padded reads.  Returns (accept [B] bool, dist [B] int32) where
    dist is the exact semi-global distance (k+1 ⇒ rejected).
    """
    def one(text, read):
        dists = bitap_search(text, read, m_bits=m_bits, k=k)
        return jnp.min(dists)

    dist = jax.vmap(one)(texts, reads)
    return dist <= k, dist


def prepare_read(read, m_bits: int):
    """Host-side helper: wildcard-pad a 1-D numpy read to ``m_bits``."""
    import numpy as np

    buf = np.full((m_bits,), WILDCARD, np.int8)
    buf[: len(read)] = read
    return buf


def prepare_region(region, n: int):
    """Host-side helper: sentinel-pad a candidate region to ``n``."""
    import numpy as np

    buf = np.full((n,), SENTINEL, np.int8)
    buf[: len(region)] = region
    return buf
