"""Use case 2: pre-alignment filtering (paper §4.8, §4.10.3).

GenASM-DC (no traceback) computes the *exact* semi-global distance of a
short read against each candidate region; candidates above the edit
threshold are rejected before the expensive alignment step.  Because the
distance is exact (not an approximation like Shouji's), the false-accept
rate is ~0 by construction — the paper's headline accuracy result.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bitvector import SENTINEL, WILDCARD
from .genasm_dc import bitap_search


@partial(jax.jit, static_argnames=("m_bits", "k"))
def filter_candidates(texts: jnp.ndarray, reads: jnp.ndarray, read_lens, *,
                      m_bits: int, k: int):
    """Batch pre-alignment filter.

    ``texts``: [B, n] int8 candidate regions (sentinel-padded by caller to
    at least read_len + k + pad).  ``reads``: [B, m_bits] int8
    wildcard-padded reads.  Returns (accept [B] bool, dist [B] int32) where
    dist is the exact semi-global distance (k+1 ⇒ rejected).
    """
    def one(text, read):
        dists = bitap_search(text, read, m_bits=m_bits, k=k)
        return jnp.min(dists)

    dist = jax.vmap(one)(texts, reads)
    return dist <= k, dist


def prepare_read(read, m_bits: int):
    """Host-side helper: wildcard-pad a 1-D numpy read to ``m_bits``."""
    import numpy as np

    buf = np.full((m_bits,), WILDCARD, np.int8)
    buf[: len(read)] = read
    return buf


def prepare_region(region, n: int):
    """Host-side helper: sentinel-pad a candidate region to ``n``."""
    import numpy as np

    buf = np.full((n,), SENTINEL, np.int8)
    buf[: len(region)] = region
    return buf
