"""Dynamic-programming alignment baselines (the paper's software comparison).

The paper benchmarks GenASM against the DP alignment kernels inside
BWA-MEM/Minimap2 (affine-gap Smith-Waterman/Needleman-Wunsch) and against
GACT's tiled DP.  These are those kernels in JAX, row-scanned so time is
O(n·m) with O(m) memory — the quadratic cost GenASM replaces.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

NEG = -(10 ** 7)


@partial(jax.jit, static_argnames=())
def nw_edit_distance(text: jnp.ndarray, pattern: jnp.ndarray, p_len, t_len):
    """Unit-cost semi-global distance (anchored start, free text end).

    dp rows over pattern; masked past p_len / t_len so fixed buffers work.
    """
    m_cap = pattern.shape[-1]
    n_cap = text.shape[-1]
    BIG = jnp.int32(10 ** 6)
    cols = jnp.arange(n_cap + 1)
    row0 = jnp.where(cols <= t_len, cols, BIG).astype(jnp.int32)  # dp[0][j] = j

    def row_step(carry, pi):
        prev, best = carry
        pc = pattern[pi]
        cost = (pc != text).astype(jnp.int32)
        diag = prev[:-1] + cost  # dp[i-1][j-1] + cost
        up = prev[1:] + 1  # deletion of text? (consumes pattern) -> insertion

        def col_step(left, du):
            d, u = du
            cur = jnp.minimum(jnp.minimum(d, u), left + 1)
            return cur, cur

        first = pi + 1  # dp[i][0] = i
        _, rest = lax.scan(col_step, first.astype(jnp.int32), (diag, up))
        row = jnp.concatenate([first[None].astype(jnp.int32), rest])
        row = jnp.where(cols <= t_len, row, BIG)
        row = jnp.where(pi < p_len, row, prev)
        rb = jnp.where(pi == p_len - 1, jnp.min(row), best)
        return (row, rb), None

    (_, best), _ = lax.scan(row_step, (row0, BIG), jnp.arange(m_cap))
    return best


@partial(jax.jit, static_argnames=("match", "subs", "gap_open", "gap_extend", "local"))
def affine_align_score(
    text: jnp.ndarray,
    pattern: jnp.ndarray,
    p_len,
    t_len,
    *,
    match: int = 2,
    subs: int = -4,
    gap_open: int = -4,
    gap_extend: int = -2,
    local: bool = False,
):
    """Affine-gap alignment score (Gotoh).  ``local=True`` → Smith-Waterman.

    Semi-global otherwise: pattern fully consumed, free text end, anchored
    text start.  Gap of length L costs open + L·extend (minimap2 convention).
    """
    m_cap = pattern.shape[-1]
    n_cap = text.shape[-1]
    cols = jnp.arange(n_cap + 1)
    big_neg = jnp.int32(NEG)
    # H: best score; E: gap-in-pattern (deletion run); F: gap-in-text (insertion run)
    if local:
        H0 = jnp.zeros((n_cap + 1,), jnp.int32)
    else:
        H0 = jnp.where(
            cols == 0, 0, gap_open + gap_extend * cols
        ).astype(jnp.int32)  # leading deletions
    E0 = jnp.full((n_cap + 1,), big_neg, jnp.int32)

    def row_step(carry, pi):
        Hprev, Eprev, best = carry
        pc = pattern[pi]
        sub = jnp.where(pc == text, match, subs).astype(jnp.int32)
        diag = Hprev[:-1] + sub
        E = jnp.maximum(Eprev[1:] + gap_extend, Hprev[1:] + gap_open + gap_extend)

        def col_step(hf, de):
            h_left, f_left = hf
            d, e = de
            f = jnp.maximum(f_left + gap_extend, h_left + gap_open + gap_extend)
            h = jnp.maximum(jnp.maximum(d, e), f)
            if local:
                h = jnp.maximum(h, 0)
            return (h, f), h

        h00 = jnp.where(
            jnp.asarray(local), 0, gap_open + gap_extend * (pi + 1)
        ).astype(jnp.int32)
        (_, _), rest = lax.scan(col_step, (h00, big_neg), (diag, E))
        Hrow = jnp.concatenate([h00[None], rest])
        Hrow = jnp.where(cols <= t_len, Hrow, big_neg)
        Erow = jnp.concatenate([big_neg[None], E])
        active = pi < p_len
        Hrow = jnp.where(active, Hrow, Hprev)
        Erow = jnp.where(active, Erow, Eprev)
        if local:
            best = jnp.maximum(best, jnp.max(Hrow))
        else:
            best = jnp.where(pi == p_len - 1, jnp.max(Hrow), best)
        return (Hrow, Erow, best), None

    (_, _, best), _ = lax.scan(row_step, (H0, E0, big_neg), jnp.arange(m_cap))
    return best
