"""GenASM-DC: the paper's modified Bitap distance calculation (Algorithm 1).

Two entry points:
  * :func:`window_dc` — one divide-and-conquer window (sub-text vs
    sub-pattern, both ``W`` chars), emitting the intermediate M/I/D
    bitvectors GenASM-TB walks (the "TB-SRAM" contents).  Pure-JAX
    reference path; the Pallas kernel in ``repro.kernels.genasm_dc``
    computes the identical function for batches of windows.
  * :func:`bitap_search` — full-length multi-word Bitap over a text
    region, reporting the minimum distance and every match location's
    distance (used by the pre-alignment filter and as a building block
    for edit-distance calculation).

All loops use ``jax.lax`` control flow so they lower to compact HLO.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .bitvector import msb, n_words, ones, pattern_bitmasks, shl1

# TB-store layout along axis -2: match, insertion, deletion.  The
# substitution vector is derived as shl1(deletion) (paper §4.6).
TB_MATCH, TB_INS, TB_DEL = 0, 1, 2


def dc_step(R_old: jnp.ndarray, cur_pm: jnp.ndarray, k: int):
    """One text-character step of GenASM-DC.

    ``R_old``: ``[k+1, nw]`` status bitvectors from the previous text char.
    ``cur_pm``: ``[nw]`` pattern bitmask of the current text char.
    Returns ``(R_new [k+1, nw], store [k+1, 3, nw])`` where ``store`` holds
    the intermediate (M, I, D) bitvectors for traceback.
    """
    nw = R_old.shape[-1]
    R0 = shl1(R_old[0]) | cur_pm

    def d_step(r_prev_new, olds):
        oldRdm1, oldRd = olds
        D = oldRdm1
        S = shl1(oldRdm1)
        I = shl1(r_prev_new)
        M = shl1(oldRd) | cur_pm
        Rd = D & S & I & M
        return Rd, (M, I, D, Rd)

    if k > 0:
        _, (Ms, Is, Ds, Rds) = lax.scan(d_step, R0, (R_old[:-1], R_old[1:]))
        R_new = jnp.concatenate([R0[None], Rds], axis=0)
        M_all = jnp.concatenate([R0[None], Ms], axis=0)
        I_all = jnp.concatenate([ones((1, nw)), Is], axis=0)
        D_all = jnp.concatenate([ones((1, nw)), Ds], axis=0)
    else:
        R_new = R0[None]
        M_all = R0[None]
        I_all = ones((1, nw))
        D_all = ones((1, nw))
    store = jnp.stack([M_all, I_all, D_all], axis=1)  # [k+1, 3, nw]
    return R_new, store


@partial(jax.jit, static_argnames=("w", "k"))
def window_dc(sub_text: jnp.ndarray, sub_pattern: jnp.ndarray, *, w: int, k: int):
    """GenASM-DC over one window.

    ``sub_text``/``sub_pattern``: ``[w] int8`` base ids (4 = sentinel /
    wildcard).  Text is scanned ``i = w-1 .. 0`` and the window answers at
    ``i = 0`` (candidate-anchored alignment start).

    Returns:
      ``d_min``: ``int32`` minimum distance (== ``k+1`` when no alignment).
      ``tb``: ``[w, k+1, 3, nw] uint32`` — intermediate bitvectors indexed
      by *text position* ``i`` (``tb[0]`` is the last-computed iteration,
      where traceback starts).
    """
    nw = n_words(w)
    pm = pattern_bitmasks(sub_pattern, w)  # [5, nw]
    R_init = ones((k + 1, nw))

    def step(R_old, i):
        cur_pm = pm[sub_text[i]]
        R_new, store = dc_step(R_old, cur_pm, k)
        return R_new, store

    idx = jnp.arange(w - 1, -1, -1)
    R_fin, stores = lax.scan(step, R_init, idx)
    tb = stores[::-1]  # index by text position i (scan emitted i = w-1 first)
    m = msb(R_fin)  # [k+1]; 0 = full pattern matches text[0:] with <= d edits
    found = m == 0
    d_min = jnp.where(jnp.any(found), jnp.argmax(found), k + 1).astype(jnp.int32)
    return d_min, tb


@partial(jax.jit, static_argnames=("w", "k"))
def window_dc_r(sub_text: jnp.ndarray, sub_pattern: jnp.ndarray, *, w: int, k: int):
    """GenASM-DC storing only the status rows R (beyond-paper TB-store
    compression, §Perf #3): all four TB check vectors derive from R.

    Returns ``(d_min, R_store [w+1, k+1, nw])`` — row ``w`` is the all-ones
    boundary (i = w), row ``i`` the status after processing text char i.
    """
    nw = n_words(w)
    pm = pattern_bitmasks(sub_pattern, w)
    R_init = ones((k + 1, nw))

    def step(R_old, i):
        R_new, _ = dc_step(R_old, pm[sub_text[i]], k)
        return R_new, R_new

    idx = jnp.arange(w - 1, -1, -1)
    R_fin, rows = lax.scan(step, R_init, idx)
    store = jnp.concatenate([rows[::-1], R_init[None]], axis=0)  # [w+1, k+1, nw]
    m = msb(R_fin)
    found = m == 0
    d_min = jnp.where(jnp.any(found), jnp.argmax(found), k + 1).astype(jnp.int32)
    return d_min, store


@partial(jax.jit, static_argnames=("m_bits", "k"))
def bitap_search(text: jnp.ndarray, pattern: jnp.ndarray, *, m_bits: int, k: int):
    """Full-length multi-word Bitap search of ``pattern`` in ``text``.

    ``text``: ``[n] int8``; ``pattern``: ``[m_bits] int8`` (wildcard-padded).
    Returns ``dists [n] int32``: for each text position ``i``, the minimum
    ``d <= k`` such that the full pattern matches ``text[i:]`` with ``d``
    edits (``k+1`` where none).  ``dists.min()`` is the semi-global
    distance; used by the pre-alignment filter.
    """
    pm = pattern_bitmasks(pattern, m_bits)
    k = int(k)
    R_init = ones((k + 1, n_words(m_bits)))

    def step(R_old, i):
        R_new, _ = dc_step(R_old, pm[text[i]], k)
        m = msb(R_new)
        found = m == 0
        d = jnp.where(jnp.any(found), jnp.argmax(found), k + 1).astype(jnp.int32)
        return R_new, d

    n = text.shape[0]
    _, dists_rev = lax.scan(step, R_init, jnp.arange(n - 1, -1, -1))
    return dists_rev[::-1]
