"""Use case 3: edit distance calculation between two arbitrary-length
sequences (paper §4.8, §4.10.4).

Per the paper, the windowed DC+TB pipeline is reused (the TB walk drives
the divide-and-conquer advance) but no CIGAR is emitted by default.  For
short sequences the full-length multi-word Bitap is also provided.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .genasm import GenASMConfig, align
from .genasm_dc import bitap_search
from .myers import myers_distance


@partial(jax.jit, static_argnames=("cfg", "p_cap"))
def genasm_distance(a: jnp.ndarray, b: jnp.ndarray, a_len, b_len, *,
                    cfg: GenASMConfig = GenASMConfig(), p_cap: int | None = None):
    """Edit distance of ``a`` (pattern) vs ``b`` (text) via windowed GenASM.

    Semi-global semantics (pattern consumed, free text end); pass
    ``b_len = a_len`` region for a global-ish distance.  Returns int32
    distance, -1 when the per-window threshold was exceeded.
    """
    res = align(b, a, a_len, b_len, cfg=cfg, p_cap=p_cap, emit_cigar=False)
    return res.distance


def genasm_distance_batch(a, b, a_lens, b_lens, *, cfg=GenASMConfig()):
    f = partial(genasm_distance, cfg=cfg)
    return jax.vmap(f)(a, b, a_lens, b_lens)


@partial(jax.jit, static_argnames=("m_bits", "k"))
def bitap_distance(a: jnp.ndarray, b: jnp.ndarray, *, m_bits: int, k: int):
    """Full-length Bitap distance (short sequences; exact, threshold k)."""
    return jnp.min(bitap_search(b, a, m_bits=m_bits, k=k))


def myers_distance_batch(texts, patterns, m_lens, *, m_bits: int, mode="global"):
    f = partial(myers_distance, m_bits=m_bits, mode=mode)
    return jax.vmap(f)(texts, patterns, m_lens)
