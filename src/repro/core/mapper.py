"""End-to-end linear read mapper (paper Figure 2-2 with GenASM inside).

Seed-and-extend: MinSeed-style minimizer seeding → GenASM-DC pre-alignment
filter over candidates → windowed GenASM DC+TB alignment of the best
candidate.  The full per-read pipeline is one jitted function; batches
vmap and the launcher shards reads over ``("pod", "data")`` with the
minimizer index replicated or sharded over ``"model"`` (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bitvector import SENTINEL, WILDCARD
from .genasm import GenASMConfig, align
from .genasm_dc import bitap_search
from .minimizer_index import ReferenceIndex, build_reference_index
from .segram.minimizer import seed_candidates


class MapResult(NamedTuple):
    position: jnp.ndarray  # int32 mapped reference start (-1 if unmapped)
    distance: jnp.ndarray  # int32 edit distance (-1 if unmapped)
    ops: jnp.ndarray  # packed CIGAR
    n_ops: jnp.ndarray
    failed: jnp.ndarray


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "p_cap", "filter_bits", "filter_k", "max_candidates",
        "minimizer_w", "minimizer_k",
    ),
)
def map_read(
    index: ReferenceIndex,
    read: jnp.ndarray,
    read_len,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
) -> MapResult:
    """Map one read against the indexed reference."""
    starts, votes = seed_candidates(
        read,
        index.hashes,
        index.positions,
        w=minimizer_w,
        k=minimizer_k,
        max_candidates=max_candidates,
    )
    L = index.ref.shape[0]
    # candidate starts are diagonal-bucketed to 32 (minimizer voting), so the
    # filter window must absorb bucket quantization + k edits of drift
    margin = filter_k + 32
    t_cap = p_cap + cfg.w * 2

    # --- pre-alignment filter (use case 2): exact distance of the read's
    # first filter_bits bases against each candidate region prefix.
    fpat = jnp.where(
        jnp.arange(filter_bits) < jnp.minimum(read_len, filter_bits),
        read[:filter_bits], WILDCARD,
    ).astype(jnp.int8)

    def filt(s):
        s0 = jnp.clip(s - margin, 0, jnp.maximum(L - 1, 0))
        region = jax.lax.dynamic_slice(
            jnp.concatenate([index.ref, jnp.full((filter_bits + 2 * margin,),
                                                 SENTINEL, jnp.int8)]),
            (s0,), (filter_bits + 2 * margin,),
        )
        dists = bitap_search(region, fpat, m_bits=filter_bits, k=filter_k)
        return jnp.min(dists), s0 + jnp.argmin(dists)

    fd, fpos = jax.vmap(filt)(starts)
    fd = jnp.where(votes > 0, fd, filter_k + 1)
    best = jnp.argmin(fd)
    pos = fpos[best]
    prefilter_ok = fd[best] <= filter_k

    # --- alignment (use case 1): windowed GenASM at the filtered position.
    text = jax.lax.dynamic_slice(
        jnp.concatenate([index.ref, jnp.full((t_cap,), SENTINEL, jnp.int8)]),
        (pos,), (t_cap,),
    )
    r = read[:p_cap]
    if r.shape[0] < p_cap:
        r = jnp.pad(r, (0, p_cap - r.shape[0]), constant_values=WILDCARD)
    pat = jnp.where(jnp.arange(p_cap) < read_len, r, WILDCARD).astype(jnp.int8)
    res = align(text, pat, read_len.astype(jnp.int32),
                jnp.minimum(L - pos, t_cap).astype(jnp.int32), cfg=cfg, p_cap=p_cap)
    failed = res.failed | (~prefilter_ok)
    return MapResult(
        position=jnp.where(failed, -1, pos).astype(jnp.int32),
        distance=jnp.where(failed, -1, res.distance),
        ops=res.ops,
        n_ops=res.n_ops,
        failed=failed,
    )


def map_batch(index: ReferenceIndex, reads, read_lens, **kw):
    f = partial(map_read, index, **kw)
    return jax.vmap(f)(reads, read_lens)
