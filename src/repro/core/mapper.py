"""End-to-end linear read mapper (paper Figure 2-2 with GenASM inside).

Seed-and-extend: MinSeed-style minimizer seeding → GenASM-DC pre-alignment
filter over candidates → windowed GenASM DC+TB alignment of the best
candidate.  Seeding + filtering is one jitted, vmapped stage
(:func:`seed_and_filter_batch`); the alignment stage is dispatched
through `repro.align.align_batch`, so every registered backend (pure
``lax``, the Pallas kernels, the ``ref`` oracle) drives the same
pipeline — the launcher shards reads over ``("pod", "data")`` with the
minimizer index replicated or sharded over ``"model"`` (DESIGN.md §5).
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bitvector import SENTINEL, WILDCARD
from .genasm import GenASMConfig
from .genasm_dc import bitap_search
from .minimizer_index import ReferenceIndex, build_reference_index  # noqa: F401
from .segram.minimizer import seed_candidates

# lexicographic-selection sentinel: masked-out candidates sort last
POS_SENTINEL = jnp.iinfo(jnp.int32).max


class MapResult(NamedTuple):
    position: jnp.ndarray  # int32 mapped reference start (-1 if unmapped)
    distance: jnp.ndarray  # int32 edit distance (-1 if unmapped)
    ops: jnp.ndarray  # packed CIGAR
    n_ops: jnp.ndarray
    failed: jnp.ndarray


class SeedFilterResult(NamedTuple):
    position: jnp.ndarray  # int32 best candidate start (filter-refined)
    prefilter_ok: jnp.ndarray  # bool — candidate survived the filter
    text: jnp.ndarray  # [t_cap] int8 reference region at position
    t_len: jnp.ndarray  # int32 valid text length
    pattern: jnp.ndarray  # [p_cap] int8 wildcard-padded read
    # numpy default, not jnp: a device constant in the class body would
    # initialize the jax backend at module import, locking the device
    # count before XLA_FLAGS-based host-device forcing can apply.
    distance: jnp.ndarray = np.int32(0)  # int32 winning filter distance


def lex_best(fd: jnp.ndarray, fpos: jnp.ndarray) -> jnp.ndarray:
    """Index of the lexicographically-minimal ``(fd, fpos)`` candidate.

    The selection rule must be *shard-layout independent*: candidates
    merged from per-shard seeding (`repro.shard`) arrive in a different
    order than single-index seeding produces, so "argmin with
    first-wins ties" would pick different winners at 1 vs N shards.
    Minimizing ``(distance, position)`` makes the winner a pure
    function of the candidate *set*, and collapses the duplicate
    candidates that shard-overlap margins produce (identical
    ``(fd, fpos)`` pairs dedup to whichever index argmin returns —
    their downstream alignment windows are byte-identical).
    """
    pm = jnp.where(fd == jnp.min(fd), fpos, POS_SENTINEL)
    return jnp.argmin(pm)


def seed_filter_read(
    ref_buf: jnp.ndarray,
    ref_offset,
    ref_len: int,
    hashes: jnp.ndarray,
    positions: jnp.ndarray,
    read: jnp.ndarray,
    read_len,
    *,
    p_cap: int,
    t_cap: int,
    filter_bits: int,
    filter_k: int,
    max_candidates: int,
    minimizer_w: int,
    minimizer_k: int,
) -> SeedFilterResult:
    """Seed + pre-alignment-filter one read against one reference buffer.

    ``ref_buf`` is an ``[Lb] int8`` reference slice whose first base sits
    at global coordinate ``ref_offset`` of a reference of total length
    ``ref_len``; ``hashes``/``positions`` are a sorted minimizer table
    whose positions are *global* coordinates.  The whole-reference
    mapper calls this with ``ref_offset=0`` and the sharded mapper with
    each shard's haloed slice — the shared body is what keeps 1-shard
    and N-shard filter distances, refined positions, and window bytes
    bit-identical (positions are compared and emitted in global
    coordinates throughout).

    Returns a :class:`SeedFilterResult` whose ``position`` is the
    global refined start of the lexicographically best ``(distance,
    position)`` candidate (``POS_SENTINEL`` if the read produced no
    seed hits), with the ``[t_cap]`` alignment text sliced from
    ``ref_buf``.
    """
    starts, votes = seed_candidates(
        read, hashes, positions,
        w=minimizer_w, k=minimizer_k, max_candidates=max_candidates,
    )
    # candidate starts are diagonal-bucketed to 32 (minimizer voting), so the
    # filter window must absorb bucket quantization + k edits of drift
    margin = filter_k + 32

    # --- pre-alignment filter (use case 2): exact distance of the read's
    # first filter_bits bases against each candidate region prefix.
    fpat = jnp.where(
        jnp.arange(filter_bits) < jnp.minimum(read_len, filter_bits),
        read[:filter_bits], WILDCARD,
    ).astype(jnp.int8)
    region_pad = jnp.concatenate(
        [ref_buf, jnp.full((filter_bits + 2 * margin,), SENTINEL, jnp.int8)])

    def filt(s):
        s0 = jnp.clip(s - margin, 0, jnp.maximum(ref_len - 1, 0))
        region = jax.lax.dynamic_slice(
            region_pad, (s0 - ref_offset,), (filter_bits + 2 * margin,))
        dists = bitap_search(region, fpat, m_bits=filter_bits, k=filter_k)
        return jnp.min(dists), s0 + jnp.argmin(dists).astype(jnp.int32)

    fd, fpos = jax.vmap(filt)(starts)
    fd = jnp.where(votes > 0, fd, filter_k + 1)
    fpos = jnp.where(votes > 0, fpos, POS_SENTINEL)
    best = lex_best(fd, fpos)
    pos = fpos[best]
    prefilter_ok = fd[best] <= filter_k

    text = jax.lax.dynamic_slice(
        jnp.concatenate([ref_buf, jnp.full((t_cap,), SENTINEL, jnp.int8)]),
        (jnp.minimum(pos, ref_len) - ref_offset,), (t_cap,),
    )
    r = read[:p_cap]
    if r.shape[0] < p_cap:
        r = jnp.pad(r, (0, p_cap - r.shape[0]), constant_values=WILDCARD)
    pat = jnp.where(jnp.arange(p_cap) < read_len, r, WILDCARD).astype(jnp.int8)
    return SeedFilterResult(
        position=pos.astype(jnp.int32),
        prefilter_ok=prefilter_ok,
        text=text,
        t_len=jnp.clip(ref_len - pos, 0, t_cap).astype(jnp.int32),
        pattern=pat,
        distance=fd[best].astype(jnp.int32),
    )


def _seed_and_filter_one(
    index: ReferenceIndex,
    read: jnp.ndarray,
    read_len,
    *,
    p_cap: int,
    t_cap: int,
    filter_bits: int,
    filter_k: int,
    max_candidates: int,
    minimizer_w: int,
    minimizer_k: int,
) -> SeedFilterResult:
    return seed_filter_read(
        index.ref, jnp.int32(0), index.ref.shape[0],
        index.hashes, index.positions, read, read_len,
        p_cap=p_cap, t_cap=t_cap, filter_bits=filter_bits,
        filter_k=filter_k, max_candidates=max_candidates,
        minimizer_w=minimizer_w, minimizer_k=minimizer_k)


@partial(
    jax.jit,
    static_argnames=(
        "p_cap", "t_cap", "filter_bits", "filter_k", "max_candidates",
        "minimizer_w", "minimizer_k",
    ),
)
def seed_and_filter_batch(index, reads, read_lens, *, p_cap, t_cap,
                          filter_bits, filter_k, max_candidates,
                          minimizer_w, minimizer_k) -> SeedFilterResult:
    """Vmapped seeding + pre-alignment filtering (one jit per shape)."""
    f = partial(
        _seed_and_filter_one, index, p_cap=p_cap, t_cap=t_cap,
        filter_bits=filter_bits, filter_k=filter_k,
        max_candidates=max_candidates, minimizer_w=minimizer_w,
        minimizer_k=minimizer_k)
    return jax.vmap(f)(reads, read_lens)


def map_batch(
    index: ReferenceIndex,
    reads: jnp.ndarray,
    read_lens: jnp.ndarray,
    *,
    cfg: GenASMConfig = GenASMConfig(),
    p_cap: int = 256,
    filter_bits: int = 128,
    filter_k: int = 12,
    max_candidates: int = 4,
    minimizer_w: int = 10,
    minimizer_k: int = 15,
    backend: str | None = None,
    block_bt: int | None = None,
) -> MapResult:
    """Map a read batch against the indexed reference.

    ``backend`` selects the alignment implementation by registry name
    (`repro.align`); None/"auto" resolves per platform.
    """
    from repro import align as align_dispatch

    t_cap = p_cap + cfg.w * 2
    sf = seed_and_filter_batch(
        index, reads, read_lens.astype(jnp.int32), p_cap=p_cap, t_cap=t_cap,
        filter_bits=filter_bits, filter_k=filter_k,
        max_candidates=max_candidates, minimizer_w=minimizer_w,
        minimizer_k=minimizer_k)

    res = align_dispatch.align_batch(
        sf.text, sf.pattern, read_lens.astype(jnp.int32), sf.t_len,
        cfg=cfg, backend=backend, p_cap=p_cap, block_bt=block_bt)
    failed = res.failed | (~sf.prefilter_ok)
    return MapResult(
        position=jnp.where(failed, -1, sf.position).astype(jnp.int32),
        distance=jnp.where(failed, -1, res.distance),
        ops=res.ops,
        n_ops=res.n_ops,
        failed=failed,
    )


def map_read(index: ReferenceIndex, read: jnp.ndarray, read_len, **kw
             ) -> MapResult:
    """Map one read (batch-of-one convenience wrapper)."""
    res = map_batch(index, read[None], jnp.asarray(read_len)[None], **kw)
    return jax.tree_util.tree_map(lambda x: x[0], res)


class LinearMapExecutor:
    """Two-stage compiled linear mapper: seed/filter stage + align stage.

    Computes exactly what `map_batch` computes (same ops, same integer
    math — PAF output is byte-identical), but jits the seed+filter and
    align stages *separately* so the host can time each one: every call
    records ``last_times`` — ``(stage, t_start, t_end, attrs)`` on the
    monotonic clock, with a ``compile`` attr flagging calls that traced
    — which the serve engine replays into its tracer (`repro.obs`,
    DESIGN.md §12).  The stage boundary materializes one
    `SeedFilterResult`, a per-flush cost measured at <1% of the stage
    itself on the smoke benchmark.

    ``trace_hook`` (if given) is called with ``("seed_filter",)`` /
    ``("align",)`` at trace time, mirroring `GraphMapExecutor`'s stage
    keys so retrace accounting is uniform across workloads.
    """

    def __init__(self, *, cfg: GenASMConfig = GenASMConfig(),
                 p_cap: int = 256,
                 filter_bits: int = 128,
                 filter_k: int = 12,
                 max_candidates: int = 4,
                 minimizer_w: int = 10,
                 minimizer_k: int = 15,
                 backend: str | None = None,
                 block_bt: int | None = None,
                 trace_hook=None):
        from repro import align as align_dispatch

        t_cap = p_cap + cfg.w * 2
        user_hook = trace_hook or (lambda key: None)
        self._compiled: set = set()

        def hook(key):
            self._compiled.add(key)
            user_hook(key)

        def sf_fn(index, reads, lens):
            hook(("seed_filter",))
            return seed_and_filter_batch(
                index, reads, lens.astype(jnp.int32), p_cap=p_cap,
                t_cap=t_cap, filter_bits=filter_bits, filter_k=filter_k,
                max_candidates=max_candidates, minimizer_w=minimizer_w,
                minimizer_k=minimizer_k)

        def align_fn(sf, lens):
            hook(("align",))
            res = align_dispatch.align_batch(
                sf.text, sf.pattern, lens.astype(jnp.int32), sf.t_len,
                cfg=cfg, backend=backend, p_cap=p_cap, block_bt=block_bt)
            failed = res.failed | (~sf.prefilter_ok)
            return MapResult(
                position=jnp.where(failed, -1, sf.position).astype(jnp.int32),
                distance=jnp.where(failed, -1, res.distance),
                ops=res.ops, n_ops=res.n_ops, failed=failed)

        self._sf = jax.jit(sf_fn)
        self._align = jax.jit(align_fn)
        self.last_times: list[tuple[str, float, float, dict]] = []

    def __call__(self, index: ReferenceIndex, reads, read_lens) -> MapResult:
        lens = jnp.asarray(read_lens)
        before = set(self._compiled)
        t0 = time.monotonic()
        sf = self._sf(index, jnp.asarray(reads), lens)
        jax.block_until_ready(sf)
        t1 = time.monotonic()
        res = self._align(sf, lens)
        jax.block_until_ready(res)
        t2 = time.monotonic()
        new = self._compiled - before
        self.last_times = [
            ("seed_filter", t0, t1, {"compile": ("seed_filter",) in new}),
            ("align", t1, t2, {"compile": ("align",) in new}),
        ]
        return res
