"""Benchmark harness utilities.

Synthetic-input generation is shared with the conformance tests via
`repro.align.inputs` (fixed seeds, one source of truth) and re-exported
here for the benchmark modules.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.align.inputs import (  # noqa: F401  (re-exports)
    aligned_read_batch,
    graph_read_batch,
    mutated_pair,
    padded_batch,
    profile_read_patterns,
    random_windows,
    variant_graph,
)


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)
