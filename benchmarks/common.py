"""Benchmark harness utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)
