"""Per-kernel roofline: predicted vs measured (EXPERIMENTS.md §Roofline).

For every registered align backend × a bucket-cap ladder, this emits one
row joining the three sides of `repro.obs.roofline`:

* **analytic** — exact DC word-ops / TB bytes / HBM traffic per
  ``align_batch`` call from the counter model (`align_counters`);
* **measured** — the compiled executable's ``cost_analysis()`` flops and
  bytes-accessed (same compile that is timed, so the numbers describe
  exactly the executable on the clock);
* **achieved** — analytic ops over min-of-iters wall time → ops/s,
  arithmetic intensity, and %-of-roof against the platform's
  `DeviceSpec`.

Two gates ride along: a **counter sanity** check (analytic vs
``cost_analysis()`` ops/bytes for the ``lax`` backend within the
documented factors — XLA's CPU flop counter ignores integer/bitwise ops
and counts scan bodies once, see DESIGN.md §13) and the **model-seeded
autotune** check (the ``block_bt`` ranked best by `predict_block_bt`
must be within 10% of the empirically autotuned best's throughput).

On CPU the Pallas rows run in interpret mode, so their *wall* numbers
measure the interpreter, not the kernel — the analytic columns are the
accelerator-relevant content there (ROADMAP item 5).  Alignment runs
distances-only (``emit_cigar=False``): the DC phase is what the counter
model covers.

    PYTHONPATH=src python benchmarks/roofline.py --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import align
from repro.core.genasm import GenASMConfig
from repro.obs.roofline import (DeviceSpec, align_counters, predict_block_bt,
                                predict_time_s)

try:
    from .common import aligned_read_batch, row
except ImportError:  # script-style: python benchmarks/roofline.py
    from common import aligned_read_batch, row

BACKENDS = ("ref", "lax", "pallas_dc", "pallas_dc_v2")

# documented agreement bands for the lax backend on CPU (DESIGN.md §13):
# XLA's CPU cost model counts only float flops (the integer/bitwise DC
# ops are invisible) and counts while/scan bodies once, so analytic
# word-ops exceed measured flops by a large, version-dependent factor;
# bytes agree within a much tighter band (the TB store dominates both)
OPS_RATIO_BAND = (0.25, 256.0)
BYTES_RATIO_BAND = (1.0 / 16.0, 16.0)


def _cost_of(compiled) -> dict:
    """flops / bytes-accessed from a compiled executable (version-safe)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    return {"measured_ops": float(ca.get("flops", 0.0)),
            "measured_bytes": float(ca.get("bytes accessed", 0.0))}


def _compile_site(backend: str, cap: int, batch: int, *, cfg: GenASMConfig,
                  block_bt: int):
    """One compiled distances-only align executable + its input args."""
    # reads a touch shorter than the cap so p_cap lands exactly on the
    # ladder rung the counters were computed for
    texts, pats, p_lens, t_lens = aligned_read_batch(
        batch, cap - 8, p_cap=cap, t_extra=2 * cfg.w, seed=29)
    args = (jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
            jnp.asarray(t_lens))
    assert int(pats.shape[1]) == cap

    def fn(t, p, pl, tl):
        return align.align_batch(t, p, pl, tl, cfg=cfg, backend=backend,
                                 p_cap=cap, emit_cigar=False,
                                 block_bt=block_bt).distance

    return jax.jit(fn).lower(*args).compile(), args


def _time_compiled(compiled, args, iters: int) -> float:
    """Min-of-iters wall seconds per call (one warmup off-clock)."""
    jax.block_until_ready(compiled(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def kernel_table(caps, *, batch: int, ref_batch: int, iters: int,
                 spec: DeviceSpec, cfg: GenASMConfig) -> list[dict]:
    """One predicted-vs-measured row per (backend, bucket_cap)."""
    rows = []
    for backend in BACKENDS:
        b = ref_batch if backend == "ref" else batch
        for cap in caps:
            bt = align.block_size_for(backend, cap, cfg.k, b)
            c = align_counters(backend, cap, cfg.k, b, w=cfg.w, o=cfg.o,
                               block_bt=bt)
            compiled, args = _compile_site(backend, cap, b, cfg=cfg,
                                           block_bt=bt)
            cost = _cost_of(compiled)
            wall = _time_compiled(compiled, args, iters)
            ach = c.word_ops / wall
            roof = spec.roof_ops_per_s(c.intensity)
            r = {
                "backend": backend, "bucket_cap": cap, "batch": b,
                "block_bt": c.notes.get("block_bt"), "exact": c.exact,
                "analytic_ops": c.word_ops,
                "analytic_tb_bytes": c.tb_bytes,
                "bytes": c.hbm_bytes,
                **cost,
                "intensity": round(c.intensity, 4),
                "wall_us": round(wall * 1e6, 1),
                "predicted_us": round(predict_time_s(c, spec) * 1e6, 1),
                "achieved_ops_per_s": round(ach, 1),
                "pct_of_roof": round(ach / roof, 6) if roof else 0.0,
            }
            rows.append(r)
            row(f"roofline_{backend}_cap{cap}", r["wall_us"],
                f"analytic_ops={c.word_ops:.3g};"
                f"measured_ops={cost['measured_ops']:.3g};"
                f"bytes={c.hbm_bytes:.3g};intensity={r['intensity']};"
                f"pct_of_roof={r['pct_of_roof']:.2%};"
                f"predicted_us={r['predicted_us']}")
    return rows


def sanity_check(rows: list[dict]) -> dict:
    """Analytic vs ``cost_analysis()`` agreement for the lax backend.

    The lax backend is the one site where no interpret-mode skew
    applies: the executable XLA costed is the executable that ran.
    """
    checks = []
    for r in rows:
        if r["backend"] != "lax":
            continue
        ops_ratio = (r["analytic_ops"] / r["measured_ops"]
                     if r["measured_ops"] else float("inf"))
        bytes_ratio = (r["bytes"] / r["measured_bytes"]
                       if r["measured_bytes"] else float("inf"))
        checks.append({
            "bucket_cap": r["bucket_cap"],
            "ops_ratio": round(ops_ratio, 3),
            "bytes_ratio": round(bytes_ratio, 3),
            "ops_ok": OPS_RATIO_BAND[0] <= ops_ratio <= OPS_RATIO_BAND[1],
            "bytes_ok":
                BYTES_RATIO_BAND[0] <= bytes_ratio <= BYTES_RATIO_BAND[1],
        })
    ok = bool(checks) and all(c["ops_ok"] and c["bytes_ok"] for c in checks)
    out = {"ops_ratio_band": list(OPS_RATIO_BAND),
           "bytes_ratio_band": list(BYTES_RATIO_BAND),
           "checks": checks, "ok": ok}
    row("roofline_counter_sanity", 0.0,
        f"ok={ok};n_checks={len(checks)}")
    return out


def autotune_check(*, cap: int, batch: int, candidates, iters: int,
                   spec: DeviceSpec, cfg: GenASMConfig) -> dict:
    """Model-seeded vs empirical block-size pick, throughput-compared.

    Runs the empirical `align.autotune` search and the zero-measurement
    `predict_block_bt` ranking over the same candidate set, then times
    both winners; ``within_10pct`` is the ISSUE acceptance bound.
    """
    backend = "pallas_dc"
    emp_bt = align.autotune(backend, cap, cfg.k, batch=batch,
                            candidates=candidates, cfg=cfg, iters=iters)
    model_bt = predict_block_bt(backend, cap, cfg.k, batch, spec=spec,
                                candidates=candidates, w=cfg.w, o=cfg.o)
    if emp_bt == model_bt:
        ratio = 1.0
        emp_s = model_s = None
    else:
        c1, a1 = _compile_site(backend, cap, batch, cfg=cfg,
                               block_bt=emp_bt)
        c2, a2 = _compile_site(backend, cap, batch, cfg=cfg,
                               block_bt=model_bt)
        emp_s = _time_compiled(c1, a1, iters)
        model_s = _time_compiled(c2, a2, iters)
        ratio = emp_s / model_s  # >1: model pick is faster than empirical
    out = {"bucket_cap": cap, "batch": batch, "candidates": list(candidates),
           "empirical_bt": emp_bt, "model_bt": model_bt,
           "empirical_s": emp_s, "model_s": model_s,
           "model_vs_empirical": round(ratio, 4),
           "within_10pct": ratio >= 0.9}
    row("roofline_autotune_model", 0.0,
        f"empirical_bt={emp_bt};model_bt={model_bt};"
        f"model_vs_empirical={out['model_vs_empirical']};"
        f"within_10pct={out['within_10pct']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small caps/batches, 1 timed iter)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    args = ap.parse_args(argv)

    if args.smoke:
        caps, batch, ref_batch, iters = (96, 160, 320), 8, 4, 1
        at = dict(cap=160, batch=16, candidates=(8, 16), iters=1)
    else:
        caps, batch, ref_batch, iters = (160, 320, 640), 16, 4, 2
        at = dict(cap=320, batch=64, candidates=(16, 32, 64), iters=2)

    cfg = GenASMConfig()
    spec = DeviceSpec.for_platform()
    align.clear_autotune_cache()  # heuristic block sizes, reproducible rows
    rows = kernel_table(caps, batch=batch, ref_batch=ref_batch, iters=iters,
                        spec=spec, cfg=cfg)
    out = {
        "platform": jax.default_backend(),
        "interpret_pallas": align.needs_interpret(),
        "device_spec": spec.name,
        "caps": list(caps),
        "kernels": rows,
        "sanity": sanity_check(rows),
        "autotune": autotune_check(spec=spec, cfg=cfg, **at),
    }
    align.clear_autotune_cache()  # don't leak the tuned site to other mods
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
