"""Roofline table from dryrun_results.json (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import row

RESULTS = Path(__file__).resolve().parents[1] / "dryrun_results.json"


def main():
    if not RESULTS.exists():
        row("roofline", 0.0, "dryrun_results.json missing — run repro.launch.dryrun")
        return
    res = json.loads(RESULTS.read_text())
    for key, rec in sorted(res.items()):
        if "error" in rec:
            row(f"roofline_{key.replace('|', '_')}", 0.0, f"ERROR:{rec['error'][:60]}")
            continue
        if "analytic" not in rec:
            continue
        a = rec["analytic"]
        row(
            f"roofline_{key.replace('|', '_')}",
            a["roofline_s"] * 1e6,
            (
                f"bottleneck={a['bottleneck']};compute_s={a['compute_s']:.2e};"
                f"memory_s={a['memory_s']:.2e};collective_s={a['collective_s']:.2e};"
                f"mfu_bound={a['mfu_bound']:.2f};"
                f"temp_gb={rec['memory']['temp_bytes'] / 1e9:.1f}"
            ),
        )


if __name__ == "__main__":
    main()
