"""BitMAc-style kernel analysis (paper Ch. 5): GenASM-DC Pallas kernel
throughput + arithmetic-intensity accounting (bytes/FLOP balance that
motivated the near-memory design)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import graph_read_batch, random_windows, row, timeit


def run(batch: int = 256, w: int = 64, k: int = 24):
    texts, pats = random_windows(batch, w, seed=13)

    kern = jax.jit(lambda t, p: ops.window_dc(t, p, w=w, k=k, block_bt=64))
    us_k = timeit(kern, jnp.asarray(texts), jnp.asarray(pats))
    pure = jax.jit(lambda t, p: ref.window_dc_batch(t, p, w=w, k=k))
    us_r = timeit(pure, jnp.asarray(texts), jnp.asarray(pats))

    # per-window work: W text steps × (k+1) rows × ~6 word-ops × nw words
    nw = w // 32
    ops_per_window = w * (k + 1) * 6 * nw
    tb_bytes = w * (k + 1) * 3 * nw * 4  # TB-SRAM stream per window (v1)
    tb_bytes_v2 = (w + 1) * (k + 1) * nw * 4  # R-only store (§Perf #8)
    row("kernel_dc_pallas_interpret", us_k / batch,
        f"windows_per_s={batch / (us_k / 1e6):.0f};word_ops_per_window={ops_per_window};tb_bytes={tb_bytes};ai={ops_per_window / tb_bytes:.2f}")
    row("kernel_dc_pure_jax", us_r / batch,
        f"windows_per_s={batch / (us_r / 1e6):.0f}")

    kern2 = jax.jit(lambda t, p: ops.window_dc_v2(t, p, w=w, k=k, block_bt=64))
    us_k2 = timeit(kern2, jnp.asarray(texts), jnp.asarray(pats))
    row("kernel_dc_v2_pallas_interpret", us_k2 / batch,
        f"windows_per_s={batch / (us_k2 / 1e6):.0f};tb_bytes={tb_bytes_v2};ai={ops_per_window / tb_bytes_v2:.2f}")


def run_bitalign_kernel(batch: int = 64, n: int = 128, m_bits: int = 64,
                        k: int = 12):
    bases, succ, pats, plens = graph_read_batch(batch, n, m_bits, k_read=16,
                                                seed=17, variant_seed=1)
    f = jax.jit(lambda b, s, p, l: ops.bitalign_dc(b, s, p, l, m_bits=m_bits,
                                                   k=k, block_bt=32))
    us = timeit(f, jnp.asarray(bases), jnp.asarray(succ), jnp.asarray(pats),
                jnp.asarray(plens))
    row("kernel_bitalign_pallas_interpret", us / batch,
        f"aligns_per_s={batch / (us / 1e6):.0f};nodes={n}")


def main():
    run()
    run_bitalign_kernel()


if __name__ == "__main__":
    main()
