"""Graph-vs-linear serving throughput under open-loop Poisson arrivals.

The same read set replayed through the `repro.serve` micro-batching
engine twice — once against the linear reference index (PAF workload)
and once against the variation-graph index (``workload="graph"``, GAF
workload) — reporting reads/s, tail latency and the graph/linear
throughput ratio (the EXPERIMENTS.md §Perf graph row).  Poisson arrivals
because that is the regime where the workload axis matters: both
workloads share the engine's admission queue, bucket ladder and executor
cache, so the delta isolates the mapper itself.

Each measured run is traced (`repro.obs`): the per-workload summary
carries the folded per-stage Amdahl ``attribution`` ledger — for the
graph workload that splits prefilter / dc_filter / align, the measured
form of the tile-screen win — and ``--trace-out base.json`` exports
``base_linear.json`` / ``base_graph.json`` Perfetto traces.

    PYTHONPATH=src python benchmarks/graph_serve.py           # full
    PYTHONPATH=src python benchmarks/graph_serve.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import minimizer_index
from repro.genomics import simulate
from repro.graph import index as graph_index
from repro.obs import Tracer, build_ledger, render_report
from repro.serve import EngineConfig, Metrics, ResultCache, ServeEngine, \
    poisson_load

try:
    from .common import row
except ImportError:  # script-style: python benchmarks/graph_serve.py
    from common import row


def run_workload(workload, index, reads, *, buckets, max_batch, rate_rps,
                 filter_k, warmup_reads, seed, prefilter=True,
                 trace_out=None):
    cfg = EngineConfig(buckets=buckets, max_batch=max_batch,
                       max_delay_s=0.005, workload=workload,
                       filter_k=filter_k, minimizer_w=8, minimizer_k=12,
                       graph_prefilter=prefilter)
    tracer = Tracer()
    tracer.enabled = False  # compile-time flushes stay out of the ledger
    engine = ServeEngine(index, cfg, tracer=tracer)
    # compile off-clock: the warmup set AND the measured reads, so every
    # (read-length, tile-count) ladder rung the measured run hits is
    # already traced (the result cache is reset below, so the measured
    # run still maps everything)
    engine.map_all(warmup_reads + reads)
    engine.metrics = Metrics()  # measured run starts from clean instruments
    engine.cache = ResultCache(cfg.cache_capacity)
    tracer.enabled = True
    rep = poisson_load(engine, reads, rate_rps=rate_rps, seed=seed)
    mapped = sum(1 for _, r in rep.results if r.position >= 0)
    summary = {
        "workload": workload,
        "backend": engine.align_backend,
        "n_reads": len(reads),
        "mapped": mapped,
        "reads_per_s": round(rep.reads_per_s, 2),
        "p50_ms": round(rep.p50_ms, 3),
        "p99_ms": round(rep.p99_ms, 3),
        "executors": engine.n_executors,
    }
    if workload == "graph":
        counters = engine.metrics.snapshot()  # flat instrument dict
        live = counters.get("graph_tiles_live", 0)
        pruned = counters.get("graph_tiles_pruned", 0)
        dc = counters.get("graph_dc_rows", 0)
        dense = counters.get("graph_dc_rows_dense", 0)
        summary["prefilter"] = bool(prefilter)
        summary["tiles_pruned_rate"] = round(pruned / live, 3) if live else 0.0
        summary["dc_rows_vs_dense"] = round(dc / dense, 3) if dense else 0.0
        summary["zero_survivor_reads"] = int(
            counters.get("graph_reads_zero_survivor", 0))
    engine.close()
    report = build_ledger(tracer.log).report()
    summary["attribution"] = report.to_dict()
    print(f"--- {workload} ---")
    print(render_report(report))
    if trace_out:
        tracer.log.export_chrome(trace_out)
        print(f"wrote {trace_out}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small ref, low rate)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="Perfetto trace base path (suffixed _linear/"
                         "_graph per workload)")
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (reads/s)")
    ap.add_argument("--no-prefilter", action="store_true",
                    help="disable the q-gram tile screen (A/B baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        ref_len, n_reads, read_len = 4_000, 32, 100
        buckets, max_batch, rate = (128,), 8, args.rate or 400.0
    else:
        ref_len, n_reads, read_len = 20_000, 96, 150
        buckets, max_batch, rate = (160, 320), 16, args.rate or 100.0

    ref = simulate.random_reference(ref_len, seed=1)
    variants = simulate.simulate_variants(
        ref, n_snp=ref_len // 400, n_ins=ref_len // 800,
        n_del=ref_len // 800, seed=3)
    lin_idx = minimizer_index.build_epoched_index(ref, w=8, k=12)
    g_idx = graph_index.build_epoched_graph_index(
        ref, variants, w=8, k=12, window=max(buckets) + 128)
    rs = simulate.simulate_reads(ref, n_reads=n_reads, read_len=read_len,
                                 profile=simulate.ILLUMINA, seed=2)
    warmup = simulate.simulate_reads(ref, n_reads=4, read_len=read_len,
                                     profile=simulate.ILLUMINA, seed=99)
    common = dict(buckets=buckets, max_batch=max_batch, rate_rps=rate,
                  filter_k=max(8, int(read_len * 0.05 * 1.5) + 4),
                  warmup_reads=list(warmup.reads), seed=args.seed,
                  prefilter=not args.no_prefilter)

    out = {"ref_len": ref_len, "n_variants": len(variants), "rate_rps": rate}
    for workload, index in (("linear", lin_idx), ("graph", g_idx)):
        trace_out = None
        if args.trace_out:
            base, ext = os.path.splitext(args.trace_out)
            trace_out = f"{base}_{workload}{ext or '.json'}"
        s = run_workload(workload, index, list(rs.reads),
                         trace_out=trace_out, **common)
        out[workload] = s
        row(f"graph_serve_{workload}", 1e6 / max(s["reads_per_s"], 1e-9),
            f"reads_per_s={s['reads_per_s']};p50_ms={s['p50_ms']};"
            f"p99_ms={s['p99_ms']};mapped={s['mapped']}/{s['n_reads']};"
            f"backend={s['backend']}")
    out["graph_vs_linear_throughput"] = round(
        out["graph"]["reads_per_s"] / max(out["linear"]["reads_per_s"], 1e-9),
        3)
    row("graph_serve_ratio", 0.0,
        f"graph_vs_linear_throughput={out['graph_vs_linear_throughput']}x")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
