"""Serving-engine benchmark: Poisson arrivals, bucketed vs single-cap.

Replays a mixed short/long read set (Illumina 150 bp + PacBio 1000 bp by
default) through the `repro.serve` micro-batching engine under open-loop
Poisson arrivals, twice: once with the length-bucket ladder and once with
every read padded to the single global cap (the old offline behaviour).
Reports reads/s, p50/p99 latency, mean batch occupancy, padded-base
waste, and cache hit rate per run — the EXPERIMENTS.md §Perf serve rows.

A third, closed-loop pass runs the bucketed engine in three modes —
tracer off / tracer on / tracer + per-kernel roofline counters on — to
measure tracing overhead (``trace_overhead_frac``) and counter-
collection overhead (``counter_overhead_frac``), both against the
ISSUE's <3% budget, and to fold the traced spans into the per-stage
Amdahl attribution ledger (``attribution`` in the JSON;
`repro.obs.attrib`).  ``--trace-out`` exports the traced pass as
Perfetto/Chrome ``trace_event`` JSON.

    PYTHONPATH=src python benchmarks/serve_engine.py           # full mix
    PYTHONPATH=src python benchmarks/serve_engine.py --smoke   # CI-sized
    ... --json serve_summary.json --trace-out trace.json       # artifacts
"""
from __future__ import annotations

import argparse
import gc
import json
import time

from repro.core import minimizer_index
from repro.genomics import simulate
from repro.obs import RooflineManager, Tracer, build_ledger, render_report
from repro.serve import EngineConfig, Metrics, ResultCache, ServeEngine, \
    poisson_load

try:
    from .common import row
except ImportError:  # script-style: python benchmarks/serve_engine.py
    from common import row


def mixed_reads(ref, *, n_short: int, n_long: int, short_len: int,
                long_len: int, seed: int):
    """Interleaved short(Illumina)/long(PacBio) mix, long reads sprinkled in."""
    shorts = simulate.simulate_reads(ref, n_reads=n_short, read_len=short_len,
                                     profile=simulate.ILLUMINA, seed=seed)
    longs = simulate.simulate_reads(ref, n_reads=n_long, read_len=long_len,
                                    profile=simulate.PACBIO_CLR, seed=seed + 1)
    reads = list(shorts.reads)
    stride = max(len(reads) // max(n_long, 1), 1)
    for i, r in enumerate(longs.reads):
        reads.insert(min((i + 1) * stride, len(reads)), r)
    return reads


def run_engine(index, reads, *, buckets, max_batch, max_delay_s, rate_rps,
               filter_k, warmup_reads, seed):
    cfg = EngineConfig(buckets=buckets, max_batch=max_batch,
                       max_delay_s=max_delay_s, filter_k=filter_k,
                       minimizer_w=8, minimizer_k=12)
    engine = ServeEngine(index, cfg)
    engine.map_all(warmup_reads)  # compile every bucket executor off-clock
    engine.metrics = Metrics()  # measured run starts from clean instruments
    engine.cache = ResultCache(cfg.cache_capacity)
    rep = poisson_load(engine, reads, rate_rps=rate_rps, seed=seed)
    m = rep.metrics
    useful, waste = m.get("bases_useful", 0.0), m.get("bases_padded_read", 0.0)
    summary = {
        "buckets": list(buckets),
        "n_reads": len(reads),
        "reads_per_s": round(rep.reads_per_s, 2),
        "p50_ms": round(rep.p50_ms, 3),
        "p99_ms": round(rep.p99_ms, 3),
        "batch_occupancy": round(m.get("batch_occupancy_mean", 0.0), 4),
        "pad_waste_frac": round(waste / max(useful + waste, 1.0), 4),
        "padded_bases_per_read": round(waste / max(len(reads), 1), 1),
        "cache_hit_rate": round(engine.cache.hit_rate, 4),
        "executors": engine.n_executors,
    }
    engine.close()
    return summary


def trace_and_attribute(index, reads, warmup, *, buckets, max_batch,
                        filter_k, trace_out=None, reps: int = 8):
    """Three-mode closed-loop pass → overheads + Amdahl ledger.

    Poisson runs are open-loop (rate-limited), so instrumentation
    overhead hides in idle time there; back-to-back ``map_all`` exposes
    it.  One warmed engine serves every rep in three modes — tracer off,
    tracer on, tracer + per-kernel roofline counters on (exactly the
    production switches: ``tracer.enabled`` / ``roofline.enabled``).
    The mode order reverses on alternate reps (ABBA) so slow drift
    cancels, and each overhead is the ratio of per-mode minima over
    ``reps`` reps: scheduler noise on this class of container is
    additive and bursty (a burst inflates one rep by 10-50%), so each
    leg's min is its cleanest observed run and the ratio of minima is
    robust unless a burst poisons *all* reps of a leg — which the rep
    count is sized to make unlikely.  (Per-rep paired ratios were
    tried and rejected: one burst on the off leg of a single rep
    deflates that rep's ratio by tens of percent, and min/median over
    ratios inherit that tail.)
    """
    tracer = Tracer()
    tracer.enabled = False  # warmup (compiles) stays out of the ledger
    # analytic counters only (measure=False: no cost_analysis compiles
    # on the overhead clock); enabled toggles per mode below
    roofline = RooflineManager(tracer=tracer, enabled=False, measure=False)
    # a generous deadline keeps every flush full: the flush count (the
    # dominant run-time term) is then deterministic across reps, which
    # a 2 ms deadline on a busy box cannot guarantee
    cfg = EngineConfig(buckets=buckets, max_batch=max_batch,
                       max_delay_s=0.25, filter_k=filter_k,
                       minimizer_w=8, minimizer_k=12, cache_capacity=0)
    loop_reads = list(reads) * 4  # longer window → percent-level signal
    times = {"off": [], "trace": [], "counters": []}
    with ServeEngine(index, cfg, tracer=tracer,
                     roofline=roofline) as engine:
        engine.map_all(warmup)  # compile off-clock
        def one(mode: str) -> None:
            tracer.enabled = mode != "off"
            roofline.enabled = mode == "counters"
            gc.collect()  # start every leg from the same heap state
            t0 = time.perf_counter()
            engine.map_all(loop_reads)
            times[mode].append(time.perf_counter() - t0)

        modes = ("off", "trace", "counters")
        # GC pauses otherwise land preferentially in the legs that
        # allocate most (spans + counter dicts), charging collector
        # scheduling — not instrumentation — to those modes
        gc.disable()
        try:
            for i in range(reps):  # ABBA ordering cancels slow drift
                for mode in (modes, modes[::-1])[i % 2]:  # between modes
                    one(mode)
        finally:
            gc.enable()
    report = build_ledger(tracer.log).report()
    print(render_report(report))
    if trace_out:
        tracer.log.export_chrome(trace_out)
        print(f"wrote {trace_out}")
    def overhead(mode: str) -> float:
        return round(min(times[mode]) / max(min(times["off"]), 1e-9)
                     - 1.0, 4)

    return {
        "untraced_s": round(min(times["off"]), 4),
        "traced_s": round(min(times["trace"]), 4),
        "counters_s": round(min(times["counters"]), 4),
        "trace_overhead_frac": overhead("trace"),
        "counter_overhead_frac": overhead("counters"),
        "roofline": roofline.report(measure=False),
        "attribution": report.to_dict(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small ref, short ladder)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write Perfetto/Chrome trace JSON here")
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate (reads/s)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.smoke:
        ref_len, n_short, n_long = 6_000, 40, 8
        short_len, long_len = 100, 300
        buckets, max_batch, rate = (128, 320), 8, args.rate or 400.0
    else:
        ref_len, n_short, n_long = 20_000, 112, 16
        short_len, long_len = 150, 1000
        buckets, max_batch, rate = (160, 320, 640, 1280), 16, args.rate or 100.0
    single_cap = (buckets[-1],)

    ref = simulate.random_reference(ref_len, seed=1)
    index = minimizer_index.build_epoched_index(ref, w=8, k=12)
    reads = mixed_reads(ref, n_short=n_short, n_long=n_long,
                        short_len=short_len, long_len=long_len, seed=2)
    warmup = mixed_reads(ref, n_short=2, n_long=2, short_len=short_len,
                         long_len=long_len, seed=99)
    common = dict(max_batch=max_batch, max_delay_s=0.005, rate_rps=rate,
                  filter_k=max(8, int(min(short_len, 128) * 0.05 * 1.5) + 4),
                  warmup_reads=warmup, seed=args.seed)

    out = {"mix": f"{n_short}x{short_len}bp+{n_long}x{long_len}bp",
           "rate_rps": rate}
    for name, bk in (("bucketed", buckets), ("single_cap", single_cap)):
        s = run_engine(index, reads, buckets=bk, **common)
        out[name] = s
        row(f"serve_engine_{name}", 1e6 / max(s["reads_per_s"], 1e-9),
            f"reads_per_s={s['reads_per_s']};p50_ms={s['p50_ms']};"
            f"p99_ms={s['p99_ms']};occupancy={s['batch_occupancy']};"
            f"pad_waste={s['pad_waste_frac']};"
            f"pad_bases_per_read={s['padded_bases_per_read']}")
    out["pad_waste_reduction"] = round(
        out["single_cap"]["padded_bases_per_read"]
        / max(out["bucketed"]["padded_bases_per_read"], 1e-9), 2)
    row("serve_engine_bucketing_win",
        0.0, f"padded_bases_per_read_reduction="
             f"{out['pad_waste_reduction']}x")

    tr = trace_and_attribute(
        index, reads, warmup, buckets=buckets, max_batch=max_batch,
        filter_k=common["filter_k"], trace_out=args.trace_out)
    out.update(tr)
    att = tr["attribution"]
    row("serve_engine_tracing", 0.0,
        f"overhead_frac={tr['trace_overhead_frac']};"
        f"counter_overhead_frac={tr['counter_overhead_frac']};"
        f"coverage={att['coverage']};"
        f"serial_fraction={att['serial_fraction']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
