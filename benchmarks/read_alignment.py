"""Paper Figs 4-8 / 4-9: read alignment throughput, GenASM vs DP baseline.

The paper compares the GenASM accelerator against the alignment kernels of
BWA-MEM/Minimap2 (affine-gap DP) and GACT.  Here both algorithms run on
identical hardware (this host / a TPU), so the measured ratio is the
*algorithmic* advantage of bitvector DC+TB over O(nm) DP — the paper's
"sources of improvement" §4.10.5 decomposition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.align import align_batch
from repro.core import dp_baseline
from repro.core.genasm import GenASMConfig
from repro.genomics import encode, simulate

from .common import row, timeit


def run(kind: str = "short", batch: int = 32):
    if kind == "short":
        read_len, p_cap, profile = 150, 256, simulate.ILLUMINA
    else:
        read_len, p_cap, profile = 1000, 1088, simulate.PACBIO_CLR
    ref = simulate.random_reference(20_000, seed=1)
    rs = simulate.simulate_reads(ref, n_reads=batch, read_len=read_len,
                                 profile=profile, seed=2)
    reads, lens = encode.batch_reads(rs.reads, p_cap)
    t_cap = p_cap + 192
    texts = np.stack([
        np.concatenate([ref, np.full(t_cap, 4, np.int8)])[p: p + t_cap]
        for p in rs.true_pos
    ])
    t_lens = np.full(batch, t_cap, np.int32)
    k = max(int(read_len * (profile.error_rate + 0.08)), 24)

    variants = [
        ("genasm", GenASMConfig(w=64, o=24, k=24)),  # paper-faithful
        ("genasm_opt", GenASMConfig(w=64, o=16, k=16, store_r=True)),  # §Perf
    ]
    aps_genasm = None
    for vname, cfg in variants:
        ga = jax.jit(lambda t, p, pl, tl, c=cfg: align_batch(t, p, pl, tl,
                                                             cfg=c,
                                                             backend="lax"))
        us = timeit(ga, jnp.asarray(texts), jnp.asarray(reads), jnp.asarray(lens),
                    jnp.asarray(t_lens))
        res = ga(jnp.asarray(texts), jnp.asarray(reads), jnp.asarray(lens),
                 jnp.asarray(t_lens))
        ok = int(np.sum(np.asarray(res.distance) >= 0))
        aps = batch / (us / 1e6)
        aps_genasm = aps_genasm or aps
        row(f"read_alignment_{kind}_{vname}", us / batch,
            f"aligns_per_s={aps:.0f};mapped={ok}/{batch}")

    dp = jax.jit(jax.vmap(lambda t, p, pl, tl: dp_baseline.affine_align_score(
        t, p, pl, tl)))
    us_dp = timeit(dp, jnp.asarray(texts), jnp.asarray(reads), jnp.asarray(lens),
                   jnp.asarray(t_lens))
    aps_dp = batch / (us_dp / 1e6)
    row(f"read_alignment_{kind}_dp_baseline", us_dp / batch,
        f"aligns_per_s={aps_dp:.0f};genasm_speedup={aps_genasm / aps_dp:.2f}x")


def main():
    run("short")
    run("long", batch=8)


if __name__ == "__main__":
    main()
