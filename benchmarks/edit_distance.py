"""Paper Fig 4-13 / §4.10.4: edit distance calculation vs the Edlib
baseline (Myers' bitvector algorithm), across lengths and similarities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.edit_distance import genasm_distance_batch
from repro.core.genasm import GenASMConfig
from repro.core.myers import myers_distance
from repro.genomics import simulate

from .common import row, timeit


def run(length: int = 1000, similarity: float = 0.95, batch: int = 8):
    rng = np.random.default_rng(7)
    err = 1 - similarity
    prof = simulate.ErrorProfile("x", err, 0.4, 0.3, 0.3)
    p_cap = length + 64
    a = np.full((batch, p_cap), 4, np.int8)
    b = np.full((batch, p_cap + 128), 4, np.int8)
    a_lens = np.zeros(batch, np.int32)
    b_lens = np.zeros(batch, np.int32)
    for i in range(batch):
        s = rng.integers(0, 4, size=length).astype(np.int8)
        t = simulate.mutate(s, prof, rng)
        a[i, : len(s)] = s
        b[i, : len(t)] = t[: b.shape[1]]
        a_lens[i], b_lens[i] = len(s), min(len(t), b.shape[1])

    cfg = GenASMConfig(w=64, o=24, k=24)
    g = jax.jit(lambda aa, bb, al, bl: genasm_distance_batch(bb, aa, bl, al)
                if False else genasm_distance_batch(aa, bb, al, bl, cfg=cfg))
    us = timeit(g, jnp.asarray(a), jnp.asarray(b), jnp.asarray(a_lens),
                jnp.asarray(b_lens))
    d = np.asarray(g(jnp.asarray(a), jnp.asarray(b), jnp.asarray(a_lens),
                     jnp.asarray(b_lens)))
    row(f"edit_distance_genasm_L{length}_s{int(similarity * 100)}", us / batch,
        f"pairs_per_s={batch / (us / 1e6):.1f};mean_dist={d.mean():.1f}")

    m_bits = ((length + 63) // 64) * 64
    my = jax.jit(jax.vmap(lambda bb, aa, al: myers_distance(
        bb, aa[:m_bits], al, m_bits=m_bits, mode="semiglobal")))
    us_m = timeit(my, jnp.asarray(b), jnp.asarray(a), jnp.asarray(a_lens))
    dm = np.asarray(my(jnp.asarray(b), jnp.asarray(a), jnp.asarray(a_lens)))
    row(f"edit_distance_myers_L{length}_s{int(similarity * 100)}", us_m / batch,
        f"pairs_per_s={batch / (us_m / 1e6):.1f};mean_dist={dm.mean():.1f}")


def main():
    run(1000, 0.95)
    run(1000, 0.80)
    run(5000, 0.95, batch=4)


if __name__ == "__main__":
    main()
