"""Paper Fig 6-15: sequence-to-graph alignment, BitAlign vs DP (PaSGAL
stand-in: the same graph DP PaSGAL computes, vectorized in numpy)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oracle import graph_edit_distance
from repro.core.segram import bitalign, graph
from repro.genomics import simulate

from .common import profile_read_patterns, row, timeit, variant_graph


def run(n_nodes: int = 512, read_len: int = 96, batch: int = 8):
    g, ref = variant_graph(n_nodes, seed=11, n_snp=8, n_ins=4, n_del=4,
                           ref_margin=24, variant_seed=3)
    m_bits = ((read_len + 63) // 64) * 64
    pats, plens = profile_read_patterns(ref, batch, read_len, m_bits,
                                        profile=simulate.ILLUMINA, seed=11)

    bases = jnp.asarray(g.bases)
    succ = jnp.asarray(g.succ_bits)
    f = jax.jit(jax.vmap(lambda p, pl: bitalign.bitalign_dc(
        bases, succ, p, pl, m_bits=m_bits, k=16)[0].min()))
    us = timeit(f, jnp.asarray(pats), jnp.asarray(plens))
    d = np.asarray(f(jnp.asarray(pats), jnp.asarray(plens)))
    row(f"bitalign_N{n_nodes}_m{read_len}", us / batch,
        f"aligns_per_s={batch / (us / 1e6):.1f};mean_dist={d.mean():.1f}")

    # PaSGAL stand-in: graph DP (numpy, host) — one alignment
    preds = graph.predecessors(g)
    t0 = time.perf_counter()
    dd = graph_edit_distance(pats[0][:read_len], g.bases, preds)
    dp_us = (time.perf_counter() - t0) * 1e6
    row(f"bitalign_dp_baseline_N{n_nodes}_m{read_len}", dp_us,
        f"aligns_per_s={1e6 / dp_us:.1f};dist={dd};bitalign_speedup={dp_us / (us / batch):.1f}x")


def main():
    run()


if __name__ == "__main__":
    main()
