"""Paper §4.10.3: pre-alignment filtering — throughput + false-accept rate.

GenASM-DC computes the exact distance, so its false-accept rate is ~0 by
construction; the baseline is a Shouji-style q-gram counting filter
(approximate), which accepts dissimilar pairs at a measurable rate.  Both
run in JAX on identical hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as gfilter
from repro.core import oracle
from repro.genomics import simulate

from .common import row, timeit


def qgram_filter(texts, reads, q: int = 4, k: int = 5):
    """Shouji-like approximate filter: shared q-gram count lower-bounds
    the edit distance; accept if deficit <= q*k."""
    def qcount(s):
        n = s.shape[-1]
        idx = jnp.arange(n - q + 1)[:, None] + jnp.arange(q)[None, :]
        codes = jnp.sum(s[..., idx] * (5 ** jnp.arange(q)), axis=-1)
        return codes

    tq = qcount(texts)
    rq = qcount(reads)
    # shared q-grams (multiset intersection approximated via sorted match)
    def shared(a, b):
        a = jnp.sort(a)
        b = jnp.sort(b)
        return jnp.sum(jnp.isin(b, a))

    sh = jax.vmap(shared)(tq, rq)
    deficit = (reads.shape[-1] - 4 + 1) - sh
    return deficit <= q * k


def run(read_len: int = 100, k: int = 5, batch: int = 256):
    rng = np.random.default_rng(5)
    m_bits = 128 if read_len <= 100 else 256
    n = m_bits + 2 * k + 16
    texts = np.full((batch, n), 4, np.int8)
    reads = np.full((batch, m_bits), 4, np.int8)
    truth = np.zeros(batch, bool)
    for i in range(batch):
        r = rng.integers(0, 4, size=read_len).astype(np.int8)
        if i % 2 == 0:  # similar pair
            t = simulate.mutate(r, simulate.ErrorProfile("x", k / read_len / 2,
                                                         .5, .25, .25), rng)
        else:  # dissimilar pair
            t = rng.integers(0, 4, size=read_len + 2 * k).astype(np.int8)
        texts[i, : min(len(t), n)] = t[:n]
        reads[i, :read_len] = r
        truth[i] = oracle.levenshtein_prefix(r, t) <= k

    f = jax.jit(lambda t, r: gfilter.filter_candidates(t, r, None, m_bits=m_bits,
                                                       k=k))
    us = timeit(f, jnp.asarray(texts), jnp.asarray(reads))
    accept, dist = f(jnp.asarray(texts), jnp.asarray(reads))
    accept = np.asarray(accept)
    fa = np.sum(accept & ~truth) / max(np.sum(~truth), 1)
    fr = np.sum(~accept & truth) / max(np.sum(truth), 1)
    row(f"prealign_filter_genasm_{read_len}", us / batch,
        f"pairs_per_s={batch / (us / 1e6):.0f};false_accept={fa:.4f};false_reject={fr:.4f}")

    qf = jax.jit(lambda t, r: qgram_filter(t[:, :m_bits], r, k=k))
    us_q = timeit(qf, jnp.asarray(texts), jnp.asarray(reads))
    acc_q = np.asarray(qf(jnp.asarray(texts), jnp.asarray(reads)))
    fa_q = np.sum(acc_q & ~truth) / max(np.sum(~truth), 1)
    row(f"prealign_filter_qgram_{read_len}", us_q / batch,
        f"pairs_per_s={batch / (us_q / 1e6):.0f};false_accept={fa_q:.4f}")


def main():
    run(100, 5)
    run(250, 15)


if __name__ == "__main__":
    main()
