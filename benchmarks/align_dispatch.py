"""`repro.align` dispatch benchmark: the same batch through every backend.

Measures `align_batch` wall time per alignment for each registered
backend on one shared, seeded input set (`repro.align.inputs`, the same
generators the conformance suite checks), plus the dispatch layer's
block-size autotune.  On CPU the Pallas rows run in interpret mode —
the interesting CPU comparison is `lax` vs `ref`; on TPU/GPU the
`pallas_dc*` rows are the paper's accelerator claim.

    PYTHONPATH=src python -m benchmarks.run align_dispatch
    PYTHONPATH=src python benchmarks/align_dispatch.py --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import align
from repro.core.genasm import GenASMConfig

try:
    from .common import aligned_read_batch, row, timeit
except ImportError:  # script-style: python benchmarks/align_dispatch.py
    from common import aligned_read_batch, row, timeit


def run(*, batch: int, read_len: int, backends=None, iters: int = 3):
    cfg = GenASMConfig()
    texts, pats, p_lens, t_lens = aligned_read_batch(
        batch, read_len, t_extra=2 * cfg.w, seed=29)
    p_cap = pats.shape[1]
    args = (jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
            jnp.asarray(t_lens))
    backends = backends or align.available_backends()
    out = {"batch": batch, "read_len": read_len, "p_cap": p_cap,
           "platform": jax.default_backend(), "backends": {}}
    base_us = None
    for name in backends:
        fn = jax.jit(lambda t, p, pl, tl, _b=name: align.align_batch(
            t, p, pl, tl, cfg=cfg, backend=_b, p_cap=p_cap))
        us = timeit(fn, *args, iters=iters)
        res = fn(*args)
        dist = np.asarray(res.distance)
        if name == "lax":
            base_us = us
        out["backends"][name] = {
            "us_per_align": round(us / batch, 2),
            "aligns_per_s": round(batch / (us / 1e6), 1),
            "mean_distance": round(float(dist[dist >= 0].mean()), 2),
        }
        row(f"align_dispatch_{name}", us / batch,
            f"aligns_per_s={batch / (us / 1e6):.0f};"
            f"interpret={align.needs_interpret()}")
    if base_us is not None:
        for name, s in out["backends"].items():
            s["speedup_vs_lax"] = round(base_us / (s["us_per_align"] * batch),
                                        3)
    # autotune: exercise the cache path and report the chosen tile
    bt = align.autotune("pallas_dc", p_cap, cfg.k, batch=batch,
                        candidates=(16, 64, 128), cfg=cfg)
    out["autotuned_block_bt"] = bt
    row("align_dispatch_autotune_block", 0.0,
        f"block_bt={bt};key=({p_cap},{cfg.k})")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small batch, short reads)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        out = run(batch=16, read_len=100, iters=2)
    else:
        out = run(batch=64, read_len=150)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
