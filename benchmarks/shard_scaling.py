"""Reference-sharding throughput: reads/s vs 1/2/4 host-platform shards.

Measures bucket-executor mapping throughput (engine admission excluded)
at two operating points:

* **filter-dominated** (the default/top-level numbers) — a large
  per-read candidate budget, the high-sensitivity regime the paper's
  GenASM-DC pre-alignment filter exists for (§4.10.3: many candidate
  locations per read).  The scatter stage strong-scales with shards.
* **align-dominated** (``align_point``) — long reads at a long bucket
  cap with a small candidate budget, where the winning-window align
  stage is most of the batch and the old single-device align was the
  Amdahl floor.

Each sharded row reports four modes so the win decomposes:

* ``reads_per_s_host_merge`` — the pre-device-merge path (per-shard
  winners synced to the host, lexicographic merge in numpy, align
  re-dispatched): the historical Amdahl floor.
* ``reads_per_s`` — packed-key argmin merge on device (winners never
  visit the host between scatter and align).
* ``reads_per_s_align_sharded`` — device merge plus the align stage
  mesh-split over the same shards.
* ``reads_per_s_pipelined`` — device merge + sharded align dispatched
  through ``start``/``finish`` double-buffering, batch i's align
  overlapping batch i+1's scatter.

Needs ``jax.device_count() >= 4``; when launched with fewer devices it
re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (XLA fixes the
device count at first backend use, so an in-process flag flip cannot
work from the combined harness).

    PYTHONPATH=src python benchmarks/shard_scaling.py            # full
    PYTHONPATH=src python benchmarks/shard_scaling.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

try:
    from .common import row
except ImportError:  # script-style: python benchmarks/shard_scaling.py
    from common import row

SHARD_COUNTS = (1, 2, 4)


def _measure(*, ref_len, n_reads, read_len, p_cap, candidates, reps, seed):
    """Time single-device vs sharded mapping on one seeded read batch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import shard
    from repro.core import mapper, minimizer_index
    from repro.core.genasm import GenASMConfig
    from repro.genomics import encode, simulate

    cfg = GenASMConfig()
    common = dict(p_cap=p_cap, filter_bits=128, filter_k=12)
    ref = simulate.random_reference(ref_len, seed=seed)
    rs = simulate.simulate_reads(ref, n_reads=n_reads, read_len=read_len,
                                 profile=simulate.ILLUMINA, seed=seed + 1)
    arr, lens = encode.batch_reads(list(rs.reads), p_cap)
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)

    def timed(fn, ex):
        """Average batch time + per-stage seconds from ``ex.last_times``."""
        res = fn()  # compile + warm
        stages: dict[str, float] = {}
        t0 = time.perf_counter()
        for _ in range(reps):
            res = fn()
            for name, a, b, _attrs in getattr(ex, "last_times", ()):
                stages[name] = stages.get(name, 0.0) + (b - a)
        dt = (time.perf_counter() - t0) / reps
        return res, dt, {k: round(v / reps, 5) for k, v in stages.items()}

    out = {}
    for s in SHARD_COUNTS:
        if s == 1:
            jarr, jlens = jnp.asarray(arr), jnp.asarray(lens)
            # the serve path's two-stage executor (same math as a fused
            # map_batch jit) so the 1-shard row reports its
            # seed_filter/align split alongside the sharded rows'
            ex = mapper.LinearMapExecutor(
                cfg=cfg, max_candidates=candidates,
                minimizer_w=8, minimizer_k=12, backend="lax", **common)

            def call(ex=ex):
                return jax.tree_util.tree_map(
                    np.asarray, ex(epi.index, jarr, jlens))

            res, dt, stages = timed(call, ex)
            out["1"] = {
                "reads_per_s": round(n_reads / dt, 2),
                "ms_per_batch": round(dt * 1e3, 2),
                "mapped": int((res.position >= 0).sum()),
                "spmd": False,
                "stages": stages,
            }
            continue

        esi = shard.from_epoched(epi, s)
        arrays = esi.index.arrays
        kw = dict(cfg=cfg, shard_candidates=max(1, candidates // s),
                  backend="lax", **common)
        ex = shard.ShardedMapExecutor(esi.index, **kw)
        res, dt, stages = timed(lambda: ex(arrays, arr, lens), ex)

        # the pre-device-merge reference: per-shard winners synced to
        # the host, numpy lexicographic merge, align re-dispatched —
        # what the serve path did before the packed-key argmin
        def host_call():
            st = ex.stage(arrays, arr, lens)
            fd, pos, text, t_len, _win = ex.merge_host(st)
            r = ex._align(jnp.asarray(text), jnp.asarray(arr),
                          jnp.asarray(lens, jnp.int32),
                          jnp.asarray(t_len), jnp.asarray(pos),
                          jnp.asarray(fd))
            return jax.tree_util.tree_map(np.asarray, r)

        res_host, dt_host, _ = timed(host_call, None)

        ex_as = shard.ShardedMapExecutor(esi.index, align_sharded=True,
                                         **kw)
        res_as, dt_as, _ = timed(lambda: ex_as(arrays, arr, lens), ex_as)

        # double-buffered stream: batch i's align overlaps batch i+1's
        # scatter (the serve engine's pipelined mode, minus admission)
        t0 = time.perf_counter()
        pending = ex_as.start(arrays, arr, lens, timed=False)
        for _ in range(reps - 1):
            nxt = ex_as.start(arrays, arr, lens, timed=False)
            ex_as.finish(pending)
            pending = nxt
        res_pipe = ex_as.finish(pending)[0]
        dt_pipe = (time.perf_counter() - t0) / reps

        for r in (res_host, res_as, res_pipe):  # modes are re-schedulings
            assert (np.asarray(r.position) == np.asarray(res.position)).all()

        out[str(s)] = {
            "reads_per_s": round(n_reads / dt, 2),
            "reads_per_s_host_merge": round(n_reads / dt_host, 2),
            "reads_per_s_align_sharded": round(n_reads / dt_as, 2),
            "reads_per_s_pipelined": round(n_reads / dt_pipe, 2),
            "ms_per_batch": round(dt * 1e3, 2),
            "mapped": int((res.position >= 0).sum()),
            "spmd": bool(jax.device_count() >= s),
            "stages": stages,  # avg s/batch: scatter strong-scales,
        }                      # merge+align are the Amdahl floor
    base = out["1"]["reads_per_s"]
    return {
        "ref_len": ref_len, "n_reads": n_reads, "read_len": read_len,
        "p_cap": p_cap, "candidates": candidates, "reps": reps,
        "seed": seed, "devices": jax.device_count(),
        "shards": out,
        "speedup_2shards_vs_1": round(
            out["2"]["reads_per_s"] / base, 3),
        "speedup_4shards_vs_1": round(
            out["4"]["reads_per_s"] / base, 3),
        "speedup_4shards_pipelined_vs_1": round(
            out["4"]["reads_per_s_pipelined"] / base, 3),
        "device_merge_win_4shards": round(
            out["4"]["reads_per_s"] / out["4"]["reads_per_s_host_merge"],
            3),
        "pipeline_win_4shards": round(
            out["4"]["reads_per_s_pipelined"] / out["4"]["reads_per_s"],
            3),
    }


def _needs_respawn() -> bool:
    import jax

    return jax.device_count() < max(SHARD_COUNTS)


def _respawn(argv, json_path) -> dict:
    """Re-exec with forced host devices; the child writes the JSON."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{max(SHARD_COUNTS)}").strip()
    cmd = [sys.executable, os.path.abspath(__file__),
           *argv, "--json", json_path, "--_no-respawn"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard_scaling worker failed:\n{proc.stderr[-2000:]}")
    with open(json_path) as f:
        return json.load(f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller reference, fewer reps)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--_no-respawn", dest="no_respawn", action="store_true",
                    help=argparse.SUPPRESS)  # internal: already re-execed
    args = ap.parse_args(argv)

    if args.smoke:
        params = dict(ref_len=160_000, n_reads=32, read_len=100, p_cap=128,
                      candidates=64, reps=4)
        align_params = dict(ref_len=120_000, n_reads=8, read_len=350,
                            p_cap=384, candidates=8, reps=2)
    else:
        params = dict(ref_len=1_000_000, n_reads=64, read_len=100, p_cap=128,
                      candidates=64, reps=8)
        align_params = dict(ref_len=400_000, n_reads=16, read_len=450,
                            p_cap=512, candidates=8, reps=4)

    if not args.no_respawn and _needs_respawn():
        import tempfile

        json_path = args.json
        if json_path is None:
            fd, json_path = tempfile.mkstemp(suffix="_shard_scaling.json")
            os.close(fd)
        child_argv = (["--smoke"] if args.smoke else []) \
            + ["--seed", str(args.seed)]
        try:
            out = _respawn(child_argv, json_path)
        finally:
            if args.json is None:
                os.unlink(json_path)
    else:
        out = _measure(seed=args.seed, **params)
        # align-dominated point: long reads/caps, small candidate
        # budget — where the sharded/pipelined align stage must win
        out["align_point"] = _measure(seed=args.seed + 1, **align_params)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(out, f, indent=2)
            print(f"wrote {args.json}")

    base = out["shards"]["1"]["reads_per_s"]
    for s in SHARD_COUNTS:
        r = out["shards"][str(s)]
        row(f"shard_scaling_s{s}", 1e6 / max(r["reads_per_s"], 1e-9),
            f"reads_per_s={r['reads_per_s']};mapped={r['mapped']}/"
            f"{out['n_reads']};speedup={r['reads_per_s'] / base:.2f}x;"
            f"spmd={r['spmd']}")
    row("shard_scaling_speedup", 0.0,
        f"4shards_vs_1={out['speedup_4shards_vs_1']}x;"
        f"2shards_vs_1={out['speedup_2shards_vs_1']}x;"
        f"pipelined_4_vs_1={out['speedup_4shards_pipelined_vs_1']}x")
    row("shard_scaling_merge", 0.0,
        f"device_merge_win_4shards={out['device_merge_win_4shards']}x;"
        f"pipeline_win_4shards={out['pipeline_win_4shards']}x")
    ap4 = out["align_point"]["shards"]["4"]
    row("shard_scaling_align_point", 0.0,
        f"reads_per_s={ap4['reads_per_s']};"
        f"align_sharded={ap4['reads_per_s_align_sharded']};"
        f"pipelined={ap4['reads_per_s_pipelined']};"
        f"host_merge={ap4['reads_per_s_host_merge']}")
    return out


if __name__ == "__main__":
    main()
