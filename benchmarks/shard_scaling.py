"""Reference-sharding throughput: reads/s vs 1/2/4 host-platform shards.

Measures bucket-executor mapping throughput (engine admission excluded)
at a *filter-dominated* operating point — a large per-read candidate
budget, the high-sensitivity regime the paper's GenASM-DC pre-alignment
filter exists for (§4.10.3: many candidate locations per read).  At 1
shard the whole seed/vote/filter stage serializes on one device; at N
shards each device filters ``candidates / N`` of the budget over its
slice of the reference in parallel (``shard_map`` scatter), the host
merges winners, and one batched align call finishes — so the filter
stage strong-scales while the align stage is the Amdahl floor (sharded
and single paths run the identical align program).

Needs ``jax.device_count() >= 4``; when launched with fewer devices it
re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (XLA fixes the
device count at first backend use, so an in-process flag flip cannot
work from the combined harness).

    PYTHONPATH=src python benchmarks/shard_scaling.py            # full
    PYTHONPATH=src python benchmarks/shard_scaling.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

try:
    from .common import row
except ImportError:  # script-style: python benchmarks/shard_scaling.py
    from common import row

SHARD_COUNTS = (1, 2, 4)


def _measure(*, ref_len, n_reads, read_len, p_cap, candidates, reps, seed):
    """Time single-device vs sharded mapping on one seeded read batch."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import shard
    from repro.core import mapper, minimizer_index
    from repro.core.genasm import GenASMConfig
    from repro.genomics import encode, simulate

    cfg = GenASMConfig()
    common = dict(p_cap=p_cap, filter_bits=128, filter_k=12)
    ref = simulate.random_reference(ref_len, seed=seed)
    rs = simulate.simulate_reads(ref, n_reads=n_reads, read_len=read_len,
                                 profile=simulate.ILLUMINA, seed=seed + 1)
    arr, lens = encode.batch_reads(list(rs.reads), p_cap)
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)

    def timed(fn, ex):
        """Average batch time + per-stage seconds from ``ex.last_times``."""
        res = fn()  # compile + warm
        stages: dict[str, float] = {}
        t0 = time.perf_counter()
        for _ in range(reps):
            res = fn()
            for name, a, b, _attrs in getattr(ex, "last_times", ()):
                stages[name] = stages.get(name, 0.0) + (b - a)
        dt = (time.perf_counter() - t0) / reps
        return res, dt, {k: round(v / reps, 5) for k, v in stages.items()}

    out = {}
    for s in SHARD_COUNTS:
        if s == 1:
            jarr, jlens = jnp.asarray(arr), jnp.asarray(lens)
            # the serve path's two-stage executor (same math as a fused
            # map_batch jit) so the 1-shard row reports its
            # seed_filter/align split alongside the sharded rows'
            ex = mapper.LinearMapExecutor(
                cfg=cfg, max_candidates=candidates,
                minimizer_w=8, minimizer_k=12, backend="lax", **common)

            def call(ex=ex):
                return jax.tree_util.tree_map(
                    np.asarray, ex(epi.index, jarr, jlens))
        else:
            esi = shard.from_epoched(epi, s)
            ex = shard.ShardedMapExecutor(
                esi.index, cfg=cfg,
                shard_candidates=max(1, candidates // s),
                backend="lax", **common)
            arrays = esi.index.arrays

            def call(ex=ex, arrays=arrays):
                return ex(arrays, arr, lens)

        res, dt, stages = timed(call, ex)
        out[str(s)] = {
            "reads_per_s": round(n_reads / dt, 2),
            "ms_per_batch": round(dt * 1e3, 2),
            "mapped": int((res.position >= 0).sum()),
            "spmd": bool(s > 1 and jax.device_count() >= s),
            "stages": stages,  # avg s/batch: scatter strong-scales,
        }                      # merge+align are the Amdahl floor
    return {
        "ref_len": ref_len, "n_reads": n_reads, "read_len": read_len,
        "p_cap": p_cap, "candidates": candidates, "reps": reps,
        "seed": seed, "devices": jax.device_count(),
        "shards": out,
        "speedup_2shards_vs_1": round(
            out["2"]["reads_per_s"] / out["1"]["reads_per_s"], 3),
        "speedup_4shards_vs_1": round(
            out["4"]["reads_per_s"] / out["1"]["reads_per_s"], 3),
    }


def _needs_respawn() -> bool:
    import jax

    return jax.device_count() < max(SHARD_COUNTS)


def _respawn(argv, json_path) -> dict:
    """Re-exec with forced host devices; the child writes the JSON."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{max(SHARD_COUNTS)}").strip()
    cmd = [sys.executable, os.path.abspath(__file__),
           *argv, "--json", json_path, "--_no-respawn"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"shard_scaling worker failed:\n{proc.stderr[-2000:]}")
    with open(json_path) as f:
        return json.load(f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller reference, fewer reps)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--_no-respawn", dest="no_respawn", action="store_true",
                    help=argparse.SUPPRESS)  # internal: already re-execed
    args = ap.parse_args(argv)

    if args.smoke:
        params = dict(ref_len=160_000, n_reads=32, read_len=100, p_cap=128,
                      candidates=64, reps=4)
    else:
        params = dict(ref_len=1_000_000, n_reads=64, read_len=100, p_cap=128,
                      candidates=64, reps=8)

    if not args.no_respawn and _needs_respawn():
        import tempfile

        json_path = args.json
        if json_path is None:
            fd, json_path = tempfile.mkstemp(suffix="_shard_scaling.json")
            os.close(fd)
        child_argv = (["--smoke"] if args.smoke else []) \
            + ["--seed", str(args.seed)]
        try:
            out = _respawn(child_argv, json_path)
        finally:
            if args.json is None:
                os.unlink(json_path)
    else:
        out = _measure(seed=args.seed, **params)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(out, f, indent=2)
            print(f"wrote {args.json}")

    base = out["shards"]["1"]["reads_per_s"]
    for s in SHARD_COUNTS:
        r = out["shards"][str(s)]
        row(f"shard_scaling_s{s}", 1e6 / max(r["reads_per_s"], 1e-9),
            f"reads_per_s={r['reads_per_s']};mapped={r['mapped']}/"
            f"{out['n_reads']};speedup={r['reads_per_s'] / base:.2f}x;"
            f"spmd={r['spmd']}")
    row("shard_scaling_speedup", 0.0,
        f"4shards_vs_1={out['speedup_4shards_vs_1']}x;"
        f"2shards_vs_1={out['speedup_2shards_vs_1']}x")
    return out


if __name__ == "__main__":
    main()
