"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  read_alignment   — Figs 4-8/4-9 (GenASM vs DP alignment kernels)
  prealign_filter  — §4.10.3 (GenASM-DC filter vs q-gram approx, accuracy)
  edit_distance    — Fig 4-13 (GenASM vs Myers/Edlib)
  bitalign         — Fig 6-15 (BitAlign vs graph-DP / PaSGAL stand-in)
  segram_e2e       — Figs 6-11..6-14 (SeGraM end-to-end mapping)
  kernel_dc        — Ch. 5 BitMAc kernel analysis
  serve_engine     — micro-batching engine under Poisson arrivals
  roofline         — §Roofline table from the multi-pod dry-run
"""
from __future__ import annotations

import inspect
import sys


def main() -> None:
    from . import (bitalign, edit_distance, kernel_dc, prealign_filter,
                   read_alignment, roofline, segram_e2e, serve_engine)

    mods = {
        "read_alignment": read_alignment,
        "prealign_filter": prealign_filter,
        "edit_distance": edit_distance,
        "bitalign": bitalign,
        "segram_e2e": segram_e2e,
        "kernel_dc": kernel_dc,
        "serve_engine": serve_engine,
        "roofline": roofline,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        try:
            # modules with an argv parameter parse CLI flags; hand them an
            # empty argv so the harness's own argument doesn't reach argparse
            if "argv" in inspect.signature(mod.main).parameters:
                mod.main([])
            else:
                mod.main()
        except Exception as e:  # keep the harness running
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
