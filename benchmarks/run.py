"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  read_alignment   — Figs 4-8/4-9 (GenASM vs DP alignment kernels)
  prealign_filter  — §4.10.3 (GenASM-DC filter vs q-gram approx, accuracy)
  edit_distance    — Fig 4-13 (GenASM vs Myers/Edlib)
  bitalign         — Fig 6-15 (BitAlign vs graph-DP / PaSGAL stand-in)
  segram_e2e       — Figs 6-11..6-14 (SeGraM mapping on repro.graph)
  graph_serve      — graph vs linear serving throughput (Poisson)
  kernel_dc        — Ch. 5 BitMAc kernel analysis
  align_dispatch   — repro.align backend dispatch (lax vs pallas_dc*)
  serve_engine     — micro-batching engine under Poisson arrivals
  shard_scaling    — reads/s vs 1/2/4 reference shards (repro.shard)
  roofline         — per-kernel predicted-vs-measured roofline table
                     (§Roofline: all align backends × bucket caps)

``--smoke`` runs the CI-sized subset (align_dispatch + serve_engine +
segram_e2e + graph_serve + shard_scaling + roofline) and ``--json PATH``
writes
their summaries into one artifact; the serving modules also emit their
per-stage Amdahl attribution (`repro.obs`) into the summary and, under
``--smoke``, Perfetto traces (``trace_serve_engine.json``,
``trace_graph_serve_{linear,graph}.json`` — CI uploads them):

    PYTHONPATH=src python benchmarks/run.py --smoke --json bench_summary.json
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys

if __package__ in (None, ""):  # script-style: python benchmarks/run.py
    import pathlib

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    __package__ = "benchmarks"

# modules with a --smoke flag and a summary-dict return (the CI subset)
SMOKE_MODS = ("align_dispatch", "serve_engine", "segram_e2e", "graph_serve",
              "shard_scaling", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module by name")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset (align_dispatch + serve_engine)")
    ap.add_argument("--json", default=None,
                    help="write collected module summaries here")
    args = ap.parse_args(argv)

    from . import (align_dispatch, bitalign, edit_distance, graph_serve,
                   kernel_dc, prealign_filter, read_alignment, roofline,
                   segram_e2e, serve_engine, shard_scaling)

    mods = {
        "read_alignment": read_alignment,
        "prealign_filter": prealign_filter,
        "edit_distance": edit_distance,
        "bitalign": bitalign,
        "segram_e2e": segram_e2e,
        "graph_serve": graph_serve,
        "kernel_dc": kernel_dc,
        "align_dispatch": align_dispatch,
        "serve_engine": serve_engine,
        "shard_scaling": shard_scaling,
        "roofline": roofline,
    }
    summaries: dict[str, object] = {}
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if args.only and name != args.only:
            continue
        if args.smoke and name not in SMOKE_MODS:
            continue
        try:
            # modules with an argv parameter parse CLI flags; hand them an
            # empty argv so the harness's own arguments don't reach argparse
            if "argv" in inspect.signature(mod.main).parameters:
                sub = ["--smoke"] if args.smoke and name in SMOKE_MODS \
                    else []
                if args.smoke and name in ("serve_engine", "graph_serve"):
                    # smoke artifacts: Perfetto traces next to the JSON
                    sub += ["--trace-out", f"trace_{name}.json"]
                if args.smoke and name == "roofline":
                    # standalone table artifact (CI uploads it)
                    sub += ["--json", "roofline_table.json"]
                out = mod.main(sub)
            else:
                out = mod.main()
            if isinstance(out, dict):
                summaries[name] = out
        except Exception as e:  # keep the harness running
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            summaries[name] = {"error": f"{type(e).__name__}: {e}"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summaries, f, indent=2)
        print(f"wrote {args.json}")
    errors = [n for n, s in summaries.items()
              if isinstance(s, dict) and "error" in s]
    if args.smoke and errors:
        # the CI smoke step must fail the build, not ship an error artifact
        sys.exit(f"smoke benchmark(s) failed: {', '.join(errors)}")


if __name__ == "__main__":
    main()
