"""Paper Figs 6-11..6-14: SeGraM end-to-end sequence-to-graph mapping
throughput (reads/s), short and long-ish reads."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.segram import graph, segram
from repro.genomics import encode, simulate

from .common import row, timeit


def run(kind: str = "short", batch: int = 16):
    ref_len = 8000
    ref = simulate.random_reference(ref_len, seed=21)
    variants = simulate.simulate_variants(ref, n_snp=24, n_ins=8, n_del=8, seed=4)
    g = graph.build_graph(ref, variants)
    idx = segram.preprocess(ref, g, w=8, k=12)
    if kind == "short":
        read_len, m_bits, win = 100, 128, 192
        prof = simulate.ILLUMINA
    else:
        read_len, m_bits, win = 400, 448, 576
        prof = simulate.PACBIO_CLR
    rs = simulate.simulate_reads(ref, n_reads=batch, read_len=read_len,
                                 profile=prof, seed=5)
    reads, lens = encode.batch_reads(rs.reads, m_bits)
    k = max(24, int(read_len * (prof.error_rate + 0.05)))
    k = min(k, 64)

    f = jax.jit(lambda r, l: segram.map_batch(
        idx, r, l, m_bits=m_bits, k=k, win_len=win, minimizer_w=8,
        minimizer_k=12))
    us = timeit(f, jnp.asarray(reads), jnp.asarray(lens))
    out = f(jnp.asarray(reads), jnp.asarray(lens))
    mapped = int(np.sum(~np.asarray(out["failed"])))
    row(f"segram_e2e_{kind}", us / batch,
        f"reads_per_s={batch / (us / 1e6):.1f};mapped={mapped}/{batch}")


def main():
    run("short")
    run("long", batch=8)


if __name__ == "__main__":
    main()
