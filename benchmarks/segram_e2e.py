"""Paper Figs 6-11..6-14: SeGraM end-to-end sequence-to-graph mapping
throughput (reads/s), short and long-ish reads.

Ported onto the `repro.graph` subsystem (PR 4): tiled graph index +
`graph.mapper.map_batch` through the `repro.align` dispatch — the same
path the serve engine compiles — instead of the old per-read vmap of
per-candidate whole-window scans in `core/segram/segram.py`.

    PYTHONPATH=src python benchmarks/segram_e2e.py            # full
    PYTHONPATH=src python benchmarks/segram_e2e.py --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core.genasm import GenASMConfig
from repro.graph import index as gindex
from repro.graph import mapper as gmapper
from repro.genomics import encode, simulate

try:
    from .common import row, timeit
except ImportError:  # script-style: python benchmarks/segram_e2e.py
    from common import row, timeit


def run(kind: str = "short", batch: int = 16, *, ref_len: int = 8000,
        backend: str | None = None):
    ref = simulate.random_reference(ref_len, seed=21)
    variants = simulate.simulate_variants(
        ref, n_snp=ref_len // 333, n_ins=ref_len // 1000,
        n_del=ref_len // 1000, seed=4)
    cfg = GenASMConfig()
    if kind == "short":
        read_len, p_cap = 100, 128
        prof = simulate.ILLUMINA
    else:
        read_len, p_cap = 400, 448
        prof = simulate.PACBIO_CLR
    idx = gindex.build_graph_index(ref, variants, w=8, k=12,
                                   window=p_cap + 2 * cfg.w)
    rs = simulate.simulate_reads(ref, n_reads=batch, read_len=read_len,
                                 profile=prof, seed=5)
    reads, lens = encode.batch_reads(rs.reads, p_cap)
    filter_k = max(12, int(128 * (prof.error_rate + 0.05)))

    be = gmapper.graph_backend_name(backend)

    # map_batch is host-orchestrated (prefilter → rung sync → DC → align),
    # so it is timed eagerly — its stages jit themselves internally
    def f(r, l):
        return gmapper.map_batch(
            idx.arrays, r, l, tile_stride=idx.tile_stride, cfg=cfg,
            p_cap=p_cap, filter_bits=128, filter_k=filter_k, minimizer_w=8,
            minimizer_k=12, backend=be)
    us = timeit(f, jnp.asarray(reads), jnp.asarray(lens))
    out = f(jnp.asarray(reads), jnp.asarray(lens))
    mapped = int(np.sum(~np.asarray(out.failed)))
    reads_per_s = batch / (us / 1e6)
    row(f"segram_e2e_{kind}", us / batch,
        f"reads_per_s={reads_per_s:.1f};mapped={mapped}/{batch};backend={be}")
    return {"read_len": read_len, "backend": be,
            "reads_per_s": round(reads_per_s, 2), "mapped": mapped,
            "n_reads": batch}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small ref, short reads only)")
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--backend", default=None,
                    help="repro.align backend (graph twin resolved)")
    args = ap.parse_args(argv)

    if args.smoke:
        out = {"short": run("short", batch=8, ref_len=4000,
                            backend=args.backend)}
    else:
        out = {"short": run("short", backend=args.backend),
               "long": run("long", batch=8, backend=args.backend)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    return out


if __name__ == "__main__":
    main()
