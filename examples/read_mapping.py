"""End-to-end driver: batched read-mapping service (seed → filter → align),
with work-queue fault tolerance and PAF output — the paper's workload.

    PYTHONPATH=src python examples/read_mapping.py
"""
from repro.launch.serve_genomics import main

main(["--ref-len", "20000", "--reads", "48", "--read-len", "150",
      "--batch", "16", "--out", "/tmp/mappings.paf"])
