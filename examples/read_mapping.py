"""Online read-mapping with the `repro.serve` micro-batching engine.

Submits a stream of simulated reads through the async serving API
(`submit() -> future`), prints per-read latency as results resolve, and
ends with the engine's metrics snapshot (queue/batch/cache/latency
counters — DESIGN.md §8).

    PYTHONPATH=src python examples/read_mapping.py
"""
from repro.core import minimizer_index
from repro.genomics import simulate
from repro.serve import EngineConfig, ServeEngine, Session

ref = simulate.random_reference(8_000, seed=1)
index = minimizer_index.build_epoched_index(ref, w=8, k=12)
rs = simulate.simulate_reads(ref, n_reads=24, read_len=150,
                             profile=simulate.ILLUMINA, seed=2)

config = EngineConfig(buckets=(160, 320), max_batch=8, max_delay_s=0.005,
                      minimizer_w=8, minimizer_k=12)
with ServeEngine(index, config) as engine:
    session = Session(engine)
    for gid, read in enumerate(rs.reads):
        session.submit(read, meta=gid)
    results = session.drain()
    # a resubmitted read is answered from the result cache (epoch-keyed)
    session.submit(rs.reads[0], meta="dup-of-0")
    results += session.drain()
    print("gid        pos   dist  bucket  cached  latency")
    for gid, res in results:
        print(f"{str(gid):<9} {res.position:>5} {res.distance:>6} "
              f"{res.bucket_cap:>7} {str(res.cached):>7} "
              f"{res.latency_s * 1e3:>8.2f} ms")

    correct = sum(abs(res.position - rs.true_pos[gid]) <= 16
                  for gid, res in results if isinstance(gid, int))
    print(f"\nposition-correct: {correct}/{len(rs.reads)}")
    print("--- engine metrics ---")
    print(engine.metrics.render())
