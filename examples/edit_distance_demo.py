"""Use case 3: edit distance of two long sequences, GenASM vs Myers(Edlib).

    PYTHONPATH=src python examples/edit_distance_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.edit_distance import genasm_distance
from repro.core.myers import myers_distance
from repro.genomics import simulate

rng = np.random.default_rng(0)
a = simulate.random_reference(2000, seed=1)          # text
b = simulate.mutate(a, simulate.PROFILES["pacbio"], rng)  # pattern (query)

p_cap = 2112
pbuf = np.full((p_cap,), 4, np.int8); pbuf[: len(b)] = b
tbuf = np.full((p_cap + 192,), 4, np.int8); tbuf[: len(a)] = a

d = int(genasm_distance(jnp.asarray(pbuf), jnp.asarray(tbuf),
                        jnp.int32(len(b)), jnp.int32(len(a)), p_cap=p_cap))
m_bits = ((len(b) + 63) // 64) * 64
mbuf = np.full((m_bits,), 4, np.int8); mbuf[: len(b)] = b
dm = int(myers_distance(jnp.asarray(tbuf), jnp.asarray(mbuf),
                        jnp.int32(len(b)), m_bits=m_bits, mode="semiglobal"))
print(f"sequence lengths: {len(a)} (text) vs {len(b)} (query)")
print(f"GenASM windowed distance: {d}")
print(f"Myers (Edlib) distance:   {dm}")
assert dm <= d <= dm + max(5, dm // 20), (d, dm)  # windowed ≈ exact
