"""SeGraM example: build a variation graph, map reads to it (seed + BitAlign).

    PYTHONPATH=src python examples/graph_alignment.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.segram import graph, segram
from repro.genomics import encode, simulate
from repro.genomics.io import cigar_string

ref = simulate.random_reference(5000, seed=3)
variants = simulate.simulate_variants(ref, n_snp=16, n_ins=6, n_del=6, seed=4)
g = graph.build_graph(ref, variants)
print(f"graph: {g.n_nodes} nodes ({g.n_nodes - len(ref)} variant nodes)")

index = segram.preprocess(ref, g, w=8, k=12)
rs = simulate.simulate_reads(ref, n_reads=8, read_len=100,
                             profile=simulate.ILLUMINA, seed=5)
reads, lens = encode.batch_reads(rs.reads, 128)
out = segram.map_batch(index, jnp.asarray(reads), jnp.asarray(lens),
                       m_bits=128, k=16, win_len=192,
                       minimizer_w=8, minimizer_k=12)
for i in range(8):
    d = int(out["distance"][i])
    node = int(out["node"][i])
    cig = cigar_string(np.asarray(out["ops"][i]), int(out["n_ops"][i]))
    print(f"read{i}: node={node} dist={d} cigar={cig[:48]}")
assert int(np.sum(~np.asarray(out["failed"]))) >= 6
