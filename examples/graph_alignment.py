"""Sequence-to-graph mapping example: build a variation graph, map reads
through the tiled `repro.graph` index and the `repro.align` dispatch
(the serve engine compiles exactly this path for ``workload="graph"``).

    PYTHONPATH=src python examples/graph_alignment.py
"""
import numpy as np
import jax.numpy as jnp

from repro.graph import index as graph_index
from repro.graph import mapper as graph_mapper
from repro.genomics import encode, simulate
from repro.genomics.io import cigar_string, gaf_path

ref = simulate.random_reference(5000, seed=3)
variants = simulate.simulate_variants(ref, n_snp=16, n_ins=6, n_del=6, seed=4)
idx = graph_index.build_graph_index(ref, variants, w=8, k=12, window=256)
print(f"graph: {idx.n_nodes} nodes ({idx.n_nodes - len(ref)} variant nodes), "
      f"{idx.n_tiles} tiles of {idx.tile_len} @ stride {idx.tile_stride}")

rs = simulate.simulate_reads(ref, n_reads=8, read_len=100,
                             profile=simulate.ILLUMINA, seed=5)
reads, lens = encode.batch_reads(rs.reads, 128)
out = graph_mapper.map_batch_index(
    idx, jnp.asarray(reads), jnp.asarray(lens), p_cap=128, filter_bits=96,
    filter_k=12, backend="graph_lax")
for i in range(8):
    d = int(out.distance[i])
    pos = int(out.position[i])
    path, plen = gaf_path(np.asarray(out.path[i]))
    cig = cigar_string(np.asarray(out.ops[i]), int(out.n_ops[i]))
    print(f"read{i}: pos={pos} dist={d} path={path[:40]} cigar={cig[:40]}")
assert int(np.sum(~np.asarray(out.failed))) >= 6
