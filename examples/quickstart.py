"""Quickstart: align one read against a reference with GenASM.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.genasm import GenASMConfig, align
from repro.genomics.encode import encode
from repro.genomics.io import cigar_string

REF = "ACGTACGGATTACAGGCATCGTACGATCGTAGCTAGCTTAGGCATCATACGGATTACATTCCGGAA"
READ = "ACGGATTACAGGCTTCGTACGATCGAGCTAGCTTAGGCAT"  # 1 subst + 1 deletion

ref = encode(REF)
read = encode(READ)
offset = 4  # candidate location (in production found by minimizer seeding)

p_cap = 64
text = np.full((p_cap + 64,), 4, np.int8)
text[: len(ref) - offset] = ref[offset:]
pat = np.full((p_cap,), 4, np.int8)
pat[: len(read)] = read

res = align(jnp.asarray(text), jnp.asarray(pat), jnp.int32(len(read)),
            jnp.int32(len(ref) - offset), cfg=GenASMConfig(), p_cap=p_cap)
print("edit distance:", int(res.distance))
print("CIGAR:", cigar_string(np.asarray(res.ops), int(res.n_ops)))
assert int(res.distance) == 2
