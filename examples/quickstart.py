"""Quickstart: align one read against a reference with GenASM.

Alignment goes through the `repro.align` backend dispatch — swap
``backend="lax"`` for ``"pallas_dc"``/``"pallas_dc_v2"`` (the Pallas
kernels; interpret mode on CPU) or ``"ref"`` (exact DP oracle) and the
result is identical.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import align as align_dispatch
from repro.core.genasm import GenASMConfig
from repro.genomics.encode import encode
from repro.genomics.io import cigar_string

REF = "ACGTACGGATTACAGGCATCGTACGATCGTAGCTAGCTTAGGCATCATACGGATTACATTCCGGAA"
READ = "ACGGATTACAGGCTTCGTACGATCGAGCTAGCTTAGGCAT"  # 1 subst + 1 deletion
BACKEND = "lax"  # or: ref | pallas_dc | pallas_dc_v2 (see repro.align)

ref = encode(REF)
read = encode(READ)
offset = 4  # candidate location (in production found by minimizer seeding)

p_cap = 64
text = np.full((p_cap + 64,), 4, np.int8)
text[: len(ref) - offset] = ref[offset:]
pat = np.full((p_cap,), 4, np.int8)
pat[: len(read)] = read

res = align_dispatch.align_batch(
    jnp.asarray(text)[None], jnp.asarray(pat)[None],
    jnp.asarray([len(read)], np.int32),
    jnp.asarray([len(ref) - offset], np.int32),
    cfg=GenASMConfig(), p_cap=p_cap, backend=BACKEND)
print("backend:", BACKEND, "of", align_dispatch.available_backends())
print("edit distance:", int(res.distance[0]))
print("CIGAR:", cigar_string(np.asarray(res.ops[0]), int(res.n_ops[0])))
assert int(res.distance[0]) == 2
