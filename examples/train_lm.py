"""Train a ~100M-parameter LM for a few hundred steps on CPU (the
end-to-end training driver over the assigned-architecture substrate).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys

from repro.launch.train import main

steps = "200"
if "--steps" in sys.argv:
    steps = sys.argv[sys.argv.index("--steps") + 1]
main(["--arch", "yi-6b", "--smoke", "--d-model", "1024", "--layers", "6",
      "--steps", steps, "--seq", "128", "--batch", "4",
      "--ckpt-dir", "/tmp/repro_ckpt"])
