"""pydocstyle-lite gate for the public API surface (CI docstring check).

Dependency-free subset of ruff's ``D`` rules (D100/D101/D102/D103),
scoped to the modules whose docstrings the docs promise to keep
accurate: every module, public class, and public function/method must
carry a real docstring.  Run from the repo root:

    python tools/check_docstrings.py

Exit code 1 lists each violation as ``path:line: code symbol``.  The
same scope is configured for ruff in ``pyproject.toml``
([tool.ruff.lint] select D + per-file-ignores), so environments with
ruff installed can run ``ruff check`` and get the superset diagnostics.
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
# the public API surface the docs guarantee (ISSUE: align_batch dispatch,
# serve engine, graph mapper, the shard subsystem)
SCOPE = [
    "src/repro/align/api.py",
    "src/repro/serve/engine.py",
    "src/repro/serve/cache.py",
    "src/repro/graph/mapper.py",
    "src/repro/shard/__init__.py",
    "src/repro/shard/partition.py",
    "src/repro/shard/graph_partition.py",
    "src/repro/shard/mapper.py",
    "src/repro/shard/graph_mapper.py",
    "src/repro/shard/failover.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/trace.py",
    "src/repro/obs/attrib.py",
    "src/repro/obs/http.py",
]
MIN_LEN = 10  # a docstring must actually say something


def _ok(node) -> bool:
    doc = ast.get_docstring(node)
    return doc is not None and len(doc.strip()) >= MIN_LEN


def check_file(path: pathlib.Path) -> list[str]:
    """Return the violation lines for one module."""
    tree = ast.parse(path.read_text(), filename=str(path))
    rel = path.relative_to(ROOT)
    out = []
    if not _ok(tree):
        out.append(f"{rel}:1: D100 missing module docstring")
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            if not node.name.startswith("_") and not _ok(node):
                out.append(f"{rel}:{node.lineno}: D101 missing docstring "
                           f"in public class {node.name}")
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_") and not _ok(sub):
                    out.append(f"{rel}:{sub.lineno}: D102 missing docstring "
                               f"in public method {node.name}.{sub.name}")
    for node in tree.body:  # module-level functions only (not nested)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not node.name.startswith("_") and not _ok(node):
            out.append(f"{rel}:{node.lineno}: D103 missing docstring "
                       f"in public function {node.name}")
    return out


def main() -> int:
    """Check every in-scope module; print violations; 0 = clean."""
    violations = []
    for mod in SCOPE:
        p = ROOT / mod
        if not p.exists():
            violations.append(f"{mod}:1: D000 scoped module is missing")
            continue
        violations.extend(check_file(p))
    for v in violations:
        print(v)
    if violations:
        print(f"\n{len(violations)} docstring violation(s) in the public "
              f"API surface")
        return 1
    print(f"docstring check: {len(SCOPE)} modules clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
