#!/usr/bin/env bash
# Quickstart-drift gate: execute every README Quickstart command.
#
# Each invocation below is a README §Quickstart command verbatim, plus
# size-only flags (--ref-len/--reads/--read-len/--batch) appended so CI
# finishes in minutes — the flags exercised by the docs (--online,
# --align-backend, --mode graph, --num-shards, --smoke) are untouched.
# A command that rots (renamed flag, moved module, changed default)
# fails this script and therefore CI, so the README cannot drift again.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
SMALL="--ref-len 4000 --reads 12 --read-len 100 --batch 4"
OUT=$(mktemp -d)
trap 'rm -rf "$OUT"' EXIT

echo "== quickstart example"
python examples/quickstart.py

echo "== offline read-mapping service (PAF)"
python -m repro.launch.serve_genomics $SMALL --out "$OUT/out.paf"

echo "== online Poisson serving"
python -m repro.launch.serve_genomics --online --rate 200 $SMALL \
    --out "$OUT/online.paf"
cmp "$OUT/out.paf" "$OUT/online.paf"  # README: both modes emit identical PAF

echo "== align-backend selection (pallas_dc_v2, interpret on CPU)"
python -m repro.launch.serve_genomics --align-backend pallas_dc_v2 $SMALL \
    --out "$OUT/pallas.paf"
cmp "$OUT/out.paf" "$OUT/pallas.paf"  # README: byte-identical PAF

echo "== graph workload (GAF)"
python -m repro.launch.serve_genomics --mode graph --online --rate 200 \
    $SMALL --out "$OUT/out.gaf"
test -s "$OUT/out.gaf"

echo "== sharded serving (--num-shards 2, byte-identical PAF)"
python -m repro.launch.serve_genomics --num-shards 2 $SMALL \
    --out "$OUT/sharded.paf"
cmp "$OUT/out.paf" "$OUT/sharded.paf"

echo "== tracing + live obs endpoints (--trace-out / --http-port)"
python -m repro.launch.serve_genomics --trace-out "$OUT/trace.json" \
    --http-port 0 $SMALL --out "$OUT/traced.paf"
cmp "$OUT/out.paf" "$OUT/traced.paf"  # tracing never changes output
python - "$OUT/trace.json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert any(e.get("name") == "flush" for e in doc["traceEvents"])
assert any(e.get("ph") == "C" for e in doc["traceEvents"]), \
    "no per-kernel counter samples in the trace"
print(f"trace.json: {len(doc['traceEvents'])} events")
EOF

echo "== /roofline endpoint (per-kernel counters over a live engine)"
python - <<'EOF'
import json
import urllib.request

import numpy as np

from repro.core import minimizer_index
from repro.obs import ObsServer, RooflineManager, Tracer
from repro.serve import EngineConfig, ServeEngine

rng = np.random.default_rng(11)
ref = rng.integers(0, 4, size=4000).astype(np.int8)
index = minimizer_index.build_epoched_index(ref, w=8, k=12)
tracer = Tracer()
roofline = RooflineManager(tracer=tracer)
cfg = EngineConfig(buckets=(128,), max_batch=4, minimizer_w=8,
                   minimizer_k=12)
with ServeEngine(index, cfg, tracer=tracer, roofline=roofline) as eng:
    roofline.metrics = eng.metrics
    eng.map_all([ref[i:i + 100].copy() for i in (60, 800, 2000, 3100)])
    with ObsServer(metrics=eng.metrics, tracer=tracer,
                   roofline=roofline, port=0) as srv:
        with urllib.request.urlopen(srv.url + "/roofline", timeout=60) as r:
            doc = json.loads(r.read())
rows = doc["kernels"]
assert rows, "no kernel dispatch sites recorded"
for row in rows:
    for key in ("analytic_ops", "measured_ops", "bytes", "intensity",
                "pct_of_roof"):
        assert key in row, f"missing {key} in /roofline row"
    assert row["measure_error"] is None, row["measure_error"]
print(f"/roofline: {len(rows)} kernel site(s), "
      f"device spec {doc['device_spec']['name']}")
EOF

echo "quickstart smoke: all README commands ran"
