#!/usr/bin/env bash
# Quickstart-drift gate: execute every README Quickstart command.
#
# Each invocation below is a README §Quickstart command verbatim, plus
# size-only flags (--ref-len/--reads/--read-len/--batch) appended so CI
# finishes in minutes — the flags exercised by the docs (--online,
# --align-backend, --mode graph, --num-shards, --smoke) are untouched.
# A command that rots (renamed flag, moved module, changed default)
# fails this script and therefore CI, so the README cannot drift again.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
SMALL="--ref-len 4000 --reads 12 --read-len 100 --batch 4"
OUT=$(mktemp -d)
trap 'rm -rf "$OUT"' EXIT

echo "== quickstart example"
python examples/quickstart.py

echo "== offline read-mapping service (PAF)"
python -m repro.launch.serve_genomics $SMALL --out "$OUT/out.paf"

echo "== online Poisson serving"
python -m repro.launch.serve_genomics --online --rate 200 $SMALL \
    --out "$OUT/online.paf"
cmp "$OUT/out.paf" "$OUT/online.paf"  # README: both modes emit identical PAF

echo "== align-backend selection (pallas_dc_v2, interpret on CPU)"
python -m repro.launch.serve_genomics --align-backend pallas_dc_v2 $SMALL \
    --out "$OUT/pallas.paf"
cmp "$OUT/out.paf" "$OUT/pallas.paf"  # README: byte-identical PAF

echo "== graph workload (GAF)"
python -m repro.launch.serve_genomics --mode graph --online --rate 200 \
    $SMALL --out "$OUT/out.gaf"
test -s "$OUT/out.gaf"

echo "== sharded serving (--num-shards 2, byte-identical PAF)"
python -m repro.launch.serve_genomics --num-shards 2 $SMALL \
    --out "$OUT/sharded.paf"
cmp "$OUT/out.paf" "$OUT/sharded.paf"

echo "== tracing + live obs endpoints (--trace-out / --http-port)"
python -m repro.launch.serve_genomics --trace-out "$OUT/trace.json" \
    --http-port 0 $SMALL --out "$OUT/traced.paf"
cmp "$OUT/out.paf" "$OUT/traced.paf"  # tracing never changes output
python - "$OUT/trace.json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert any(e.get("name") == "flush" for e in doc["traceEvents"])
print(f"trace.json: {len(doc['traceEvents'])} events")
EOF

echo "quickstart smoke: all README commands ran"
