"""Continuous perf gate: compare a bench summary against a committed anchor.

Walks both JSON trees and compares every ``reads_per_s`` leaf (any dict
key containing that substring, at any nesting depth) in the current
``bench_summary.json`` against the anchor committed with the PR that
last touched performance (``BENCH_PR*.json``).  A key regressing below
``factor`` × anchor fails the build; keys present in only one file are
reported but never fail (benchmarks come and go across PRs) — *unless*
the two files share **zero** throughput keys, which means the summary
schema drifted out from under the anchor and the gate would otherwise
silently stop gating anything: that exits non-zero (code 2) until the
anchor is refreshed.

The default factor 0.85 tolerates runner-to-runner noise (GitHub
machines vary run to run) while catching the >15% regressions a serving
change can realistically introduce.  Escape hatches for emergencies:

    BENCH_GATE_SKIP=1        skip the gate entirely (prints why it ran)
    BENCH_GATE_FACTOR=0.7    widen the tolerance for a known-noisy run

    python tools/bench_gate.py bench_summary.json BENCH_PR7.json
    python tools/bench_gate.py current.json anchor.json --factor 0.9
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def collect(node, prefix: str = "") -> dict[str, float]:
    """Flatten every numeric ``reads_per_s``-ish leaf to dotted-path keys."""
    out: dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(collect(v, path))
            elif "reads_per_s" in str(k) and isinstance(v, (int, float)):
                out[path] = float(v)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(collect(v, f"{prefix}[{i}]"))
    return out


def gate(current: dict, anchor: dict, factor: float
         ) -> tuple[list, list, int]:
    """Return (failures, report_lines, n_shared) over the throughput keys.

    ``n_shared`` is the count of ``reads_per_s`` keys present in *both*
    trees — zero means schema drift and the caller must fail loudly
    rather than pass an empty comparison.
    """
    cur, ref = collect(current), collect(anchor)
    n_shared = len(set(cur) & set(ref))
    failures, lines = [], []
    for key in sorted(ref):
        if key not in cur:
            lines.append(f"  {key}: anchor-only ({ref[key]:.2f}), skipped")
            continue
        c, r = cur[key], ref[key]
        ratio = c / r if r > 0 else float("inf")
        verdict = "ok" if ratio >= factor else "REGRESSION"
        lines.append(f"  {key}: {r:.2f} -> {c:.2f} reads/s "
                     f"({ratio:.2%} of anchor) {verdict}")
        if ratio < factor:
            failures.append((key, r, c, ratio))
    for key in sorted(set(cur) - set(ref)):
        lines.append(f"  {key}: new key ({cur[key]:.2f}), skipped")
    return failures, lines, n_shared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="bench_summary.json from this run")
    ap.add_argument("anchor", help="committed anchor (BENCH_PR*.json)")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR", 0.85)),
                    help="minimum current/anchor ratio per key "
                         "(default 0.85 = fail on >15%% regression; env "
                         "BENCH_GATE_FACTOR overrides)")
    args = ap.parse_args(argv)

    if os.environ.get("BENCH_GATE_SKIP"):
        print("bench gate: skipped (BENCH_GATE_SKIP set)")
        return 0

    with open(args.current) as f:
        current = json.load(f)
    with open(args.anchor) as f:
        anchor = json.load(f)

    failures, lines, n_shared = gate(current, anchor, args.factor)
    print(f"bench gate: {args.current} vs {args.anchor} "
          f"(factor {args.factor})")
    print("\n".join(lines))
    if n_shared == 0:
        print("bench gate: FAILED — current summary and anchor share zero "
              "reads_per_s keys (schema drift?); nothing was actually "
              "compared. Refresh the anchor (BENCH_PR*.json) to match the "
              "current bench_summary.json layout.")
        return 2
    if failures:
        print(f"bench gate: {len(failures)} key(s) regressed below "
              f"{args.factor:.0%} of anchor:")
        for key, r, c, ratio in failures:
            print(f"  {key}: {r:.2f} -> {c:.2f} ({ratio:.2%})")
        return 1
    print("bench gate: all throughput keys within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
