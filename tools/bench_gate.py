"""Continuous perf gate: compare a bench summary against a committed anchor.

Walks both JSON trees and compares every ``reads_per_s`` leaf (any dict
key containing that substring, at any nesting depth) in the current
``bench_summary.json`` against the anchor committed with the PR that
last touched performance (``BENCH_PR*.json``).  A key regressing below
``factor`` × anchor fails the build; keys present in only one file are
reported but never fail (benchmarks come and go across PRs).

The default factor 0.85 tolerates runner-to-runner noise (GitHub
machines vary run to run) while catching the >15% regressions a serving
change can realistically introduce.  Escape hatches for emergencies:

    BENCH_GATE_SKIP=1        skip the gate entirely (prints why it ran)
    BENCH_GATE_FACTOR=0.7    widen the tolerance for a known-noisy run

    python tools/bench_gate.py bench_summary.json BENCH_PR7.json
    python tools/bench_gate.py current.json anchor.json --factor 0.9
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def collect(node, prefix: str = "") -> dict[str, float]:
    """Flatten every numeric ``reads_per_s``-ish leaf to dotted-path keys."""
    out: dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(collect(v, path))
            elif "reads_per_s" in str(k) and isinstance(v, (int, float)):
                out[path] = float(v)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(collect(v, f"{prefix}[{i}]"))
    return out


def gate(current: dict, anchor: dict, factor: float) -> tuple[list, list]:
    """Return (failures, report_lines) for every shared throughput key."""
    cur, ref = collect(current), collect(anchor)
    failures, lines = [], []
    for key in sorted(ref):
        if key not in cur:
            lines.append(f"  {key}: anchor-only ({ref[key]:.2f}), skipped")
            continue
        c, r = cur[key], ref[key]
        ratio = c / r if r > 0 else float("inf")
        verdict = "ok" if ratio >= factor else "REGRESSION"
        lines.append(f"  {key}: {r:.2f} -> {c:.2f} reads/s "
                     f"({ratio:.2%} of anchor) {verdict}")
        if ratio < factor:
            failures.append((key, r, c, ratio))
    for key in sorted(set(cur) - set(ref)):
        lines.append(f"  {key}: new key ({cur[key]:.2f}), skipped")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="bench_summary.json from this run")
    ap.add_argument("anchor", help="committed anchor (BENCH_PR*.json)")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR", 0.85)),
                    help="minimum current/anchor ratio per key "
                         "(default 0.85 = fail on >15%% regression; env "
                         "BENCH_GATE_FACTOR overrides)")
    args = ap.parse_args(argv)

    if os.environ.get("BENCH_GATE_SKIP"):
        print("bench gate: skipped (BENCH_GATE_SKIP set)")
        return 0

    with open(args.current) as f:
        current = json.load(f)
    with open(args.anchor) as f:
        anchor = json.load(f)

    failures, lines = gate(current, anchor, args.factor)
    print(f"bench gate: {args.current} vs {args.anchor} "
          f"(factor {args.factor})")
    print("\n".join(lines))
    if failures:
        print(f"bench gate: {len(failures)} key(s) regressed below "
              f"{args.factor:.0%} of anchor:")
        for key, r, c, ratio in failures:
            print(f"  {key}: {r:.2f} -> {c:.2f} ({ratio:.2%})")
        return 1
    print("bench gate: all throughput keys within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
