"""tools/bench_gate.py: throughput-key comparison and schema-drift guard."""
import importlib.util
import json
import pathlib

import pytest

_spec = importlib.util.spec_from_file_location(
    "bench_gate",
    pathlib.Path(__file__).resolve().parents[1] / "tools" / "bench_gate.py")
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


def test_collect_flattens_nested_reads_per_s_leaves():
    tree = {"serve": {"bucketed": {"reads_per_s": 100.0, "p50_ms": 3.0}},
            "rows": [{"reads_per_s": 7.5}],
            "reads_per_s_online": 2}
    got = bench_gate.collect(tree)
    assert got == {"serve.bucketed.reads_per_s": 100.0,
                   "rows[0].reads_per_s": 7.5,
                   "reads_per_s_online": 2.0}


def test_gate_passes_within_tolerance_and_fails_regressions():
    anchor = {"a": {"reads_per_s": 100.0}, "b": {"reads_per_s": 50.0}}
    ok = {"a": {"reads_per_s": 90.0}, "b": {"reads_per_s": 49.0}}
    failures, lines, n_shared = bench_gate.gate(ok, anchor, 0.85)
    assert failures == [] and n_shared == 2
    bad = {"a": {"reads_per_s": 50.0}, "b": {"reads_per_s": 49.0}}
    failures, _, _ = bench_gate.gate(bad, anchor, 0.85)
    assert [f[0] for f in failures] == ["a.reads_per_s"]


def test_gate_anchor_only_and_new_keys_reported_not_failed():
    anchor = {"kept": {"reads_per_s": 10.0}, "gone": {"reads_per_s": 5.0}}
    current = {"kept": {"reads_per_s": 10.0}, "fresh": {"reads_per_s": 9.0}}
    failures, lines, n_shared = bench_gate.gate(current, anchor, 0.85)
    assert failures == [] and n_shared == 1
    text = "\n".join(lines)
    assert "gone.reads_per_s: anchor-only" in text
    assert "fresh.reads_per_s: new key" in text


def test_gate_zero_overlap_reports_zero_shared():
    anchor = {"old_schema": {"reads_per_s": 10.0}}
    current = {"new_schema": {"reads_per_s": 12.0}}
    failures, _, n_shared = bench_gate.gate(current, anchor, 0.85)
    assert failures == [] and n_shared == 0


def _write(path, tree):
    path.write_text(json.dumps(tree))
    return str(path)


def test_main_exits_nonzero_on_zero_shared_keys(tmp_path, capsys):
    cur = _write(tmp_path / "cur.json", {"new": {"reads_per_s": 12.0}})
    anc = _write(tmp_path / "anchor.json", {"old": {"reads_per_s": 10.0}})
    assert bench_gate.main([cur, anc]) == 2
    out = capsys.readouterr().out
    assert "zero" in out and "schema drift" in out


def test_main_passes_and_fails_regressions(tmp_path):
    anc = _write(tmp_path / "anchor.json", {"a": {"reads_per_s": 100.0}})
    good = _write(tmp_path / "good.json", {"a": {"reads_per_s": 99.0}})
    bad = _write(tmp_path / "bad.json", {"a": {"reads_per_s": 10.0}})
    assert bench_gate.main([good, anc]) == 0
    assert bench_gate.main([bad, anc]) == 1


def test_main_skip_env(tmp_path, monkeypatch):
    monkeypatch.setenv("BENCH_GATE_SKIP", "1")
    cur = _write(tmp_path / "cur.json", {"new": {"reads_per_s": 1.0}})
    anc = _write(tmp_path / "anchor.json", {"old": {"reads_per_s": 10.0}})
    assert bench_gate.main([cur, anc]) == 0


def test_gate_matches_committed_anchor_schema():
    # the committed anchor must share keys with itself (sanity on the
    # real artifact the CI gate runs against)
    anchor_path = pathlib.Path(__file__).resolve().parents[1] / \
        "BENCH_PR7.json"
    if not anchor_path.exists():
        pytest.skip("no committed anchor in this checkout")
    anchor = json.loads(anchor_path.read_text())
    failures, _, n_shared = bench_gate.gate(anchor, anchor, 0.85)
    assert failures == [] and n_shared > 0
