"""Serving path: greedy generation, int8 KV cache parity, prefill/decode."""
import numpy as np
import jax
import jax.numpy as jnp

import repro.models.transformer as tr
from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import model_zoo
from repro.train.serve import greedy_generate


def test_greedy_generate_deterministic():
    cfg = reduced(get_config("internlm2-1.8b"))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = greedy_generate(cfg, params, prompt, steps=6, max_len=32)
    out2 = greedy_generate(cfg, params, prompt, steps=6, max_len=32)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (1, 7)


def test_int8_kv_cache_matches_bf16():
    """§Perf #12: quantized cache keeps greedy decisions identical."""
    cfg = reduced(get_config("yi-6b"))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    B = 2
    batch = {"tokens": jnp.ones((B, 1), jnp.int32) * 3}
    outs = {}
    for int8 in (False, True):
        tr.KV_INT8 = int8
        st = model_zoo.decode_state_init(cfg, B, 32)
        seq = []
        for p in range(5):
            lo, st = model_zoo.decode_fn(cfg, params, st, batch, jnp.int32(p))
            seq.append(np.asarray(lo))
        outs[int8] = seq
    tr.KV_INT8 = False
    for p in range(5):
        rel = np.abs(outs[True][p] - outs[False][p]).max() / (
            np.abs(outs[False][p]).max() + 1e-9)
        assert rel < 0.05
        np.testing.assert_array_equal(outs[True][p].argmax(-1),
                                      outs[False][p].argmax(-1))


def test_prefill_then_decode_consistent():
    """Prefill logits == step-by-step decode logits at the same position."""
    cfg = reduced(get_config("yi-6b"))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    pre = model_zoo.prefill_fn(cfg, params, {"tokens": toks})
    st = model_zoo.decode_state_init(cfg, 1, 16)
    for p in range(4):
        lo, st = model_zoo.decode_fn(cfg, params, st,
                                     {"tokens": toks[:, p: p + 1]}, jnp.int32(p))
    np.testing.assert_allclose(np.asarray(pre), np.asarray(lo), rtol=2e-2,
                               atol=2e-2)
