"""Boundary-mapping property: shard cuts must never change a mapping.

Reads are *simulated to straddle the shard cut points* — each read's
true locus is centered on an internal partition boundary, the worst
case for a sharded index (its seeds split across two shards, its
filter region and alignment window live in the overlap halos).  For
every such read, mapping at 1 shard and at N shards must agree exactly:
positions, distances, CIGAR strings, and (for the graph workload) GAF
node paths.  Error profiles sweep substitutions and indels so the
agreement is a property of the merge rule, not of clean data.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import shard
from repro.core import mapper as core_mapper
from repro.core import minimizer_index
from repro.core.genasm import GenASMConfig
from repro.genomics import encode, io, simulate
from repro.graph import index as graph_index
from repro.graph import mapper as graph_mapper

L = 9_000
READ_LEN = 100
P_CAP = 128
CFG = GenASMConfig()
KW = dict(p_cap=P_CAP, filter_bits=128, filter_k=12)
SEED_KW = dict(minimizer_w=8, minimizer_k=12)  # single-device mappers only
# (the sharded mappers read w/k off the sharded index itself)


def _boundary_reads(ref, bounds, *, seed, n_per_boundary=4):
    """Reads whose true loci straddle every internal cut in ``bounds``."""
    rng = np.random.default_rng(seed)
    reads = []
    for b in bounds[1:-1]:
        for j in range(n_per_boundary):
            # start so the cut lands inside the read, at varying offsets
            start = b - READ_LEN + 1 + int(rng.integers(1, READ_LEN - 1))
            start = int(np.clip(start, 0, len(ref) - READ_LEN))
            read = np.array(ref[start: start + READ_LEN], np.int8)
            if j % 2:  # half the reads carry sequencing errors
                subs = rng.integers(0, READ_LEN, size=3)
                read[subs] = (read[subs] + 1 + rng.integers(0, 3,
                                                            size=3)) % 4
            reads.append(read)
    return reads


def _cigars(res):
    return [io.cigar_string(np.asarray(res.ops)[i], int(res.n_ops[i]))
            for i in range(len(res.n_ops))]


@pytest.mark.parametrize("num_shards,align_sharded,pipelined", [
    # device-merge path at every shard count ...
    (2, False, False), (3, False, False), (4, False, False),
    # ... and the mesh-split align / pipelined-dispatch axes, which
    # must stay byte-neutral on the same boundary-straddling reads
    (2, True, False), (2, False, True), (3, True, True),
])
def test_linear_boundary_reads_map_identically(num_shards, align_sharded,
                                               pipelined):
    ref = simulate.random_reference(L, seed=21)
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
    esi = shard.from_epoched(epi, num_shards)
    reads = _boundary_reads(ref, esi.index.layout.bounds,
                            seed=100 + num_shards)
    arr, lens = encode.batch_reads(reads, P_CAP)

    single = core_mapper.map_batch(
        epi.index, jnp.asarray(arr), jnp.asarray(lens), cfg=CFG,
        max_candidates=4, backend="lax", **KW, **SEED_KW)
    sharded = shard.map_batch_sharded(
        esi.index, arr, lens, cfg=CFG, shard_candidates=4, backend="lax",
        align_sharded=align_sharded, pipelined=pipelined, **KW)

    assert (np.asarray(single.position) == sharded.position).all()
    assert (np.asarray(single.distance) == sharded.distance).all()
    assert _cigars(single) == _cigars(sharded)
    # boundary reads must actually map (the halo absorbed the cut)
    assert (sharded.position >= 0).all()


@pytest.mark.parametrize("num_shards,prefilter,align_sharded,pipelined", [
    (2, True, False, False), (2, False, False, False),
    (3, True, False, False), (3, False, False, False),
    # mesh-split align / pipelined-dispatch axes (byte-neutral)
    (2, True, True, False), (2, False, False, True),
    (3, True, True, True),
])
def test_graph_boundary_reads_map_identically(num_shards, prefilter,
                                              align_sharded, pipelined):
    ref = simulate.random_reference(L, seed=22)
    variants = simulate.simulate_variants(ref, n_snp=30, n_ins=15,
                                          n_del=15, seed=23)
    gidx = graph_index.build_graph_index(ref, variants, w=8, k=12,
                                         window=P_CAP + 2 * CFG.w)
    esi = shard.from_epoched_graph(gidx, num_shards)
    reads = _boundary_reads(ref, esi.index.layout.bounds,
                            seed=200 + num_shards)
    arr, lens = encode.batch_reads(reads, P_CAP)

    single = graph_mapper.map_batch_index(
        gidx, jnp.asarray(arr), jnp.asarray(lens), cfg=CFG,
        max_candidates=4, backend="graph_lax", prefilter=prefilter,
        **KW, **SEED_KW)
    sharded = shard.map_batch_sharded_graph(
        esi.index, arr, lens, cfg=CFG, shard_candidates=4,
        backend="graph_lax", prefilter=prefilter,
        align_sharded=align_sharded, pipelined=pipelined, **KW)

    assert (np.asarray(single.position) == sharded.position).all()
    assert (np.asarray(single.distance) == sharded.distance).all()
    assert _cigars(single) == _cigars(sharded)
    assert (np.asarray(single.path) == sharded.path).all()  # GAF paths
    # and the GAF path strings themselves render identically
    for i in range(len(reads)):
        p1, n1 = io.gaf_path(np.asarray(single.path)[i])
        p2, n2 = io.gaf_path(sharded.path[i])
        assert (p1, n1) == (p2, n2)
    assert (sharded.position >= 0).mean() >= 0.8  # boundary reads map