"""Genomics substrate: encode/pack, FASTA/FASTQ IO, simulator, pipeline."""
import numpy as np

from repro.genomics import encode, io, pipeline, simulate


def test_encode_roundtrip():
    s = "ACGTNacgt"
    ids = encode.encode(s)
    assert encode.decode(ids) == "ACGTNACGT"


def test_pack_2bit_roundtrip(rng):
    ids = rng.integers(0, 4, size=1001).astype(np.int8)
    packed = encode.pack_2bit(ids)
    out = encode.unpack_2bit(packed, 1001)
    np.testing.assert_array_equal(out, ids)
    assert packed.nbytes * 4 <= ids.nbytes + 64  # 4x compression


def test_fasta_fastq_roundtrip(tmp_path, rng):
    recs = [io.Record(f"r{i}", rng.integers(0, 4, size=37).astype(np.int8))
            for i in range(3)]
    io.write_fasta(tmp_path / "x.fa", recs, width=10)
    back = list(io.read_fasta(tmp_path / "x.fa"))
    assert [r.name for r in back] == ["r0", "r1", "r2"]
    np.testing.assert_array_equal(back[1].seq, recs[1].seq)
    io.write_fastq(tmp_path / "x.fq", recs)
    back = list(io.read_fastq(tmp_path / "x.fq"))
    np.testing.assert_array_equal(back[2].seq, recs[2].seq)


def test_cigar_string():
    ops = np.array([0, 0, 0, 1, 2, 2, 3, 0], np.int8)
    assert io.cigar_string(ops, 8) == "3M1X2I1D1M"


def test_simulator_error_rate(rng):
    ref = simulate.random_reference(4000, seed=0)
    out = simulate.mutate(ref, simulate.ILLUMINA, rng)
    # length roughly preserved (ins ≈ del rates)
    assert abs(len(out) - len(ref)) < len(ref) * 0.05
    # substitution-only profile: positional identity ≈ 1 - rate·frac_sub
    subs_only = simulate.ErrorProfile("s", 0.05, 1.0, 0.0, 0.0)
    out2 = simulate.mutate(ref, subs_only, rng)
    same = np.mean(out2 == ref)
    assert 0.90 < same < 0.99


def test_read_batches_sharding():
    reads = [np.arange(i + 1, dtype=np.int8) % 4 for i in range(10)]
    b0 = list(pipeline.ReadBatches(reads, batch=2, cap=16, process_index=0,
                                   process_count=2))
    b1 = list(pipeline.ReadBatches(reads, batch=2, cap=16, process_index=1,
                                   process_count=2))
    assert len(b0) == 3 and len(b1) == 3
    # disjoint coverage: lengths identify reads
    lens0 = {int(l) for _, _, ls in b0 for l in ls if l > 0}
    lens1 = {int(l) for _, _, ls in b1 for l in ls if l > 0}
    assert lens0 & lens1 == set()
    assert lens0 | lens1 == set(range(1, 11))


def test_read_batches_resume():
    reads = [np.zeros(4, np.int8)] * 8
    it = pipeline.ReadBatches(reads, batch=2, cap=8, start_batch=2)
    ids = [b for b, _, _ in it]
    assert ids == [2, 3]
