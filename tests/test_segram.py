"""SeGraM: minimizers, graph construction, BitAlign vs graph-DP oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import oracle
from repro.core.segram import bitalign, graph, minimizer, segram
from repro.genomics import encode, simulate

from conftest import mutate_seq


def test_minimizers_cover_windows(rng):
    seq = rng.integers(0, 4, size=300).astype(np.int8)
    is_min, h = minimizer.minimizers(jnp.asarray(seq), w=8, k=12)
    is_min = np.asarray(is_min)
    h = np.asarray(h)
    # every window of 8 k-mers must contain at least one sampled minimizer
    n_k = len(h)
    for s in range(0, n_k - 8 + 1, 8):
        assert is_min[s: s + 8].any()


def test_minimizer_index_roundtrip(rng):
    ref = rng.integers(0, 4, size=2000).astype(np.int8)
    idx = minimizer.build_index(ref, w=8, k=12)
    # query with an exact fragment: true diagonal must be a candidate
    start = 700
    read = ref[start: start + 120]
    starts, votes = minimizer.seed_candidates(
        jnp.asarray(read), jnp.asarray(idx.hashes), jnp.asarray(idx.positions),
        w=8, k=12)
    starts = np.asarray(starts)[np.asarray(votes) > 0]
    assert any(abs(int(s) - start) <= 32 for s in starts)


def test_linear_graph_equals_linear_bitap(rng):
    ref = rng.integers(0, 4, size=96).astype(np.int8)
    g = graph.linear_graph(ref)
    m = 30
    pat = mutate_seq(ref[10: 10 + m], 2, 1, 1, rng)
    pbuf = np.full((64,), 4, np.int8)
    pbuf[: len(pat)] = pat
    dists, _ = bitalign.bitalign_dc(jnp.asarray(g.bases), jnp.asarray(g.succ_bits),
                                    jnp.asarray(pbuf), jnp.int32(len(pat)),
                                    m_bits=64, k=8)
    got = int(np.asarray(dists).min())
    want = min(min(oracle.levenshtein_prefix(pat, ref[i:]) for i in range(96)), 9)
    assert got == want


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_property_bitalign_matches_graph_dp(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    ref = rng.integers(0, 4, size=70).astype(np.int8)
    variants = simulate.simulate_variants(ref, n_snp=3, n_ins=2, n_del=1,
                                          seed=int(rng.integers(0, 999)))
    g = graph.build_graph(ref, variants)
    m = data.draw(st.integers(10, 32))
    start = data.draw(st.integers(0, 30))
    pat = mutate_seq(ref[start: start + m], data.draw(st.integers(0, 2)),
                     data.draw(st.integers(0, 1)), data.draw(st.integers(0, 1)),
                     rng)
    pbuf = np.full((64,), 4, np.int8)
    pbuf[: len(pat)] = pat
    dists, _ = bitalign.bitalign_dc(jnp.asarray(g.bases), jnp.asarray(g.succ_bits),
                                    jnp.asarray(pbuf), jnp.int32(len(pat)),
                                    m_bits=64, k=10)
    got = int(np.asarray(dists).min())
    want = min(oracle.graph_edit_distance(pat, g.bases, graph.predecessors(g)), 11)
    assert got == want


def test_bitalign_traceback_valid_path(rng):
    ref = np.tile(np.arange(4, dtype=np.int8), 25)
    variants = [graph.Variant(10, "snp", (3,)), graph.Variant(30, "del", span=2),
                graph.Variant(50, "ins", (2, 2))]
    g = graph.build_graph(ref, variants)
    pat = np.asarray(g.bases[5:35]).copy()
    pbuf = np.full((64,), 4, np.int8)
    pbuf[: len(pat)] = pat
    res = bitalign.bitalign(jnp.asarray(g.bases), jnp.asarray(g.succ_bits),
                            jnp.asarray(pbuf), jnp.int32(len(pat)), m_bits=64,
                            k=10)
    assert not bool(res["failed"])
    ops = np.asarray(res["ops"])
    nodes = np.asarray(res["nodes"])
    pi, edits, last = 0, 0, -1
    for s in range(int(res["n_ops"])):
        op, nd = int(ops[s]), int(nodes[s])
        if op in (0, 1, 3):
            assert nd > last
            last = nd
        if op == 0:
            assert g.bases[nd] == pat[pi]
            pi += 1
        elif op in (1, 2):
            pi += 1
            edits += 1
        elif op == 3:
            edits += 1
    assert pi == len(pat)
    assert edits == int(res["distance"])


def test_segram_end_to_end_maps_reads(rng):
    ref = simulate.random_reference(3000, seed=42)
    variants = simulate.simulate_variants(ref, n_snp=10, n_ins=4, n_del=4, seed=7)
    g = graph.build_graph(ref, variants)
    idx = segram.preprocess(ref, g, w=8, k=12)
    rs = simulate.simulate_reads(ref, n_reads=6, read_len=100,
                                 profile=simulate.ILLUMINA, seed=8)
    reads, lens = encode.batch_reads(rs.reads, 128)
    out = segram.map_batch(idx, jnp.asarray(reads), jnp.asarray(lens),
                           m_bits=128, k=16, win_len=192, minimizer_w=8,
                           minimizer_k=12)
    assert int(np.sum(~np.asarray(out["failed"]))) >= 5
