"""Myers/DP baselines vs oracles (the paper's comparison kernels)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import dp_baseline, myers, oracle


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_myers_global_matches_levenshtein(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    m = data.draw(st.integers(3, 60))
    n = data.draw(st.integers(3, 90))
    a = rng.integers(0, 4, size=m).astype(np.int8)
    b = rng.integers(0, 4, size=n).astype(np.int8)
    pbuf = np.full((64,), 4, np.int8)
    pbuf[:m] = a
    got = int(myers.myers_distance(jnp.asarray(b), jnp.asarray(pbuf),
                                   jnp.int32(m), m_bits=64, mode="global"))
    assert got == oracle.levenshtein(a, b)


def test_nw_edit_distance_matches_oracle(rng):
    for _ in range(8):
        m = int(rng.integers(5, 60))
        n = int(rng.integers(m, 100))
        a = rng.integers(0, 4, size=m).astype(np.int8)
        b = rng.integers(0, 4, size=n).astype(np.int8)
        pbuf = np.zeros((64,), np.int8); pbuf[:m] = a
        tbuf = np.zeros((128,), np.int8); tbuf[:n] = b
        got = int(dp_baseline.nw_edit_distance(jnp.asarray(tbuf), jnp.asarray(pbuf),
                                               jnp.int32(m), jnp.int32(n)))
        assert got == oracle.levenshtein_prefix(a, b)


def test_affine_score_identity(rng):
    a = rng.integers(0, 4, size=64).astype(np.int8)
    t = np.concatenate([a, np.zeros(32, np.int8)])
    p = np.concatenate([a, np.zeros(16, np.int8)])
    s = int(dp_baseline.affine_align_score(jnp.asarray(t), jnp.asarray(p),
                                           jnp.int32(64), jnp.int32(64)))
    assert s == 64 * 2


def test_affine_score_penalizes_gap(rng):
    a = rng.integers(0, 4, size=50).astype(np.int8)
    b = np.concatenate([a[:25], a[27:]])  # 2-deletion
    t = np.concatenate([a, np.zeros(30, np.int8)])
    p = np.concatenate([b, np.zeros(32, np.int8)])
    s = int(dp_baseline.affine_align_score(jnp.asarray(t), jnp.asarray(p),
                                           jnp.int32(48), jnp.int32(52)))
    assert s == 48 * 2 - (4 + 2 * 2)  # matches minus open+2·extend
