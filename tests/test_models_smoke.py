"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig, reduced
from repro.models import model_zoo

SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    batch = model_zoo.synth_batch(cfg, SMOKE)["batch"]
    batch["tokens"] = batch["tokens"] % cfg.vocab
    batch["targets"] = batch["targets"] % cfg.vocab
    loss, metrics = model_zoo.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 20
    grads = jax.grad(lambda p: model_zoo.loss_fn(cfg, p, batch)[0])(params)
    gn = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.square(l.astype(jnp.float32)))), grads, 0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    B = 2
    state = model_zoo.decode_state_init(cfg, B, 64)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if model_zoo.is_encdec(cfg):
        batch["memory"] = jnp.zeros((B, 16, cfg.d_model), jnp.bfloat16)
    logits, state = model_zoo.decode_fn(cfg, params, state, batch, jnp.int32(0))
    logits, _ = model_zoo.decode_fn(cfg, params, state, batch, jnp.int32(1))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_smoke(arch):
    cfg = reduced(get_config(arch))
    params = model_zoo.init(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("pf", seq_len=32, global_batch=2, kind="prefill")
    batch = model_zoo.synth_batch(cfg, shape)["batch"]
    batch["tokens"] = batch["tokens"] % cfg.vocab
    logits = model_zoo.prefill_fn(cfg, params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_loss_decreases():
    """~100k-param model, a few optimizer steps: loss must go down."""
    from repro.train import loop as train_loop

    from repro.train.optimizer import AdamWConfig

    cfg = reduced(get_config("yi-6b"))
    tcfg = train_loop.TrainConfig(
        microbatches=2,
        adamw=AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=50))
    params, opt_state = train_loop.init_state(cfg, tcfg, jax.random.PRNGKey(1))
    step = jax.jit(train_loop.build_train_step(cfg, tcfg))
    rngnp = np.random.default_rng(0)
    toks = rngnp.integers(0, cfg.vocab, size=(4, 32))
    batch = {
        "tokens": jnp.asarray(toks, jnp.int32),
        "targets": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    losses = []
    for _ in range(12):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
