"""End-to-end system behaviour: the full GenASM read-mapping service with
checkpoint/restart fault tolerance, and accuracy vs the DP gold standard
(the paper's §4.10.2 analysis in miniature)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import dp_baseline, mapper, minimizer_index, oracle
from repro.core.genasm_tb import cigar_score
from repro.dist.fault import RestartableLoop, WorkQueue
from repro.genomics import encode, pipeline, simulate


def _setup(n_reads=24, seed=0):
    ref = simulate.random_reference(6000, seed=seed)
    idx = minimizer_index.build_reference_index(ref, w=8, k=12)
    rs = simulate.simulate_reads(ref, n_reads=n_reads, read_len=120,
                                 profile=simulate.ILLUMINA, seed=seed + 1)
    return ref, idx, rs


def test_mapping_service_with_workqueue():
    """Stateless batch mapping through the lease-based scheduler."""
    ref, idx, rs = _setup()
    batches = list(pipeline.ReadBatches(rs.reads, batch=8, cap=128))
    q = WorkQueue(len(batches), lease_s=60)
    done = {}
    while not q.finished:
        b = q.claim()
        if b is None:
            break
        _, arr, lens = batches[b]
        res = mapper.map_batch(idx, jnp.asarray(arr), jnp.asarray(lens),
                               p_cap=192, filter_bits=128, filter_k=16,
                               minimizer_w=8, minimizer_k=12)
        done[b] = np.asarray(res.position)
        q.complete(b)
    assert len(done) == len(batches)
    pos = np.concatenate([done[b] for b in sorted(done)])
    ok = np.abs(pos[: len(rs.true_pos)] - rs.true_pos) <= 16
    assert ok.mean() >= 0.75


def test_checkpoint_restart_resumes(tmp_path):
    """Kill the loop mid-run; restart resumes from the latest checkpoint."""
    mgr = CheckpointManager(tmp_path, keep=3)
    state = {"cursor": jnp.int32(0)}

    calls = []

    def step_fn(st, step):
        calls.append(step)
        if len(calls) == 5 and not getattr(step_fn, "resumed", False):
            raise RuntimeError("simulated node failure")
        return {"cursor": st["cursor"] + 1}

    loop = RestartableLoop(mgr, save_every=2)
    try:
        loop.run(state, step_fn, n_steps=10)
        assert False, "should have crashed"
    except RuntimeError:
        pass
    mgr.wait()
    assert mgr.latest_step() is not None
    step_fn.resumed = True
    final = loop.run(state, step_fn, n_steps=10)
    assert int(final["cursor"]) == 10


def test_genasm_score_parity_vs_dp():
    """Paper §4.10.2: GenASM alignment scores track the DP gold standard."""
    ref, idx, rs = _setup(n_reads=16, seed=3)
    reads, lens = encode.batch_reads(rs.reads, 128)
    res = mapper.map_batch(idx, jnp.asarray(reads), jnp.asarray(lens),
                           p_cap=192, filter_bits=128, filter_k=16,
                           minimizer_w=8, minimizer_k=12)
    pos = np.asarray(res.position)
    close = 0
    total = 0
    for i in range(16):
        if pos[i] < 0:
            continue
        total += 1
        g_score = int(cigar_score(jnp.asarray(np.asarray(res.ops)[i]),
                                  jnp.int32(int(np.asarray(res.n_ops)[i]))))
        region = np.full((192 + 128,), 4, np.int8)
        chunk = ref[pos[i]: pos[i] + 192 + 128]
        region[: len(chunk)] = chunk
        pbuf = np.full((192,), 0, np.int8)
        pbuf[: lens[i]] = reads[i, : lens[i]]
        dp = int(dp_baseline.affine_align_score(
            jnp.asarray(region), jnp.asarray(pbuf), jnp.int32(int(lens[i])),
            jnp.int32(len(chunk))))
        if dp != 0 and abs(g_score - dp) <= max(8, abs(dp) * 0.1):
            close += 1
    assert total >= 12
    assert close / total >= 0.8, f"{close}/{total}"
