"""Tile pre-filter soundness + ragged-gather/bucket-ladder properties.

The q-gram tile screen (`graph.mapper.tile_prefilter`) may only remove
candidate tiles that the exact GenASM-DC filter would reject anyway —
otherwise GAF output would change with the screen on.  This suite proves
that three ways:

  * **differential vs the exact filter** — no slot whose dense in-span
    DC distance is ≤ k is ever pruned, across edit budgets;
  * **differential vs the DP oracle** — the tile containing the
    oracle-best mapping (``oracle.graph_edit_distance_anchored``) is
    never pruned for any edit budget that admits that mapping;
  * **end-to-end** — prefilter on/off produce identical
    `GraphMapResult`s, including node paths, on mixed clean/mutated/
    garbage batches.

Plus the screen's monotonicity in k, the argsort-compaction round-trip
invariants (every survivor gathered exactly once, padding never
scattered into a live slot), the zero-survivor short-circuit, and the
serve engine's (read-length, tile-count) bucket ladder compiling once
per rung pair.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import filter as qfilter
from repro.core import oracle
from repro.core.genasm import GenASMConfig
from repro.core.segram import graph as cgraph
from repro.genomics import encode, simulate
from repro.graph import index as gindex
from repro.graph import mapper as gmapper
from repro.serve import EngineConfig, ServeEngine

CFG = GenASMConfig()
P_CAP = 128
T_CAP = P_CAP + 2 * CFG.w
FILTER_K = 12
L = 6_000
MAX_CAND = 4
SEED_KW = dict(minimizer_w=8, minimizer_k=12)


@pytest.fixture(scope="module")
def graph_setup():
    ref = simulate.random_reference(L, seed=41)
    variants = simulate.simulate_variants(ref, n_snp=20, n_ins=10,
                                          n_del=10, seed=42)
    gidx = gindex.build_graph_index(ref, variants, w=8, k=12,
                                    window=T_CAP)
    return ref, variants, gidx


def _mixed_reads(ref, *, seed, n_clean=6, n_mut=6, n_garbage=4,
                 read_len=100):
    """Clean / mutated / unmappable reads, encoded to [B, P_CAP]."""
    rng = np.random.default_rng(seed)
    reads = []
    for i in range(n_clean + n_mut):
        s = int(rng.integers(0, len(ref) - read_len))
        r = np.array(ref[s: s + read_len], np.int8)
        if i >= n_clean:
            subs = rng.integers(0, read_len, size=4)
            r[subs] = (r[subs] + 1 + rng.integers(0, 3, size=4)) % 4
        reads.append(r)
    for _ in range(n_garbage):
        reads.append(rng.integers(0, 4, read_len).astype(np.int8))
    return encode.batch_reads(reads, P_CAP)


def _pf_kw(gidx, filter_k=FILTER_K, prefilter=True):
    return dict(tile_stride=gidx.tile_stride, n_tiles=gidx.n_tiles,
                backbone_len=gidx.arrays.node_of_backbone.shape[0],
                filter_bits=P_CAP, filter_k=filter_k,
                max_candidates=MAX_CAND, prefilter=prefilter, **SEED_KW)


def _dense_slot_dists(gidx, arr, lens, pf, filter_k):
    """Every slot's dense in-span DC distance (the exact filter verdict)."""
    view = gmapper.whole_graph_view(gidx.arrays)
    b, c = pf.votes.shape
    _, tile_len = view.tile_gtext.shape
    tile_g, tile_local = gmapper._tiles_of_starts(
        view, pf.starts, tile_stride=gidx.tile_stride, n_tiles=gidx.n_tiles,
        backbone_len=gidx.arrays.node_of_backbone.shape[0])
    fpat, flens = gmapper._filter_pattern(jnp.asarray(arr),
                                          jnp.asarray(lens, jnp.int32),
                                          P_CAP)
    wins = view.tile_gtext[tile_local]
    dists = gmapper._filter_dists(
        wins.reshape(b * c, tile_len), jnp.repeat(fpat, c, axis=0),
        jnp.repeat(flens, c), m_bits=P_CAP, k=filter_k, use_kernel=False,
        block_bt=None, interpret=True).reshape(b, c, tile_len)
    span_ok = jnp.arange(tile_len) < tile_len - T_CAP
    dists = jnp.where(span_ok[None, None, :], dists, filter_k + 1)
    return np.asarray(jnp.min(dists, axis=-1)), np.asarray(tile_g)


# ------------------------------------------------------------- soundness --
@pytest.mark.parametrize("filter_k", [4, 8, 12])
def test_screen_never_prunes_dc_passing_tiles(graph_setup, filter_k):
    """Differential vs the exact filter: prune ⇒ dense DC distance > k,
    for every candidate slot, at every edit budget."""
    ref, _, gidx = graph_setup
    arr, lens = _mixed_reads(ref, seed=50 + filter_k)
    view = gmapper.whole_graph_view(gidx.arrays)
    pf = gmapper.tile_prefilter(view, jnp.asarray(arr),
                                jnp.asarray(lens, jnp.int32),
                                **_pf_kw(gidx, filter_k=filter_k))
    d_slot, _ = _dense_slot_dists(gidx, arr, lens, pf, filter_k)
    live = np.asarray(pf.votes) > 0
    keep = np.asarray(pf.keep)
    # every live slot the exact filter accepts must survive the screen
    bad = live & (d_slot <= filter_k) & ~keep
    assert not bad.any(), \
        f"screen pruned DC-passing slots at k={filter_k}: {np.argwhere(bad)}"
    # and the screen must actually be a subset of live
    assert not (keep & ~live).any()


def test_oracle_best_tile_never_pruned(graph_setup):
    """The tile holding the oracle-best anchored mapping survives the
    screen at every edit budget ≥ the oracle distance."""
    ref, variants, gidx = graph_setup
    g = cgraph.build_graph(ref, list(variants))  # the index's own graph
    rng = np.random.default_rng(77)
    view = gmapper.whole_graph_view(gidx.arrays)
    nob = np.asarray(gidx.arrays.node_of_backbone)
    checked = 0
    reads, anchors = [], []
    for _ in range(12):
        p = int(rng.integers(0, L - 200))
        m = int(rng.integers(60, 96))
        read = np.array(ref[p: p + m], np.int8)
        n_sub = int(rng.integers(0, 4))
        for _ in range(n_sub):
            j = int(rng.integers(0, m))
            read[j] = (read[j] + 1 + rng.integers(0, 3)) % 4
        reads.append(read)
        anchors.append(p)
    arr, lens = encode.batch_reads(reads, P_CAP)

    # oracle-anchored distance of each read at its true backbone locus
    tile_stride = gidx.tile_stride
    for k in (6, 12):
        pf = gmapper.tile_prefilter(view, jnp.asarray(arr),
                                    jnp.asarray(lens, jnp.int32),
                                    **_pf_kw(gidx, filter_k=k))
        tile_g, _ = gmapper._tiles_of_starts(
            view, pf.starts, tile_stride=tile_stride, n_tiles=gidx.n_tiles,
            backbone_len=nob.shape[0])
        tile_g = np.asarray(tile_g)
        live = np.asarray(pf.votes) > 0
        keep = np.asarray(pf.keep)
        for i, (read, p) in enumerate(zip(reads, anchors)):
            node = int(nob[p])
            sub_b, sub_s = cgraph.extract_subgraph(g, node, T_CAP)
            sub = cgraph.GenomeGraph(sub_b, sub_s,
                                     np.zeros(T_CAP, np.int32),
                                     np.zeros(0, np.int32))
            d_star = oracle.graph_edit_distance_anchored(
                read, sub_b, cgraph.predecessors(sub), start=0)
            if d_star > k:
                continue  # budget does not admit the mapping
            true_tile = node // tile_stride
            hit = live[i] & (tile_g[i] == true_tile)
            if not hit.any():
                continue  # seeding never offered the true tile
            assert keep[i][hit].any(), \
                (f"read {i}: oracle d*={d_star} ≤ k={k} but every slot of "
                 f"tile {true_tile} was pruned")
            checked += 1
    assert checked >= 10  # the property was actually exercised


def test_screen_monotone_in_k(graph_setup):
    """keep(k₁) ⊆ keep(k₂) for k₁ ≤ k₂ — raising the budget never
    prunes more."""
    ref, _, gidx = graph_setup
    arr, lens = _mixed_reads(ref, seed=60)
    view = gmapper.whole_graph_view(gidx.arrays)
    prev = None
    for k in (2, 4, 8, 12, 16):
        pf = gmapper.tile_prefilter(view, jnp.asarray(arr),
                                    jnp.asarray(lens, jnp.int32),
                                    **_pf_kw(gidx, filter_k=k))
        keep = np.asarray(pf.keep)
        if prev is not None:
            assert not (prev & ~keep).any(), f"screen not monotone at k={k}"
        prev = keep


def test_prefilter_on_off_results_identical(graph_setup):
    """Full GraphMapResult equality — positions, distances, CIGAR ops,
    node paths, failure flags — with the screen on and off."""
    ref, _, gidx = graph_setup
    arr, lens = _mixed_reads(ref, seed=70)
    kw = dict(cfg=CFG, p_cap=P_CAP, filter_bits=P_CAP, filter_k=FILTER_K,
              max_candidates=MAX_CAND, backend="graph_lax", **SEED_KW)
    on = gmapper.map_batch_index(gidx, jnp.asarray(arr), jnp.asarray(lens),
                                 prefilter=True, **kw)
    off = gmapper.map_batch_index(gidx, jnp.asarray(arr), jnp.asarray(lens),
                                  prefilter=False, **kw)
    for f in on._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(on, f)), np.asarray(getattr(off, f)),
            err_msg=f"prefilter on/off diverge on {f}")
    assert (np.asarray(on.position) >= 0).sum() >= 10  # batch actually maps


# ------------------------------------------- ragged gather / compaction --
def test_compaction_round_trip_invariants():
    """The argsort compaction gathers every survivor exactly once, in
    slot order, and scatter-back touches only survivor slots."""
    rng = np.random.default_rng(5)
    b, c = 16, 4
    bc = b * c
    keep = rng.random((b, c)) < 0.3
    kf = keep.reshape(bc)
    n_tot = int(kf.sum())
    n_cap = gmapper.tile_rung(n_tot, bc)
    # the stage's exact compaction arithmetic
    order = np.argsort(np.where(kf, 0, bc) + np.arange(bc), kind="stable")
    slots = order[:n_cap]
    # every survivor appears exactly once, before any non-survivor,
    # in increasing slot order
    assert n_cap >= n_tot
    assert sorted(slots[:n_tot]) == list(np.flatnonzero(kf))
    assert (np.diff(slots[:n_tot]) > 0).all()
    assert not kf[slots[n_tot:]].any()  # tail rows are padding only
    # scatter-back: padding rows write the dense defaults, so only
    # survivor slots can carry a real distance
    d_r = rng.integers(0, FILTER_K + 1, n_cap)
    rowmask = np.arange(n_cap) < n_tot
    d_c = np.full(bc, FILTER_K + 1)
    d_c[slots] = np.where(rowmask, d_r, FILTER_K + 1)
    assert (d_c[~kf] == FILTER_K + 1).all(), "padding scattered into a slot"
    assert (d_c[slots[:n_tot]] == d_r[:n_tot]).all()


def test_compacted_stage_matches_dense_at_any_rung(graph_setup):
    """graph_candidate_stage with pf/n_cap equals the dense legacy path
    on every winner field, at the high-water rung and at full cap."""
    ref, _, gidx = graph_setup
    arr, lens = _mixed_reads(ref, seed=80)
    view = gmapper.whole_graph_view(gidx.arrays)
    skw = dict(tile_stride=gidx.tile_stride, n_tiles=gidx.n_tiles,
               backbone_len=gidx.arrays.node_of_backbone.shape[0],
               n_nodes=gidx.n_nodes, t_cap=T_CAP, filter_bits=P_CAP,
               filter_k=FILTER_K, max_candidates=MAX_CAND, **SEED_KW)
    reads_j = jnp.asarray(arr)
    lens_j = jnp.asarray(lens, jnp.int32)
    dense = gmapper.graph_candidate_stage(view, reads_j, lens_j, **skw)
    pf = gmapper.tile_prefilter(view, reads_j, lens_j, **_pf_kw(gidx))
    total = int(np.asarray(pf.n_keep).sum())
    assert total > 0
    b = arr.shape[0]
    for n_cap in (gmapper.tile_rung(total, b * MAX_CAND), b * MAX_CAND):
        comp = gmapper.graph_candidate_stage(view, reads_j, lens_j, pf=pf,
                                             n_cap=n_cap, **skw)
        for f in ("distance", "origin", "tile", "t_len", "prefilter_ok"):
            np.testing.assert_array_equal(
                np.asarray(getattr(dense, f)), np.asarray(getattr(comp, f)),
                err_msg=f"compacted stage (n_cap={n_cap}) diverges on {f}")
        # window bytes agree wherever a live winner exists (dead winners
        # carry garbage that align_winners canonicalizes away)
        ok = np.asarray(dense.distance) <= FILTER_K
        np.testing.assert_array_equal(np.asarray(dense.gwin)[ok],
                                      np.asarray(comp.gwin)[ok])
        np.testing.assert_array_equal(np.asarray(dense.bwin)[ok],
                                      np.asarray(comp.bwin)[ok])


def test_tile_rung_ladder():
    assert gmapper.tile_rung(0, 128) == 0
    assert gmapper.tile_rung(-3, 128) == 0
    assert gmapper.tile_rung(1, 128) == 8  # floor rung
    assert gmapper.tile_rung(8, 128) == 8
    assert gmapper.tile_rung(9, 128) == 16
    assert gmapper.tile_rung(100, 128) == 128
    assert gmapper.tile_rung(500, 128) == 128  # clamped to dense cap
    for n in range(1, 130):
        r = gmapper.tile_rung(n, 128)
        assert r >= min(n, 128)  # never smaller than the survivors


# --------------------------------------------- zero-survivor short-circuit --
def test_zero_survivor_batch_short_circuits(graph_setup):
    """A batch where no read has surviving candidates skips DC and align
    entirely and still equals the prefilter-off result bitwise."""
    ref, _, gidx = graph_setup
    rng = np.random.default_rng(90)
    reads = [rng.integers(0, 4, 100).astype(np.int8) for _ in range(6)]
    arr, lens = encode.batch_reads(reads, P_CAP)
    kw = dict(tile_stride=gidx.tile_stride, cfg=CFG, p_cap=P_CAP,
              filter_bits=P_CAP, filter_k=FILTER_K,
              max_candidates=MAX_CAND, backend="graph_lax", **SEED_KW)
    ex_on = gmapper.GraphMapExecutor(prefilter=True, **kw)
    ex_off = gmapper.GraphMapExecutor(prefilter=False, **kw)
    r_on = ex_on(gidx.arrays, jnp.asarray(arr), jnp.asarray(lens))
    assert ex_on.last_stats["dc_rows"] == 0  # no DC launch at all
    assert ex_on.last_stats["reads_zero_survivor"] == len(reads)
    assert np.asarray(r_on.failed).all()
    assert (np.asarray(r_on.position) == -1).all()
    assert (np.asarray(r_on.n_ops) == 0).all()
    r_off = ex_off(gidx.arrays, jnp.asarray(arr), jnp.asarray(lens))
    for f in r_on._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r_on, f)), np.asarray(getattr(r_off, f)),
            err_msg=f"zero-survivor short-circuit diverges on {f}")


def test_mixed_batch_zero_survivor_reads_stat(graph_setup):
    """Zero-survivor *reads* inside a live batch are counted and mapped
    to the canonical unmapped result."""
    ref, _, gidx = graph_setup
    rng = np.random.default_rng(91)
    reads = [np.array(ref[500:600], np.int8),
             rng.integers(0, 4, 100).astype(np.int8)]
    arr, lens = encode.batch_reads(reads, P_CAP)
    kw = dict(tile_stride=gidx.tile_stride, cfg=CFG, p_cap=P_CAP,
              filter_bits=P_CAP, filter_k=FILTER_K,
              max_candidates=MAX_CAND, backend="graph_lax", **SEED_KW)
    ex = gmapper.GraphMapExecutor(prefilter=True, **kw)
    res = ex(gidx.arrays, jnp.asarray(arr), jnp.asarray(lens))
    assert ex.last_stats["reads_zero_survivor"] >= 1
    assert 0 < ex.last_stats["dc_rows"] <= ex.last_stats["dc_rows_dense"]
    assert int(res.position[0]) == 500 and int(res.distance[0]) == 0
    assert bool(res.failed[1]) and int(res.n_ops[1]) == 0


# ----------------------------------------------------- serve bucket ladder --
def test_engine_graph_ladder_compiles_once_per_rung(graph_setup):
    """The engine's graph executors trace once per (read-length rung,
    tile-count rung) pair — prefilter/align once per cap, candidate
    stage once per rung — and never retrace on repeat traffic."""
    ref, variants, _ = graph_setup
    egi = gindex.build_epoched_graph_index(ref, variants, w=8, k=12,
                                           window=192 + 2 * CFG.w)
    cfg = EngineConfig(buckets=(96, 192), max_batch=4, workload="graph",
                       filter_k=10, cache_capacity=0, **SEED_KW)
    rs_short = simulate.simulate_reads(ref, n_reads=8, read_len=90,
                                       profile=simulate.ILLUMINA, seed=14)
    rs_long = simulate.simulate_reads(ref, n_reads=8, read_len=180,
                                      profile=simulate.ILLUMINA, seed=15)
    with ServeEngine(egi, cfg) as eng:
        eng.map_all(list(rs_short.reads) + list(rs_long.reads))
        first = dict(eng.trace_counts)
        # both caps traced their prefilter + align exactly once, plus at
        # least one tile-count rung each
        for cap in (96, 192):
            assert first.get((cap, "prefilter")) == 1
            assert first.get((cap, "align")) == 1
            rungs = [k for k in first if k[0] == cap
                     and isinstance(k[1], int)]
            assert rungs, f"no candidate-stage rung traced for cap {cap}"
        assert all(v == 1 for v in first.values()), first
        # repeat traffic of the same shape: no retraces, no new rungs
        eng.map_all(list(rs_short.reads) + list(rs_long.reads))
        assert eng.trace_counts == first
    assert {k[1] for k in eng._executors} == {"graph"}


def test_engine_graph_prefilter_metrics(graph_setup):
    """Graph flushes export the screen/occupancy counters."""
    ref, _, gidx = graph_setup
    egi = gindex.EpochedGraphIndex(gidx)
    cfg = EngineConfig(buckets=(128,), max_batch=4, workload="graph",
                       filter_k=10, **SEED_KW)
    rs = simulate.simulate_reads(ref, n_reads=8, read_len=100,
                                 profile=simulate.ILLUMINA, seed=16)
    with ServeEngine(egi, cfg) as eng:
        eng.map_all(list(rs.reads))
        snap = eng.metrics.snapshot()
    assert snap["graph_candidate_slots"] > 0
    assert snap["graph_tiles_kept"] <= snap["graph_tiles_live"]
    assert snap["graph_dc_rows"] <= snap["graph_dc_rows_dense"]


# ------------------------------------------------------ q-gram primitives --
def test_qgram_bloom_has_no_false_negatives():
    """Every q-gram actually present in the indexed text is confirmed
    (Bloom filters have one-sided error only)."""
    rng = np.random.default_rng(7)
    text = jnp.asarray(rng.integers(0, 4, 300).astype(np.int8))
    bloom = qfilter.qgram_bloom(text, 300)
    codes = qfilter.qgram_codes(text)
    pos_ok = jnp.ones(codes.shape, bool)
    hits = qfilter.qgram_hits(codes, pos_ok, bloom)
    assert int(hits) == codes.shape[0]


def test_qgram_min_hits_bound():
    """The q-gram lemma threshold: m-q+1 - q·k, minus graph slack."""
    q = qfilter.QGRAM_Q
    assert int(qfilter.qgram_min_hits(93, 4, 0)) == 93 - q * 4
    assert int(qfilter.qgram_min_hits(93, 4, 10)) == 93 - q * 4 - 10
    # non-positive bound ⇒ cannot prune (any hit count passes)
    assert int(qfilter.qgram_min_hits(10, 12, 0)) <= 0
