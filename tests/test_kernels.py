"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("w,k,bt", [(64, 24, 8), (64, 8, 4), (96, 16, 8),
                                    (128, 24, 4)])
def test_genasm_dc_kernel_matches_ref(rng, w, k, bt):
    b = 2 * bt
    texts = rng.integers(0, 5, size=(b, w)).astype(np.int8)
    pats = rng.integers(0, 5, size=(b, w)).astype(np.int8)
    d_k, tb_k = ops.window_dc(jnp.asarray(texts), jnp.asarray(pats), w=w, k=k,
                              block_bt=bt)
    d_r, tb_r = ref.window_dc_batch(jnp.asarray(texts), jnp.asarray(pats), w=w, k=k)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(tb_k), np.asarray(tb_r))


def test_genasm_dc_kernel_pads_ragged_batch(rng):
    texts = rng.integers(0, 4, size=(5, 64)).astype(np.int8)
    pats = rng.integers(0, 4, size=(5, 64)).astype(np.int8)
    d, tb = ops.window_dc(jnp.asarray(texts), jnp.asarray(pats), block_bt=4)
    assert d.shape == (5,)
    assert tb.shape[0] == 5


@pytest.mark.parametrize("m_bits,mode", [(32, "global"), (64, "global"),
                                         (64, "semiglobal"), (128, "semiglobal")])
def test_myers_kernel_matches_ref(rng, m_bits, mode):
    b, n = 8, 96
    texts = rng.integers(0, 4, size=(b, n)).astype(np.int8)
    pats = np.full((b, m_bits), 4, np.int8)
    lens = rng.integers(4, min(m_bits, 60), size=(b,)).astype(np.int32)
    for i in range(b):
        pats[i, : lens[i]] = rng.integers(0, 4, size=lens[i])
    dk = ops.myers_distance(jnp.asarray(texts), jnp.asarray(pats),
                            jnp.asarray(lens), m_bits=m_bits, mode=mode,
                            block_bt=4)
    dr = ref.myers_distance_batch(jnp.asarray(texts), jnp.asarray(pats),
                                  jnp.asarray(lens), m_bits=m_bits, mode=mode)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


def test_kernel_wildcards_and_sentinels(rng):
    """Wildcard pattern chars match everything incl. text sentinels."""
    texts = np.full((8, 64), 4, np.int8)  # all-sentinel text
    pats = np.full((8, 64), 4, np.int8)  # all-wildcard pattern
    d, _ = ops.window_dc(jnp.asarray(texts), jnp.asarray(pats), block_bt=8)
    np.testing.assert_array_equal(np.asarray(d), 0)


@pytest.mark.parametrize("w,k,bt", [(64, 24, 8), (64, 16, 4), (96, 16, 8)])
def test_genasm_dc_v2_kernel_matches_ref(rng, w, k, bt):
    b = 2 * bt
    texts = rng.integers(0, 5, size=(b, w)).astype(np.int8)
    pats = rng.integers(0, 5, size=(b, w)).astype(np.int8)
    d_k, r_k = ops.window_dc_v2(jnp.asarray(texts), jnp.asarray(pats), w=w, k=k,
                                block_bt=bt)
    d_r, r_r = ref.window_dc_batch_v2(jnp.asarray(texts), jnp.asarray(pats),
                                      w=w, k=k)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))


def test_v2_store_is_3x_smaller():
    """The §Perf #8 claim: R-only store ≈ 1/3 of the M/I/D store."""
    w, k, nw = 64, 24, 2
    v1 = w * (k + 1) * 3 * nw * 4
    v2 = (w + 1) * (k + 1) * nw * 4
    assert v1 / v2 > 2.9


@pytest.mark.parametrize("m_bits,k", [(64, 10), (96, 8)])
def test_bitalign_kernel_matches_ref(rng, m_bits, k):
    from repro.core.segram import graph
    from repro.genomics import simulate

    B, N = 8, 80
    bases = np.zeros((B, N), np.int8)
    succ = np.zeros((B, N), np.uint32)
    pats = np.full((B, m_bits), 4, np.int8)
    plens = np.zeros((B,), np.int32)
    for i in range(B):
        refseq = rng.integers(0, 4, size=N - 10).astype(np.int8)
        variants = simulate.simulate_variants(refseq, n_snp=2, n_ins=1,
                                              n_del=1, seed=i)
        g = graph.build_graph(refseq, variants)
        bases[i], succ[i] = graph.extract_subgraph(g, 0, N)
        m = int(rng.integers(10, min(40, m_bits - 2)))
        pats[i, :m] = refseq[:m]
        plens[i] = m
    dk, rk_ = ops.bitalign_dc(jnp.asarray(bases), jnp.asarray(succ),
                              jnp.asarray(pats), jnp.asarray(plens),
                              m_bits=m_bits, k=k, block_bt=4)
    dr, rr = ref.bitalign_dc_batch(jnp.asarray(bases), jnp.asarray(succ),
                                   jnp.asarray(pats), jnp.asarray(plens),
                                   m_bits=m_bits, k=k)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(rk_), np.asarray(rr))
