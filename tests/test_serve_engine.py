"""repro.serve: buckets, deadline flush, executor/result caches, pipeline."""
import time

import numpy as np
import pytest

from repro.core import minimizer_index
from repro.genomics import pipeline, simulate
from repro.launch import serve_genomics
from repro.serve import EngineConfig, ResultCache, ServeEngine
from repro.serve.metrics import Metrics


@pytest.fixture(scope="module")
def ref():
    return simulate.random_reference(4000, seed=11)


@pytest.fixture(scope="module")
def epi(ref):
    return minimizer_index.build_epoched_index(ref, w=8, k=12)


@pytest.fixture(scope="module")
def reads(ref):
    short = simulate.simulate_reads(ref, n_reads=10, read_len=90,
                                    profile=simulate.ILLUMINA, seed=3)
    long = simulate.simulate_reads(ref, n_reads=2, read_len=150,
                                   profile=simulate.ILLUMINA, seed=4)
    return short, long


@pytest.fixture(scope="module")
def engine(epi):
    cfg = EngineConfig(buckets=(96, 192), max_batch=4, max_delay_s=0.02,
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    eng = ServeEngine(epi, cfg)
    yield eng
    eng.close()


def test_bucket_selection_and_validation():
    cfg = EngineConfig(buckets=(160, 96))  # unsorted on purpose
    assert cfg.buckets == (96, 160)
    assert cfg.bucket_for(1) == 96
    assert cfg.bucket_for(96) == 96
    assert cfg.bucket_for(97) == 160
    assert cfg.bucket_for(500) == 160  # beyond the ladder: trim to top rung
    with pytest.raises(ValueError):
        EngineConfig(buckets=(100,))  # not a multiple of 32
    with pytest.raises(ValueError):
        EngineConfig(buckets=())


def test_engine_maps_and_accounts_occupancy(engine, reads):
    short, long = reads
    res = engine.map_all(list(short.reads) + list(long.reads))
    ok = sum(abs(r.position - tp) <= 16
             for r, tp in zip(res, list(short.true_pos) + list(long.true_pos)))
    assert ok >= 10  # ≥80% placed at 5% error
    assert {r.bucket_cap for r in res} == {96, 192}
    m = engine.metrics.snapshot()
    # every admitted base is either useful or accounted padding
    total = sum(min(r.read_len, r.bucket_cap) for r in res)
    assert m["bases_useful"] == total
    assert m["bases_padded_read"] == sum(
        r.bucket_cap - min(r.read_len, r.bucket_cap) for r in res)
    assert m["batch_occupancy_count"] == m["batches_flushed"] >= 3
    assert 0.0 < m["batch_occupancy_mean"] <= 1.0


def test_executor_cache_one_trace_per_bucket(engine, reads):
    short, long = reads
    engine.map_all(list(short.reads))  # repeat traffic into both buckets
    engine.map_all(list(long.reads))
    assert engine.n_executors == 2  # one per (bucket_cap, config)
    # linear executors trace their seed_filter and align stages once per
    # bucket cap (the two-jit split that makes stage timing observable)
    assert engine.trace_counts == {
        (96, "seed_filter"): 1, (96, "align"): 1,
        (192, "seed_filter"): 1, (192, "align"): 1}


class _FakeClock:
    """Deterministic monotonic clock the test advances by hand.

    The engine worker re-polls its deadline at least every 50ms of real
    time, so a fake-clock advance is observed promptly without the test
    ever racing a real wall-clock deadline."""

    def __init__(self):
        import threading
        self._t = 0.0
        self._lock = threading.Lock()

    def __call__(self):
        with self._lock:
            return self._t

    def advance(self, dt):
        with self._lock:
            self._t += dt


def test_deadline_triggered_flush(epi, reads):
    short, _ = reads
    clk = _FakeClock()
    cfg = EngineConfig(buckets=(96,), max_batch=8, max_delay_s=0.03,
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    with ServeEngine(epi, cfg, clock=clk) as eng:
        futs = [eng.submit(r) for r in short.reads[:3]]
        # fake time is frozen before the deadline: the partial batch
        # must stay parked no matter how long compile/dispatch takes
        time.sleep(0.15)
        assert not any(f.done() for f in futs)
        clk.advance(1.0)  # past max_delay_s → deadline flush
        res = [f.result(timeout=30) for f in futs]  # flushes despite 3 < 8
    assert all(r.position >= 0 or r.position == -1 for r in res)
    m = eng.metrics.snapshot()
    assert m["batches_flushed"] == 1
    assert m["batch_occupancy_mean"] == pytest.approx(3 / 8)


def test_result_cache_hit_and_epoch_invalidation(ref, reads):
    short, _ = reads
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
    cfg = EngineConfig(buckets=(96,), max_batch=4, max_delay_s=0.005,
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    with ServeEngine(epi, cfg) as eng:
        r0 = eng.map_all([short.reads[0]])[0]
        assert not r0.cached
        r1 = eng.map_all([short.reads[0]])[0]
        assert r1.cached
        assert (r1.position, r1.distance) == (r0.position, r0.distance)
        assert eng.cache.hits == 1
        epoch0 = epi.epoch
        assert epi.refresh(ref) == epoch0 + 1  # same bases, new epoch
        r2 = eng.map_all([short.reads[0]])[0]
        assert not r2.cached  # old-epoch entry is unreachable
        assert r2.position == r0.position


def test_worker_exception_fails_futures_not_hangs(epi, reads):
    short, _ = reads
    cfg = EngineConfig(buckets=(96,), max_batch=4, max_delay_s=0.005,
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    eng = ServeEngine(epi, cfg)

    def boom(cap):
        raise RuntimeError("executor boom")

    eng._executor = boom
    fut = eng.submit(short.reads[0])
    with pytest.raises(RuntimeError):  # resolved with the error, no hang
        fut.result(timeout=30)
    with pytest.raises(RuntimeError):  # engine refuses new work after death
        eng.submit(short.reads[1])
    eng.close()  # shutdown of a dead engine is still clean
    assert not eng._worker.is_alive()


def test_engine_rejects_mismatched_minimizer_params(ref):
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
    with pytest.raises(ValueError, match="minimizer"):
        # engine seeds with the 10/15 defaults; index was built 8/12
        ServeEngine(epi, EngineConfig(buckets=(96,)))


def test_result_cache_unit():
    c = ResultCache(capacity=2)
    a, b, d = (np.full(4, i, np.int8) for i in range(3))
    c.put(a, 0, "A")
    c.put(b, 0, "B")
    assert c.get(a, 0) == "A" and c.get(a, 1) is None  # epoch is part of key
    c.put(d, 0, "D")  # evicts b (a was touched more recently)
    assert c.get(b, 0) is None and c.get(a, 0) == "A"
    assert c.evict_epochs_below(1) == 2 and len(c) == 0
    disabled = ResultCache(capacity=0)
    disabled.put(a, 0, "A")
    assert disabled.get(a, 0) is None
    assert 0.0 <= c.hit_rate <= 1.0


def test_metrics_histogram_and_render():
    m = Metrics()
    h = m.histogram("latency_s")
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    assert h.count == 4
    assert h.quantile(0.5) <= h.quantile(0.99)
    assert h.quantile(0.99) >= 0.05  # p99 lands near the outlier
    m.counter("reads_submitted").inc(3)
    text = m.render()
    assert "reads_submitted 3" in text
    assert "latency_s_p99" in text


def test_prefetcher_propagates_worker_exception():
    def bad():
        yield 0, np.zeros((2, 4), np.int8), np.zeros(2, np.int32)
        raise ValueError("boom")

    pf = pipeline.Prefetcher(bad(), device_put=lambda x: x)
    it = iter(pf)
    assert next(it)[0] == 0
    with pytest.raises(ValueError, match="boom"):
        next(it)
    pf.close()
    assert not pf._t.is_alive()


def test_prefetcher_close_mid_stream():
    def endless():
        i = 0
        while True:
            yield i, np.zeros((1, 4), np.int8), np.ones(1, np.int32)
            i += 1

    with pipeline.Prefetcher(endless(), device_put=lambda x: x, depth=1) as pf:
        assert next(iter(pf))[0] == 0
    deadline = time.monotonic() + 5.0
    while pf._t.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not pf._t.is_alive()  # close() joined the worker


def test_strip_gids():
    rows = [{"gid": 3, "qname": "anything", "tstart": 7}]
    assert serve_genomics.strip_gids(rows) == [{"qname": "anything",
                                               "tstart": 7}]


def test_offline_online_identical_paf(tmp_path):
    common = ["--ref-len", "4000", "--reads", "10", "--read-len", "100",
              "--batch", "4", "--buckets", "128"]
    p_off, p_on = tmp_path / "off.paf", tmp_path / "on.paf"
    serve_genomics.main(common + ["--out", str(p_off)])
    serve_genomics.main(common + ["--online", "--rate", "500",
                                  "--out", str(p_on)])
    off, on = p_off.read_text(), p_on.read_text()
    assert off == on
    assert off.count("\n") >= 8  # most of the 10 reads mapped
    assert "gid" not in off  # stripped before write_paf


def test_executor_cache_keyed_on_align_backend(epi, reads):
    """Switching align backends must never reuse a stale compiled
    executor: the cache key carries the resolved backend name."""
    short, _ = reads
    cfg = EngineConfig(buckets=(96,), max_batch=4, align_backend="lax",
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    with ServeEngine(epi, cfg) as eng:
        assert eng.align_backend == "lax"
        r_lax = eng.map_all(list(short.reads[:4]))
        keys_lax = set(eng._executors)
    cfg2 = EngineConfig(buckets=(96,), max_batch=4,
                        align_backend="pallas_dc_v2", filter_k=10,
                        minimizer_w=8, minimizer_k=12)
    with ServeEngine(epi, cfg2) as eng2:
        assert eng2.align_backend == "pallas_dc_v2"
        r_pal = eng2.map_all(list(short.reads[:4]))
        assert set(eng2._executors) != keys_lax
    # same reads, same results, different backend underneath
    assert [(r.position, r.distance) for r in r_lax] == \
        [(r.position, r.distance) for r in r_pal]


def test_map_stream_over_prefetcher(epi, reads):
    """genomics.pipeline.map_stream: batches → MapResults via dispatch."""
    short, _ = reads
    idx = epi.index
    batches = pipeline.ReadBatches(list(short.reads), batch=4, cap=96)
    got = {}
    with pipeline.Prefetcher(iter(batches)) as pf:
        for b, res in pipeline.map_stream(idx, pf, backend="lax", p_cap=128,
                                          filter_bits=96, filter_k=12,
                                          minimizer_w=8, minimizer_k=12):
            got[b] = np.asarray(res.position)
    assert sorted(got) == [0, 1, 2]
    pos = np.concatenate([got[b] for b in sorted(got)])[:len(short.true_pos)]
    assert (np.abs(pos - short.true_pos) <= 16).mean() >= 0.7
