"""Cross-backend conformance suite for `repro.align` (DESIGN.md §9).

Differential testing in the Alser et al. sense: every registered backend
runs the same inputs and must agree — `ref` (exact DP oracle with
traceback) against `core/oracle`, and the windowed backends (`lax`,
`pallas_dc`, `pallas_dc_v2`) bit-for-bit against each other, with every
emitted CIGAR validated by `core/oracle.check_cigar`.

Distance-vs-oracle tiers (windowed GenASM is greedy per window):

  * substitution-only injections — *exact* equality (pinned empirically
    over 900 seeds across all geometries below);
  * mixed substitution/indel injections — oracle ≤ reported ≤ oracle + 3
    when the aligner succeeds (the paper's §4.10.2 slack), CIGAR always
    internally consistent.

``REPRO_ALIGN_BACKEND`` (the CI matrix knob) narrows the parametrized
backend list to one name.  Shapes are held static per config so each
(backend, cfg) pair compiles once; raggedness lives in the length
arrays (sentinel-padded tails), not the shapes.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import align
from repro.align import inputs
from repro.core import oracle
from repro.core.genasm import GenASMConfig

# k ∈ {0, 4, 24} × W ∈ {32, 64} (o pinned to keep commit = w - o positive)
CONFIGS = {
    "w32_k0": GenASMConfig(w=32, o=8, k=0),
    "w32_k4": GenASMConfig(w=32, o=8, k=4),
    "w32_k24": GenASMConfig(w=32, o=24, k=24),
    "w64_k0": GenASMConfig(w=64, o=24, k=0),
    "w64_k4": GenASMConfig(w=64, o=16, k=4),
    "w64_k24": GenASMConfig(w=64, o=24, k=24),
}
P_CAP, T_CAP = 160, 224  # one static shape → one compile per (backend, cfg)

_env = os.environ.get("REPRO_ALIGN_BACKEND")
BACKENDS = (_env,) if _env else align.available_backends()
WINDOWED = tuple(b for b in BACKENDS if b != "ref")


def _run(backend, cfg, texts, pats, p_lens, t_lens, block_bt=None):
    return align.align_batch(
        jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
        jnp.asarray(t_lens), cfg=cfg, backend=backend, p_cap=P_CAP,
        block_bt=block_bt)


def _check_cigars(res, pairs, backend):
    dist = np.asarray(res.distance)
    ops = np.asarray(res.ops)
    n_ops = np.asarray(res.n_ops)
    for i, (pattern, text) in enumerate(pairs):
        if dist[i] < 0:
            continue
        err = oracle.check_cigar(ops[i], int(n_ops[i]), pattern, text,
                                 int(dist[i]))
        assert err is None, f"{backend}: pair {i}: {err}"


def _ragged_pairs(rng, *, n_sub, n_ins, n_del, n_pairs=5):
    """Ragged lengths (including a length well below one window)."""
    pairs = []
    for _ in range(n_pairs):
        m = int(rng.integers(12, P_CAP - 24))
        pairs.append(inputs.mutated_pair(
            rng, m, n_sub=min(n_sub, m // 4), n_ins=n_ins, n_del=n_del,
            t_extra=40))
    return pairs


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("cfg_name", sorted(CONFIGS))
def test_subs_only_distance_exact(backend, cfg_name, rng):
    """Substitution-only injections: distance == DP oracle, CIGAR valid."""
    cfg = CONFIGS[cfg_name]
    pairs = _ragged_pairs(rng, n_sub=cfg.k, n_ins=0, n_del=0)
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, T_CAP)
    res = _run(backend, cfg, texts, pats, p_lens, t_lens)
    dist = np.asarray(res.distance)
    for i, (pattern, text) in enumerate(pairs):
        want = oracle.levenshtein_prefix(pattern, text)
        assert dist[i] == want, f"pair {i}: want {want} got {dist[i]}"
    _check_cigars(res, pairs, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_indel_mix_within_slack(backend, rng):
    """Mixed sub/indel injections: bounded slack, CIGAR always consistent."""
    cfg = CONFIGS["w64_k24"]
    pairs = _ragged_pairs(rng, n_sub=3, n_ins=2, n_del=2, n_pairs=6)
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, T_CAP)
    res = _run(backend, cfg, texts, pats, p_lens, t_lens)
    dist = np.asarray(res.distance)
    for i, (pattern, text) in enumerate(pairs):
        want = oracle.levenshtein_prefix(pattern, text)
        if backend == "ref":
            assert dist[i] == want
        else:
            assert dist[i] >= 0, f"pair {i} failed with only 7 edits"
            assert want <= dist[i] <= want + 3, \
                f"pair {i}: oracle {want} got {dist[i]}"
    _check_cigars(res, pairs, backend)


@pytest.mark.parametrize("cfg_name", ["w32_k4", "w64_k24"])
def test_windowed_backends_bit_identical(cfg_name, rng):
    """lax and pallas_dc* must agree bit-for-bit on every output field
    (the kernels compute the same function; dispatch must not perturb it)."""
    if len(WINDOWED) < 2:
        pytest.skip("matrix run pins a single backend")
    cfg = CONFIGS[cfg_name]
    pairs = _ragged_pairs(rng, n_sub=2, n_ins=1, n_del=1, n_pairs=6)
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, T_CAP)
    base = _run("lax", cfg, texts, pats, p_lens, t_lens)
    for backend in WINDOWED:
        if backend == "lax":
            continue
        got = _run(backend, cfg, texts, pats, p_lens, t_lens, block_bt=4)
        for field in ("distance", "ops", "n_ops", "text_consumed", "failed"):
            np.testing.assert_array_equal(
                np.asarray(getattr(base, field)),
                np.asarray(getattr(got, field)),
                err_msg=f"{backend}.{field} diverges from lax")


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_property_differential_random_edits(data):
    """Property: for random (k, W, edit-mix) draws all backends agree on
    distance, and windowed distance is oracle-exact for subs-only draws."""
    seed = data.draw(st.integers(0, 2 ** 31))
    rng = np.random.default_rng(seed)
    cfg = CONFIGS["w64_k24" if data.draw(st.integers(0, 1)) else "w32_k24"]
    n_sub = data.draw(st.integers(0, 4))
    indels = data.draw(st.integers(0, 1))  # 0 → subs-only (exact tier)
    n_ins = data.draw(st.integers(0, 2)) * indels
    n_del = data.draw(st.integers(0, 2)) * indels
    pairs = _ragged_pairs(rng, n_sub=n_sub, n_ins=n_ins, n_del=n_del,
                          n_pairs=3)
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, T_CAP)
    results = {b: _run(b, cfg, texts, pats, p_lens, t_lens) for b in BACKENDS}
    if "lax" in results:
        base = results["lax"]
        for b in WINDOWED:
            np.testing.assert_array_equal(
                np.asarray(base.distance), np.asarray(results[b].distance),
                err_msg=f"{b} distance diverges from lax")
            np.testing.assert_array_equal(
                np.asarray(base.ops), np.asarray(results[b].ops),
                err_msg=f"{b} ops diverge from lax")
    for b, res in results.items():
        _check_cigars(res, pairs, b)
        if indels == 0 or b == "ref":
            dist = np.asarray(res.distance)
            for i, (pattern, text) in enumerate(pairs):
                assert dist[i] == oracle.levenshtein_prefix(pattern, text)


@pytest.mark.parametrize("backend", BACKENDS)
def test_align_batch_succeeds_on_cpu(backend):
    """Regression (dispatch platform fallback): the Pallas kernels used to
    die with an opaque Mosaic lowering error when invoked on CPU without
    ``interpret=True``; dispatch must detect the platform and fall back,
    so plain align_batch works everywhere for every backend."""
    rng = np.random.default_rng(0)
    pairs = [inputs.mutated_pair(rng, 40, n_sub=1)]
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, 64, 96)
    res = align.align_batch(
        jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
        jnp.asarray(t_lens), cfg=GenASMConfig(), backend=backend, p_cap=64)
    assert int(np.asarray(res.distance)[0]) == 1


def test_emit_cigar_false_uniform_across_backends(rng):
    """Distances-only mode: every backend returns the same distances, the
    same [B, 1] padded ops shape, and the same n_ops it reports with
    CIGARs on (the AlignResult contract must not vary per backend)."""
    pairs = _ragged_pairs(rng, n_sub=2, n_ins=0, n_del=0, n_pairs=3)
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, T_CAP)
    args = (jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
            jnp.asarray(t_lens))
    want = [oracle.levenshtein_prefix(p, t) for p, t in pairs]
    for backend in BACKENDS:
        res = align.align_batch(*args, cfg=CONFIGS["w64_k24"],
                                backend=backend, p_cap=P_CAP,
                                emit_cigar=False)
        assert res.ops.shape == (len(pairs), 1), backend
        np.testing.assert_array_equal(np.asarray(res.distance), want,
                                      err_msg=backend)
        full = align.align_batch(*args, cfg=CONFIGS["w64_k24"],
                                 backend=backend, p_cap=P_CAP)
        np.testing.assert_array_equal(
            np.asarray(res.n_ops), np.asarray(full.n_ops),
            err_msg=f"{backend}: n_ops diverges between cigar modes")


def test_resolve_backend_env_and_auto(monkeypatch):
    monkeypatch.delenv("REPRO_ALIGN_BACKEND", raising=False)
    auto = align.resolve_backend(None).name
    assert auto in align.available_backends()
    if align.needs_interpret():  # CPU container: lax is the auto default
        assert auto == "lax"
    monkeypatch.setenv("REPRO_ALIGN_BACKEND", "pallas_dc_v2")
    assert align.resolve_backend("auto").name == "pallas_dc_v2"
    # explicit name beats the env var
    assert align.resolve_backend("ref").name == "ref"
    with pytest.raises(ValueError, match="unknown align backend"):
        align.get_backend("nope")


def test_autotune_cache_keyed_on_site():
    align.clear_autotune_cache()
    bt = align.autotune("pallas_dc", 64, 4, batch=16, candidates=(8, 16),
                        cfg=GenASMConfig(w=32, o=8, k=4))
    assert bt in (8, 16)
    # cached: block_size_for returns the tuned value for the same site,
    # heuristic for a different one
    assert align.block_size_for("pallas_dc", 64, 4, batch=999) == bt
    assert align.block_size_for("pallas_dc", 128, 4, batch=16) == 16
    # non-pallas backends pin the heuristic without timing anything
    assert align.autotune("lax", 64, 4, batch=16) == 16
    align.clear_autotune_cache()
