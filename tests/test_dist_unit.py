"""Focused unit tests for repro.dist beyond the seed suites:
WorkQueue lease/steal semantics, Heartbeat flagging, and the ``_fit``
spec-to-shape reconciler on degenerate meshes."""
import os
import time

import pytest

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.fault import Heartbeat, RestartableLoop, WorkQueue
from repro.dist.sharding import _fit, batch_specs, param_specs


# ------------------------------------------------------------- WorkQueue ---

def test_workqueue_prefers_fresh_items_over_steals():
    q = WorkQueue(3, lease_s=0.0)  # every lease instantly stealable
    first = [q.claim() for _ in range(3)]
    # all three fresh items are issued before any steal happens
    assert sorted(first) == [0, 1, 2]


def test_workqueue_steals_longest_expired_first():
    q = WorkQueue(2, lease_s=0.0)
    a = q.claim()
    time.sleep(0.01)
    b = q.claim()
    # both leases are expired; a's expiry is older, so a is re-issued first
    assert q.claim() == a
    assert q.claim() == b


def test_workqueue_live_leases_are_not_stolen():
    q = WorkQueue(1, lease_s=60.0)
    assert q.claim() == 0
    assert q.claim() is None  # leased and live: nothing claimable
    assert not q.finished
    q.complete(0)
    assert q.finished
    assert q.claim() is None  # drained


def test_workqueue_complete_is_idempotent_and_fail_requeues():
    q = WorkQueue(2, lease_s=60.0)
    a = q.claim()
    q.fail(a)  # returned to the head: next claim gets it back
    assert q.claim() == a
    q.complete(a)
    q.complete(a)  # duplicate completion (stolen twin) is harmless
    b = q.claim()
    q.complete(b)
    assert q.finished


def test_workqueue_empty_is_finished():
    q = WorkQueue(0)
    assert q.finished
    assert q.claim() is None


# ------------------------------------------------------------- Heartbeat ---

def test_heartbeat_warmup_never_flags():
    hb = Heartbeat(factor=1.0, warmup=100)
    for _ in range(20):
        assert hb.beat() is False


def test_heartbeat_flags_then_recovers():
    hb = Heartbeat(factor=4.0, warmup=3)
    for _ in range(8):
        hb.beat()
        time.sleep(0.01)
    time.sleep(0.1)
    assert hb.beat() is True  # straggler gap
    assert hb.straggler_count == 1
    for _ in range(4):  # baseline not poisoned by the straggler gap
        time.sleep(0.01)
        assert hb.beat() is False


# ------------------------------------------------------------------ _fit ---

def _mesh(shape, axes):
    return jax.make_mesh(shape, axes)


def test_fit_basic_and_padding():
    mesh = _mesh((2, 2), ("data", "model"))
    assert _fit(mesh, (8, 16), (None, "model")) == P(None, "model")
    # shorter want pads on the left (stacked-blocks leading axis)
    assert _fit(mesh, (3, 8, 16), (None, "model")) == P(None, None, "model")
    # longer want drops leading entries
    assert _fit(mesh, (16,), (None, "model")) == P("model")


def test_fit_drops_nondivisible_and_unknown_axes():
    mesh = _mesh((2, 2), ("data", "model"))
    assert _fit(mesh, (7, 16), ("model", None)) == P()  # 7 % 2 != 0
    assert _fit(mesh, (8, 16), ("pod", "model")) == P(None, "model")


def test_fit_never_reuses_a_mesh_axis():
    mesh = _mesh((2, 2), ("data", "model"))
    # both dims want "model": only the first gets it (EP-over-experts rule)
    assert _fit(mesh, (4, 8), ("model", "model")) == P("model")


def test_fit_single_device_mesh_is_degenerate():
    mesh = _mesh((1,), ("data",))
    assert _fit(mesh, (8, 16), ("model", "data")) == P(None, "data")
    spec = _fit(mesh, (7, 13), ("data", "model"))
    assert spec == P("data") or spec == P()  # axis of size 1 divides all


def test_fit_axis_size_one():
    mesh = _mesh((4, 1), ("data", "model"))
    # "model" has size 1: sharding over it is legal and a no-op
    assert _fit(mesh, (6, 9), (None, "model")) == P(None, "model")


def test_fit_tuple_axes_partial_fit():
    mesh = _mesh((2, 2), ("pod", "data"))
    # dim 4 fits pod×data (2×2); dim 2 keeps only the first axis of the pair
    assert _fit(mesh, (4, 8), (("pod", "data"), None)) == P(("pod", "data"))
    assert _fit(mesh, (2, 8), (("pod", "data"), None)) == P("pod")


# ----------------------------------------------------- spec tree shapes ---

def test_param_and_batch_specs_divide_shapes():
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import model_zoo
    import jax.numpy as jnp

    mesh = _mesh((2, 2), ("data", "model"))
    cfg = reduced(get_config("mixtral-8x7b"), n_heads=4, n_kv_heads=2,
                  vocab=512)
    params = jax.eval_shape(
        lambda k: model_zoo.init(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(params, mesh)
    sizes = dict(mesh.shape)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        assert isinstance(spec, P)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            assert dim % int(np.prod([sizes[a] for a in axes])) == 0, (
                path, leaf.shape, spec)
    # MoE experts shard over "model"; the router stays replicated
    moe_spec = specs["blocks"]["slot0"]["moe"]
    assert moe_spec["wi"][1] == "model"  # (stack, EXPERT, EMBED, MLP)
    assert moe_spec["router"] == P()

    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    assert batch_specs(batch, mesh)["tokens"] == P("data")


# -------------------------------------------------- RestartableLoop edge ---

def test_restartable_loop_no_checkpoint_runs_all(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    import jax.numpy as jnp

    mgr = CheckpointManager(tmp_path, keep=2)
    loop = RestartableLoop(mgr, save_every=4)
    out = loop.run({"c": jnp.int32(0)},
                   lambda st, i: {"c": st["c"] + 1}, n_steps=6)
    assert int(out["c"]) == 6
    mgr.wait()
    assert mgr.latest_step() == 6
    # a second run resumes from the final checkpoint: zero extra steps
    calls = []
    out2 = loop.run({"c": jnp.int32(0)},
                    lambda st, i: calls.append(i) or {"c": st["c"] + 1},
                    n_steps=6)
    assert calls == []
    assert int(out2["c"]) == 6
