"""repro.obs: spans, Perfetto export, Amdahl ledger, HTTP exposition."""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.obs import (NULL_TRACER, ObsServer, Span, TraceLog, Tracer,
                       build_ledger, render_report)
from repro.obs.attrib import PARALLEL_STAGES, STAGE_ORDER
from repro.serve.metrics import Histogram, Metrics


# ----------------------------------------------------------------- tracer --
def test_span_nesting_parent_links():
    tr = Tracer()
    with tr.span("flush") as f:
        with tr.span("seed_filter") as a:
            pass
        with tr.span("align") as b:
            pass
    spans = {s.name: s for s in tr.log.spans()}
    assert spans["seed_filter"].parent_id == f.span_id
    assert spans["align"].parent_id == f.span_id
    assert spans["flush"].parent_id is None
    assert a.span_id != b.span_id
    # children close before (and inside) the parent window
    assert f.t_start <= a.t_start <= a.t_end <= f.t_end


def test_retroactive_add_parents_to_open_span():
    tr = Tracer()
    t0 = time.monotonic()
    with tr.span("flush") as f:
        tr.add("align", t0, t0 + 0.5, compile=True)
    s = tr.log.spans()[0]
    assert s.name == "align" and s.parent_id == f.span_id
    assert s.duration_s == pytest.approx(0.5)
    assert s.attrs == {"compile": True}


def test_nesting_is_per_thread():
    tr = Tracer()
    seen = {}

    def worker():
        # no open span on THIS thread, even while main holds one
        seen["parent"] = tr.current_parent()

    with tr.span("flush"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["parent"] is None


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("flush") as s:
        s.set(bucket=1)  # inert null span — must not raise
        tr.add("align", 0.0, 1.0)
        tr.event("submit")
    assert tr.log.spans() == []
    assert NULL_TRACER.log.spans() == []


def test_ring_buffer_drops_oldest():
    log = TraceLog(max_spans=4)
    for i in range(6):
        log.append(Span(name=f"s{i}", t_start=0.0, t_end=1.0, span_id=i))
    assert [s.name for s in log.spans()] == ["s2", "s3", "s4", "s5"]
    assert log.dropped == 2
    assert [d["name"] for d in log.last(2)] == ["s4", "s5"]


# ---------------------------------------------------------------- perfetto --
def test_chrome_export_is_valid_trace_event_json(tmp_path):
    tr = Tracer()
    t0 = time.monotonic()
    with tr.span("flush", bucket_cap=128):
        tr.add("enqueue_wait", t0 - 0.01, t0, async_=True)
        tr.add("align", t0, t0 + 0.001)
        tr.event("submit", length=100)
    path = tmp_path / "trace.json"
    tr.log.export_chrome(str(path))
    doc = json.loads(path.read_text())

    events = doc["traceEvents"]
    assert isinstance(events, list) and events
    phases = {e["ph"] for e in events}
    assert {"M", "X", "i", "b", "e"} <= phases
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    # async begin/end ids pair up exactly
    b_ids = sorted(e["id"] for e in events if e["ph"] == "b")
    e_ids = sorted(e["id"] for e in events if e["ph"] == "e")
    assert b_ids == e_ids and len(b_ids) == 1
    # thread-name metadata declares every tid used by real events
    named = {e["tid"] for e in events if e["ph"] == "M"}
    used = {e["tid"] for e in events if e["ph"] != "M"}
    assert used <= named


def test_jsonl_export_round_trips(tmp_path):
    tr = Tracer()
    with tr.span("flush", batch=3):
        pass
    path = tmp_path / "trace.jsonl"
    tr.log.export_jsonl(str(path))
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(rows) == 1
    assert rows[0]["name"] == "flush" and rows[0]["attrs"] == {"batch": 3}
    assert rows[0]["duration_ms"] >= 0.0


# ------------------------------------------------------------------ ledger --
def _mk(name, t0, t1, span_id, parent=None, **attrs):
    return Span(name=name, t_start=t0, t_end=t1, span_id=span_id,
                parent_id=parent, attrs=attrs)


def test_ledger_sums_to_flush_wall_time():
    # flush [0, 1.0]: seed_filter 0.6 + align 0.3 attributed, 0.1 uncovered
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("seed_filter", 0.0, 0.6, 2, parent=1),
        _mk("align", 0.6, 0.9, 3, parent=1),
    ]
    led = build_ledger(spans)
    rep = led.report()
    assert rep.n_flushes == 1
    assert rep.flush_s == pytest.approx(1.0)
    total = sum(r["total_s"] for r in rep.stages
                if r["stage"] != "enqueue_wait")
    assert total == pytest.approx(rep.flush_s)  # "other" absorbs the gap
    assert led.total("other") == pytest.approx(0.1)
    assert rep.coverage == pytest.approx(0.9)
    # serial fraction = (align + other) / busy = 0.4 / 1.0
    assert rep.serial_fraction == pytest.approx(0.4)


def test_ledger_enqueue_wait_excluded_from_busy_and_coverage():
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("enqueue_wait", -5.0, 0.0, 2, parent=1),
        _mk("align", 0.0, 1.0, 3, parent=1),
    ]
    rep = build_ledger(spans).report()
    assert rep.busy_s == pytest.approx(1.0)  # the 5 s wait is not busy time
    assert rep.coverage == pytest.approx(1.0)
    eq = next(r for r in rep.stages if r["stage"] == "enqueue_wait")
    assert eq["frac"] == 0.0  # a busy-fraction would be meaningless


def test_ledger_amdahl_projection():
    # one parallel stage at 50% of busy time: spd@2 = 1/(0.5 + 0.25)
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("scatter", 0.0, 0.5, 2, parent=1),
        _mk("merge", 0.5, 1.0, 3, parent=1),
    ]
    rep = build_ledger(spans).report(shard_counts=(2,))
    sc = next(r for r in rep.stages if r["stage"] == "scatter")
    assert sc["parallel"]
    assert sc["speedup_x2"] == pytest.approx(4 / 3, abs=1e-3)  # rows round
    assert sc["speedup_inf"] == pytest.approx(2.0)
    assert rep.serial_fraction == pytest.approx(0.5)
    assert set(PARALLEL_STAGES) <= set(STAGE_ORDER)


def test_render_report_is_one_row_per_stage():
    spans = [_mk("flush", 0.0, 1.0, 1), _mk("align", 0.0, 1.0, 2, parent=1)]
    text = render_report(build_ledger(spans).report())
    lines = text.splitlines()
    assert "stage attribution: 1 flushes" in lines[0]
    assert any(line.startswith("align") for line in lines)
    assert any(line.startswith("other") for line in lines)


def test_ledger_unknown_stage_folds_into_other():
    led = build_ledger([_mk("flush", 0.0, 1.0, 1),
                        _mk("mystery", 0.0, 0.2, 2, parent=1)])
    assert led.total("other") == pytest.approx(1.0)  # full flush uncovered


# -------------------------------------------------------------------- http --
def _get(url: str) -> tuple[int, str]:
    with urllib.request.urlopen(url, timeout=5) as r:
        return r.status, r.read().decode()


def test_obs_server_endpoints_smoke():
    metrics = Metrics()
    metrics.counter("reads_total").inc(7)
    tr = Tracer()
    with tr.span("flush"):
        with tr.span("align"):
            pass
    with ObsServer(metrics=metrics, tracer=tr, port=0) as srv:
        code, body = _get(srv.url + "/healthz")
        assert code == 200 and body == "ok\n"

        code, body = _get(srv.url + "/metrics")
        assert code == 200 and "reads_total 7" in body

        code, body = _get(srv.url + "/trace?n=1")
        doc = json.loads(body)
        assert code == 200 and len(doc["spans"]) == 1
        assert doc["spans"][0]["name"] == "flush"  # newest last

        code, body = _get(srv.url + "/attrib")
        rep = json.loads(body)
        assert code == 200 and rep["n_flushes"] == 1
        assert any(r["stage"] == "align" for r in rep["stages"])


def test_obs_server_404s():
    with ObsServer(port=0) as srv:  # nothing attached
        for path in ("/metrics", "/trace", "/attrib", "/nope"):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(srv.url + path)
            assert ei.value.code == 404


# ----------------------------------------------------------------- metrics --
def test_histogram_quantiles_monotone():
    h = Histogram()
    for v in (1e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 0.1, 1.0):
        h.observe(v)
    qs = [h.quantile(q) for q in (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)]
    assert qs == sorted(qs)
    assert qs[-1] <= h._bounds[-1]


def test_histogram_boundary_observation_exact():
    h = Histogram(lo=1.0, hi=100.0, n_buckets=9)
    for b in h._bounds:
        h.observe(b)  # lands in the bucket it bounds, never the next one
    st = h.stats()
    assert st["count"] == len(h._bounds)
    assert st["p50"] <= st["p99"] <= h._bounds[-1]
    # clamped outlier still counts
    h.observe(1e9)
    assert h.stats()["count"] == len(h._bounds) + 1


def test_metrics_snapshot_is_flat_and_consistent():
    m = Metrics()
    m.counter("c").inc(3)
    m.gauge("g").set(2.5)
    m.histogram("h").observe(0.01)
    snap = m.snapshot()
    assert snap["c"] == 3 and snap["g"] == 2.5
    assert snap["h_count"] == 1 and snap["h_p50"] <= snap["h_p99"]
    assert "c 3" in m.render()
