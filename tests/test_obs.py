"""repro.obs: spans, Perfetto export, Amdahl ledger, HTTP, roofline."""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.obs import (NULL_TRACER, DeviceSpec, ObsServer, RooflineManager,
                       Span, TraceLog, Tracer, align_counters, build_ledger,
                       dc_window_counters, predict_block_bt, render_report)
from repro.obs.attrib import PARALLEL_STAGES, STAGE_ORDER
from repro.serve.metrics import Histogram, Metrics


# ----------------------------------------------------------------- tracer --
def test_span_nesting_parent_links():
    tr = Tracer()
    with tr.span("flush") as f:
        with tr.span("seed_filter") as a:
            pass
        with tr.span("align") as b:
            pass
    spans = {s.name: s for s in tr.log.spans()}
    assert spans["seed_filter"].parent_id == f.span_id
    assert spans["align"].parent_id == f.span_id
    assert spans["flush"].parent_id is None
    assert a.span_id != b.span_id
    # children close before (and inside) the parent window
    assert f.t_start <= a.t_start <= a.t_end <= f.t_end


def test_retroactive_add_parents_to_open_span():
    tr = Tracer()
    t0 = time.monotonic()
    with tr.span("flush") as f:
        tr.add("align", t0, t0 + 0.5, compile=True)
    s = tr.log.spans()[0]
    assert s.name == "align" and s.parent_id == f.span_id
    assert s.duration_s == pytest.approx(0.5)
    assert s.attrs == {"compile": True}


def test_nesting_is_per_thread():
    tr = Tracer()
    seen = {}

    def worker():
        # no open span on THIS thread, even while main holds one
        seen["parent"] = tr.current_parent()

    with tr.span("flush"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["parent"] is None


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("flush") as s:
        s.set(bucket=1)  # inert null span — must not raise
        tr.add("align", 0.0, 1.0)
        tr.event("submit")
    assert tr.log.spans() == []
    assert NULL_TRACER.log.spans() == []


def test_ring_buffer_drops_oldest():
    log = TraceLog(max_spans=4)
    for i in range(6):
        log.append(Span(name=f"s{i}", t_start=0.0, t_end=1.0, span_id=i))
    assert [s.name for s in log.spans()] == ["s2", "s3", "s4", "s5"]
    assert log.dropped == 2
    assert [d["name"] for d in log.last(2)] == ["s4", "s5"]


# ---------------------------------------------------------------- perfetto --
def test_chrome_export_is_valid_trace_event_json(tmp_path):
    tr = Tracer()
    t0 = time.monotonic()
    with tr.span("flush", bucket_cap=128):
        tr.add("enqueue_wait", t0 - 0.01, t0, async_=True)
        tr.add("align", t0, t0 + 0.001)
        tr.event("submit", length=100)
    path = tmp_path / "trace.json"
    tr.log.export_chrome(str(path))
    doc = json.loads(path.read_text())

    events = doc["traceEvents"]
    assert isinstance(events, list) and events
    phases = {e["ph"] for e in events}
    assert {"M", "X", "i", "b", "e"} <= phases
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and e["ts"] >= 0.0
    # async begin/end ids pair up exactly
    b_ids = sorted(e["id"] for e in events if e["ph"] == "b")
    e_ids = sorted(e["id"] for e in events if e["ph"] == "e")
    assert b_ids == e_ids and len(b_ids) == 1
    # thread-name metadata declares every tid used by real events
    named = {e["tid"] for e in events if e["ph"] == "M"}
    used = {e["tid"] for e in events if e["ph"] != "M"}
    assert used <= named


def test_jsonl_export_round_trips(tmp_path):
    tr = Tracer()
    with tr.span("flush", batch=3):
        pass
    path = tmp_path / "trace.jsonl"
    tr.log.export_jsonl(str(path))
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(rows) == 1
    assert rows[0]["name"] == "flush" and rows[0]["attrs"] == {"batch": 3}
    assert rows[0]["duration_ms"] >= 0.0


# ------------------------------------------------------------------ ledger --
def _mk(name, t0, t1, span_id, parent=None, **attrs):
    return Span(name=name, t_start=t0, t_end=t1, span_id=span_id,
                parent_id=parent, attrs=attrs)


def test_ledger_sums_to_flush_wall_time():
    # flush [0, 1.0]: seed_filter 0.6 + align 0.3 attributed, 0.1 uncovered
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("seed_filter", 0.0, 0.6, 2, parent=1),
        _mk("align", 0.6, 0.9, 3, parent=1),
    ]
    led = build_ledger(spans)
    rep = led.report()
    assert rep.n_flushes == 1
    assert rep.flush_s == pytest.approx(1.0)
    total = sum(r["total_s"] for r in rep.stages
                if r["stage"] != "enqueue_wait")
    assert total == pytest.approx(rep.flush_s)  # "other" absorbs the gap
    assert led.total("other") == pytest.approx(0.1)
    assert rep.coverage == pytest.approx(0.9)
    # serial fraction = (align + other) / busy = 0.4 / 1.0
    assert rep.serial_fraction == pytest.approx(0.4)


def test_ledger_enqueue_wait_excluded_from_busy_and_coverage():
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("enqueue_wait", -5.0, 0.0, 2, parent=1),
        _mk("align", 0.0, 1.0, 3, parent=1),
    ]
    rep = build_ledger(spans).report()
    assert rep.busy_s == pytest.approx(1.0)  # the 5 s wait is not busy time
    assert rep.coverage == pytest.approx(1.0)
    eq = next(r for r in rep.stages if r["stage"] == "enqueue_wait")
    assert eq["frac"] == 0.0  # a busy-fraction would be meaningless


def test_ledger_amdahl_projection():
    # one parallel stage at 50% of busy time: spd@2 = 1/(0.5 + 0.25)
    spans = [
        _mk("flush", 0.0, 1.0, 1),
        _mk("scatter", 0.0, 0.5, 2, parent=1),
        _mk("merge", 0.5, 1.0, 3, parent=1),
    ]
    rep = build_ledger(spans).report(shard_counts=(2,))
    sc = next(r for r in rep.stages if r["stage"] == "scatter")
    assert sc["parallel"]
    assert sc["speedup_x2"] == pytest.approx(4 / 3, abs=1e-3)  # rows round
    assert sc["speedup_inf"] == pytest.approx(2.0)
    assert rep.serial_fraction == pytest.approx(0.5)
    assert set(PARALLEL_STAGES) <= set(STAGE_ORDER)


def test_render_report_is_one_row_per_stage():
    spans = [_mk("flush", 0.0, 1.0, 1), _mk("align", 0.0, 1.0, 2, parent=1)]
    text = render_report(build_ledger(spans).report())
    lines = text.splitlines()
    assert "stage attribution: 1 flushes" in lines[0]
    assert any(line.startswith("align") for line in lines)
    assert any(line.startswith("other") for line in lines)


def test_ledger_unknown_stage_folds_into_other():
    led = build_ledger([_mk("flush", 0.0, 1.0, 1),
                        _mk("mystery", 0.0, 0.2, 2, parent=1)])
    assert led.total("other") == pytest.approx(1.0)  # full flush uncovered


# -------------------------------------------------------------------- http --
def _get(url: str) -> tuple[int, str]:
    with urllib.request.urlopen(url, timeout=5) as r:
        return r.status, r.read().decode()


def test_obs_server_endpoints_smoke():
    metrics = Metrics()
    metrics.counter("reads_total").inc(7)
    tr = Tracer()
    with tr.span("flush"):
        with tr.span("align"):
            pass
    with ObsServer(metrics=metrics, tracer=tr, port=0) as srv:
        code, body = _get(srv.url + "/healthz")
        assert code == 200 and body == "ok\n"

        code, body = _get(srv.url + "/metrics")
        assert code == 200 and "reads_total 7" in body

        code, body = _get(srv.url + "/trace?n=1")
        doc = json.loads(body)
        assert code == 200 and len(doc["spans"]) == 1
        assert doc["spans"][0]["name"] == "flush"  # newest last

        code, body = _get(srv.url + "/attrib")
        rep = json.loads(body)
        assert code == 200 and rep["n_flushes"] == 1
        assert any(r["stage"] == "align" for r in rep["stages"])


def test_obs_server_404s():
    with ObsServer(port=0) as srv:  # nothing attached
        for path in ("/metrics", "/trace", "/attrib", "/nope"):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(srv.url + path)
            assert ei.value.code == 404


# ----------------------------------------------------------------- metrics --
def test_histogram_quantiles_monotone():
    h = Histogram()
    for v in (1e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 0.1, 1.0):
        h.observe(v)
    qs = [h.quantile(q) for q in (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)]
    assert qs == sorted(qs)
    assert qs[-1] <= h._bounds[-1]


def test_histogram_boundary_observation_exact():
    h = Histogram(lo=1.0, hi=100.0, n_buckets=9)
    for b in h._bounds:
        h.observe(b)  # lands in the bucket it bounds, never the next one
    st = h.stats()
    assert st["count"] == len(h._bounds)
    assert st["p50"] <= st["p99"] <= h._bounds[-1]
    # clamped outlier still counts
    h.observe(1e9)
    assert h.stats()["count"] == len(h._bounds) + 1


def test_metrics_snapshot_is_flat_and_consistent():
    m = Metrics()
    m.counter("c").inc(3)
    m.gauge("g").set(2.5)
    m.histogram("h").observe(0.01)
    snap = m.snapshot()
    assert snap["c"] == 3 and snap["g"] == 2.5
    assert snap["h_count"] == 1 and snap["h_p50"] <= snap["h_p99"]
    assert "c 3" in m.render()


# ---------------------------------------------------------------- counters --
def test_counter_events_export_as_perfetto_C_and_parse(tmp_path):
    tr = Tracer()
    tr.counter("kernel/lax/cap160", word_ops=100.0, hbm_bytes=400.0)
    tr.counter("kernel/lax/cap160", word_ops=250.0, hbm_bytes=900.0)
    path = tmp_path / "trace.json"
    tr.log.export_chrome(str(path))
    doc = json.loads(path.read_text())
    cs = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert len(cs) == 2
    for e in cs:
        assert e["name"] == "kernel/lax/cap160"
        assert set(e["args"]) == {"word_ops", "hbm_bytes"}
    # cumulative samples are monotone in both series and in time
    assert cs[0]["ts"] <= cs[1]["ts"]
    assert cs[0]["args"]["word_ops"] < cs[1]["args"]["word_ops"]
    assert cs[0]["args"]["hbm_bytes"] < cs[1]["args"]["hbm_bytes"]


def test_disabled_tracer_counter_records_nothing():
    tr = Tracer(enabled=False)
    tr.counter("kernel/x", word_ops=1.0)
    assert tr.log.spans() == []


# ---------------------------------------------------------------- roofline --
def test_dc_window_counters_hand_counted_tiny_case():
    # w=32 (one word), k=2: 32 text steps x 3 distance rows x 6 ops/cell
    c = dc_window_counters(32, 2)
    assert c["nw"] == 1
    assert c["word_ops"] == 32 * 3 * 6
    # M/I/D store: one u32 word per (step, row, vector)
    assert c["tb_bytes"] == 32 * 3 * 3 * 4
    # R-only store: one u32 word per (step incl. boundary, row)
    assert dc_window_counters(32, 2, store="r")["tb_bytes"] == 33 * 3 * 4
    with pytest.raises(ValueError):
        dc_window_counters(33, 2)  # not a word multiple
    with pytest.raises(ValueError):
        dc_window_counters(32, 2, store="nope")


def test_align_counters_hand_counted_launch_structure():
    # cap=64, w=32, o=8: commit 24 -> ceil(64/24)+2 = 5 windows;
    # batch 8 @ block 8 -> 1 grid step/window -> 5 launches
    c = align_counters("pallas_dc", 64, 2, 8, w=32, o=8, block_bt=8)
    assert c.launches == 5 and c.exact
    assert c.word_ops == 5 * 8 * (32 * 3 * 6)
    assert c.tb_bytes == 5 * 8 * (32 * 3 * 3 * 4)
    # io: per window-lane, text+pattern tiles (2w int8) + d_min (4B)
    assert c.hbm_bytes == c.tb_bytes + 5 * 8 * (2 * 32 + 4)
    # v2's R-only store is ~3x less TB traffic at equal ops
    v2 = align_counters("pallas_dc_v2", 64, 2, 8, w=32, o=8, block_bt=8)
    assert v2.word_ops == c.word_ops
    assert v2.tb_bytes < c.tb_bytes / 2.5
    # padding counts: batch 9 pads to 16 at block 8 -> 2 launches/window
    p = align_counters("pallas_dc", 64, 2, 9, w=32, o=8, block_bt=8)
    assert p.launches == 10
    assert p.word_ops == 2 * c.word_ops
    # ref is an estimate, flagged as such
    assert not align_counters("ref", 64, 2, 8).exact
    with pytest.raises(KeyError):
        align_counters("mystery_backend", 64, 2, 8)


def test_device_spec_load_and_roof():
    spec = DeviceSpec.load("tpu_v5e")
    assert spec.peak_flops == pytest.approx(197e12)
    assert spec.hbm_bw == pytest.approx(819e9)
    # roofline: bandwidth-bound below the ridge, compute-bound above
    ridge = spec.peak_word_ops / spec.hbm_bw
    assert spec.roof_ops_per_s(ridge / 10) == pytest.approx(
        ridge / 10 * spec.hbm_bw)
    assert spec.roof_ops_per_s(ridge * 10) == spec.peak_word_ops
    with pytest.raises(ValueError):
        DeviceSpec.load("no_such_device")
    for name in ("gpu_generic", "cpu_host"):
        assert DeviceSpec.load(name).peak_word_ops > 0


def test_predict_block_bt_prefers_fewer_launches_under_overhead():
    # launch overhead dominates at tiny work sizes -> pick the largest
    # tile that fits the batch (one launch per window)
    slow_launch = DeviceSpec(name="x", peak_flops=1e15, peak_word_ops=1e15,
                             hbm_bw=1e15, launch_overhead_s=1.0)
    assert predict_block_bt("pallas_dc", 160, 8, 64,
                            spec=slow_launch) == 64
    # zero overhead + padding waste: batch 40 at block 64 pads 24 lanes,
    # block 8 pads none -> the model must not pick the padded tile
    no_overhead = DeviceSpec(name="y", peak_flops=1e12, peak_word_ops=1e12,
                             hbm_bw=1e12, launch_overhead_s=0.0)
    bt = predict_block_bt("pallas_dc", 160, 8, 40, spec=no_overhead)
    assert 40 % bt == 0


def test_roofline_manager_records_and_reports():
    m = Metrics()
    tr = Tracer()
    rf = RooflineManager(spec=DeviceSpec.load("cpu_host"), metrics=m,
                         tracer=tr, measure=False)
    for _ in range(3):
        rf.record_flush("lax", 160, 24, 16, align_s=0.01)
    rep = rf.report()
    assert rep["device_spec"]["name"] == "cpu_host"
    (row,) = rep["kernels"]
    assert row["kernel"] == "lax/cap160" and row["calls"] == 3
    for key in ("analytic_ops", "measured_ops", "bytes", "intensity",
                "pct_of_roof"):
        assert key in row
    assert row["analytic_ops"] > 0 and 0 < row["pct_of_roof"] < 1
    assert row["achieved_ops_per_s"] == pytest.approx(
        row["analytic_ops"] * 3 / 0.03)
    # counters land in the Metrics registry, cumulatively
    snap = m.snapshot()
    assert snap["kernel_lax_cap160_word_ops"] == pytest.approx(
        row["analytic_ops"] * 3)
    assert snap["kernel_lax_cap160_launches"] >= 0
    # ...and as monotone Perfetto counter samples
    cs = [s for s in tr.log.spans() if s.kind == "counter"]
    assert len(cs) == 3
    vals = [s.attrs["word_ops"] for s in cs]
    assert vals == sorted(vals) and vals[0] < vals[-1]


def test_roofline_manager_disabled_is_noop_and_unknown_backend_skipped():
    rf = RooflineManager(spec=DeviceSpec.load("cpu_host"), enabled=False,
                         measure=False)
    assert rf.record_flush("lax", 160, 24, 16, align_s=0.01) is None
    assert rf.report()["kernels"] == []
    rf.enabled = True
    assert rf.record_flush("graph_lax", 160, 24, 16, align_s=0.01) is None
    assert rf.report()["kernels"] == []


def test_roofline_measured_side_cost_analysis():
    rf = RooflineManager(spec=DeviceSpec.load("cpu_host"))
    rf.record_flush("lax", 64, 8, 8, align_s=0.005)
    (row,) = rf.report(measure=True)["kernels"]
    assert row["measure_error"] is None
    # XLA's CPU cost model sees only the float residue of the integer
    # DC program (DESIGN.md par. 13): demand presence and rough scale,
    # not agreement
    assert row["measured_ops"] is not None
    assert row["measured_bytes"] is not None and row["measured_bytes"] > 0
    # the analytic/measured ops ratio stays within the documented band
    assert row["analytic_ops"] / max(row["measured_ops"], 1.0) < 1024


# ------------------------------------------------------- engine integration --
def test_serve_engine_roofline_integration():
    import numpy as np

    from repro.core import minimizer_index
    from repro.serve import EngineConfig, ServeEngine

    rng = np.random.default_rng(5)
    ref = rng.integers(0, 4, size=2000).astype(np.int8)
    index = minimizer_index.build_epoched_index(ref, w=8, k=12)
    reads = [ref[i:i + 100].copy() for i in (50, 400, 900, 1300)]
    tr = Tracer()
    rf = RooflineManager(spec=DeviceSpec.load("cpu_host"), tracer=tr,
                         measure=False)
    cfg = EngineConfig(buckets=(128,), max_batch=4, minimizer_w=8,
                       minimizer_k=12)
    with ServeEngine(index, cfg, tracer=tr, roofline=rf) as eng:
        eng.map_all(reads)
        backend = eng.align_backend
    rows = rf.report(measure=False)["kernels"]
    assert rows and rows[0]["kernel"] == f"{backend}/cap128"
    assert rows[0]["calls"] >= 1 and rows[0]["align_s"] > 0
    # the align span carries the counters for per-stage attribution
    aligns = [s for s in tr.log.spans() if s.name == "align"]
    assert aligns and aligns[0].attrs["word_ops"] == rows[0]["analytic_ops"]
    rep = build_ledger(tr.log).report()
    arow = next(r for r in rep.stages if r["stage"] == "align")
    assert arow["word_ops"] == pytest.approx(
        rows[0]["analytic_ops"] * rows[0]["calls"])
    assert arow["ops_per_s"] > 0 and arow["intensity"] > 0


# ------------------------------------------------------------- http extras --
def test_trace_endpoint_bad_n_is_400_and_large_n_clamps():
    tr = Tracer(log=TraceLog(max_spans=8))
    for _ in range(12):
        with tr.span("flush"):
            pass
    with ObsServer(tracer=tr, port=0) as srv:
        for bad in ("foo", "-5", "1.5", ""):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _get(srv.url + f"/trace?n={bad}")
            assert ei.value.code == 400
        # n beyond the ring clamps to the ring size instead of erroring
        code, body = _get(srv.url + "/trace?n=999999999")
        assert code == 200
        assert len(json.loads(body)["spans"]) == 8


def test_roofline_endpoint_serves_kernel_rows():
    rf = RooflineManager(spec=DeviceSpec.load("cpu_host"), measure=False)
    rf.record_flush("lax", 160, 24, 16, align_s=0.02)
    rf.record_flush("lax", 320, 24, 16, align_s=0.04)
    with ObsServer(roofline=rf, port=0) as srv:
        code, body = _get(srv.url + "/roofline?measure=0")
        assert code == 200
        doc = json.loads(body)
        assert doc["device_spec"]["name"] == "cpu_host"
        kernels = {r["kernel"] for r in doc["kernels"]}
        assert kernels == {"lax/cap160", "lax/cap320"}
        for r in doc["kernels"]:
            for key in ("analytic_ops", "measured_ops", "bytes",
                        "intensity", "pct_of_roof"):
                assert key in r


def test_roofline_endpoint_404_when_unattached():
    with ObsServer(port=0) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/roofline")
        assert ei.value.code == 404
