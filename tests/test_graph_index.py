"""repro.graph index + core graph construction: tiles, epochs, mapper."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import oracle
from repro.core.segram import graph as cgraph
from repro.core.segram import segram
from repro.graph import index as gindex
from repro.graph import mapper as gmapper
from repro.graph import windowed
from repro.genomics import encode, simulate
from repro.serve import EngineConfig, ServeEngine


# ----------------------------------------------------- graph construction --
def test_build_graph_multibase_snp_honored():
    """A len-2 snp alt spells a branch: the alt allele aligns at cost 0."""
    ref = np.tile(np.arange(4, dtype=np.int8), 10)
    g = cgraph.build_graph(ref, [cgraph.Variant(10, "snp", (3, 3))])
    assert g.n_nodes == len(ref) + 2
    # the branch replaces ref[10]: ...8,9,[3,3],11,12...
    allele = np.array([ref[8], ref[9], 3, 3, ref[11], ref[12]], np.int8)
    d = oracle.graph_edit_distance(allele, g.bases, cgraph.predecessors(g))
    assert d == 0
    # and the backbone spelling still aligns at cost 0
    d_bb = oracle.graph_edit_distance(ref[8:13], g.bases,
                                      cgraph.predecessors(g))
    assert d_bb == 0


def test_build_graph_snp_branch_shares_predecessors():
    """The first alt node gets exactly its backbone twin's predecessors
    (the list the old implementation re-derived with an O(E) scan)."""
    ref = np.tile(np.arange(4, dtype=np.int8), 10)
    variants = [cgraph.Variant(9, "del", span=2),  # jump lands at 12
                cgraph.Variant(12, "snp", (0,))]
    g = cgraph.build_graph(ref, variants)
    preds = cgraph.predecessors(g)
    nid = int(g.node_of_backbone[12])
    alt = nid + 1  # alt node is emitted right after its twin
    assert g.backbone[alt] == -1
    assert preds[alt] == preds[nid]
    assert len(preds[nid]) == 2  # chain predecessor + deletion jump


def test_build_graph_rejects_bad_variants():
    ref = np.zeros(30, np.int8)
    with pytest.raises(ValueError, match="past the reference end"):
        cgraph.build_graph(ref, [cgraph.Variant(27, "del", span=2)])
    with pytest.raises(ValueError, match="non-empty alt"):
        cgraph.build_graph(ref, [cgraph.Variant(5, "snp", ())])
    with pytest.raises(ValueError, match="HOP_LIMIT"):
        cgraph.build_graph(ref, [cgraph.Variant(5, "del", span=20)])


def test_window_extractors_share_boundary_rule(rng):
    """Host extract_subgraph and device segram._window agree bitwise."""
    ref = simulate.random_reference(600, seed=9)
    variants = simulate.simulate_variants(ref, n_snp=8, n_ins=4, n_del=4,
                                          seed=10)
    g = cgraph.build_graph(ref, variants)
    idx = segram.preprocess(ref, g, w=8, k=12)
    for s in (0, 17, 300, g.n_nodes - 96):
        hb, hs = cgraph.extract_subgraph(g, s, 96)
        db, ds, s0 = segram._window(idx, jnp.int32(s), 96)
        assert int(s0) == s
        np.testing.assert_array_equal(hb, np.asarray(db))
        np.testing.assert_array_equal(hs, np.asarray(ds))


# ----------------------------------------------------------- tiled index ---
def test_tiles_match_extract_subgraph():
    """Every tile is extract_subgraph at its start — one masking rule."""
    ref = simulate.random_reference(900, seed=3)
    variants = simulate.simulate_variants(ref, n_snp=6, n_ins=3, n_del=3,
                                          seed=4)
    g = cgraph.build_graph(ref, variants)
    idx = gindex.build_graph_index(ref, variants, w=8, k=12, window=128,
                                   tile_stride=64)
    tiles = np.asarray(idx.arrays.tile_gtext)
    for c in (0, 1, idx.n_tiles // 2, idx.n_tiles - 1):
        bases, succ = cgraph.extract_subgraph(g, c * idx.tile_stride,
                                              idx.tile_len)
        want = np.asarray(windowed.pack_graph_text(jnp.asarray(bases),
                                                   jnp.asarray(succ)))
        np.testing.assert_array_equal(tiles[c], want, err_msg=f"tile {c}")
        assert int(idx.arrays.tile_valid[c]) == \
            min(idx.tile_len, g.n_nodes - c * idx.tile_stride)


def test_npz_roundtrip(tmp_path):
    ref = simulate.random_reference(800, seed=5)
    variants = simulate.simulate_variants(ref, n_snp=5, n_ins=2, n_del=2,
                                          seed=6)
    idx = gindex.build_graph_index(ref, variants, w=8, k=12, window=128)
    p = tmp_path / "graph_index.npz"
    gindex.save_graph_index(p, idx)
    got = gindex.load_graph_index(p)
    assert (got.tile_len, got.tile_stride) == (idx.tile_len, idx.tile_stride)
    assert (got.minimizer_w, got.minimizer_k) == (8, 12)
    for f in idx.arrays._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(idx.arrays, f)),
            np.asarray(getattr(got.arrays, f)), err_msg=f)
    np.testing.assert_array_equal(idx.ref, got.ref)


def test_epoched_graph_index_refresh_bumps_epoch():
    ref = simulate.random_reference(600, seed=7)
    egi = gindex.build_epoched_graph_index(ref, (), w=8, k=12, window=128)
    idx0, e0 = egi.current()
    variants = simulate.simulate_variants(ref, n_snp=4, n_ins=2, n_del=2,
                                          seed=8)
    assert egi.refresh(ref, variants) == e0 + 1
    idx1, e1 = egi.current()
    assert e1 == e0 + 1
    assert idx1.n_nodes > idx0.n_nodes  # variant nodes landed
    assert idx1.tile_stride == idx0.tile_stride  # build kwargs persisted


# ---------------------------------------------------------------- mapper ---
def test_mapper_chunked_long_reference():
    """A reference ≥ 10× one BitAlign window maps through the tiles."""
    ref = simulate.random_reference(4000, seed=42)  # ~15x a 256-node window
    variants = simulate.simulate_variants(ref, n_snp=12, n_ins=5, n_del=5,
                                          seed=7)
    idx = gindex.build_graph_index(ref, variants, w=8, k=12, window=256)
    assert idx.n_tiles * idx.tile_stride >= 10 * 256
    rs = simulate.simulate_reads(ref, n_reads=12, read_len=100,
                                 profile=simulate.ILLUMINA, seed=8)
    reads, lens = encode.batch_reads(rs.reads, 128)
    out = gmapper.map_batch_index(idx, jnp.asarray(reads), jnp.asarray(lens),
                                  p_cap=128, filter_bits=96, filter_k=12,
                                  backend="graph_lax")
    failed = np.asarray(out.failed)
    pos = np.asarray(out.position)
    ok = (~failed) & (np.abs(pos - rs.true_pos) <= 40)
    assert ok.sum() >= 10
    # paths walk real edges
    succ = np.asarray(idx.arrays.succ_bits)
    for i in np.nonzero(~failed)[0]:
        p = [int(x) for x in np.asarray(out.path[i]) if x >= 0]
        for a, b in zip(p, p[1:]):
            assert (succ[a] >> (b - a - 1)) & 1


def test_mapper_rejects_undersized_tiles():
    ref = simulate.random_reference(600, seed=1)
    idx = gindex.build_graph_index(ref, (), w=8, k=12, window=64)
    with pytest.raises(ValueError, match="rebuild the index"):
        gmapper.map_batch_index(idx, jnp.zeros((2, 128), jnp.int8),
                                jnp.full((2,), 100), p_cap=128)


# ------------------------------------------------------- serving workload --
def test_engine_graph_workload_end_to_end():
    ref = simulate.random_reference(3000, seed=11)
    variants = simulate.simulate_variants(ref, n_snp=8, n_ins=4, n_del=4,
                                          seed=12)
    egi = gindex.build_epoched_graph_index(
        ref, variants, w=8, k=12, window=96 + 2 * 64)
    cfg = EngineConfig(buckets=(96,), max_batch=4, workload="graph",
                       filter_k=10, minimizer_w=8, minimizer_k=12)
    rs = simulate.simulate_reads(ref, n_reads=8, read_len=90,
                                 profile=simulate.ILLUMINA, seed=13)
    with ServeEngine(egi, cfg) as eng:
        assert eng.align_backend in ("graph_lax", "graph_pallas")
        res = eng.map_all(list(rs.reads))
        # graph results carry node paths; cached twins copy them
        ok = [r for r in res if r.position >= 0]
        assert len(ok) >= 6
        assert all(r.path is not None and (r.path >= -1).all() for r in res)
        again = eng.map_all([rs.reads[0]])[0]
        assert again.cached and again.path is not None
        key_workloads = {k[1] for k in eng._executors}
    assert key_workloads == {"graph"}


def test_engine_graph_workload_rejects_linear_index():
    from repro.core import minimizer_index

    ref = simulate.random_reference(1000, seed=2)
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
    cfg = EngineConfig(buckets=(96,), workload="graph", minimizer_w=8,
                       minimizer_k=12)
    with pytest.raises(TypeError, match="GraphIndex"):
        ServeEngine(epi, cfg)
    with pytest.raises(ValueError, match="workload"):
        EngineConfig(buckets=(96,), workload="protein")
