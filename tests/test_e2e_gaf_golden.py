"""Golden end-to-end regression: serve_genomics GAF output is byte-stable.

The graph twin of ``test_e2e_paf_golden.py``: the full service driver
(simulate → variation-graph index → engine → GAF) on a fixed-seed read
set must write bytes identical to ``tests/data/serve_graph_golden.gaf``
— across the offline WorkQueue drain and the ``--online`` Poisson path,
and across the ``graph_lax``/``graph_pallas`` backends (interpret mode
on CPU).  Any backend divergence or accidental mapping change shows up
as a diff against one committed file.

Regenerate the snapshot (after an *intentional* output change) with:

    PYTHONPATH=src python -m repro.launch.serve_genomics \
        --mode graph --ref-len 3000 --reads 10 --read-len 100 --batch 4 \
        --buckets 128 --align-backend graph_lax \
        --out tests/data/serve_graph_golden.gaf
"""
import pathlib

import pytest

from repro.launch import serve_genomics

GOLDEN = pathlib.Path(__file__).parent / "data" / "serve_graph_golden.gaf"
BASE_ARGS = [
    "--mode", "graph", "--ref-len", "3000", "--reads", "10",
    "--read-len", "100", "--batch", "4", "--buckets", "128",
]


def _run_gaf(tmp_path, backend: str, *, online: bool = False,
             shards: int = 1, align_sharded: bool = False,
             pipelined: bool = False) -> bytes:
    tag = (f"{backend}{'_online' if online else ''}_s{shards}"
           f"{'_as' if align_sharded else ''}{'_pl' if pipelined else ''}")
    out = tmp_path / f"{tag}.gaf"
    argv = BASE_ARGS + ["--align-backend", backend, "--out", str(out)]
    if online:
        argv += ["--online", "--rate", "2000"]
    if shards != 1:
        argv += ["--num-shards", str(shards)]
    if align_sharded:
        argv += ["--align-sharded"]
    if pipelined:
        argv += ["--pipelined"]
    serve_genomics.main(argv)
    return out.read_bytes()


@pytest.mark.parametrize("backend", ["graph_lax", "graph_pallas"])
def test_offline_gaf_matches_golden(tmp_path, backend):
    assert _run_gaf(tmp_path, backend) == GOLDEN.read_bytes(), \
        f"offline GAF for backend {backend} diverged from the snapshot"


def test_online_gaf_matches_golden(tmp_path):
    """The online Poisson path must emit the same GAF as the offline
    drain (same engine underneath) regardless of arrival timing."""
    assert _run_gaf(tmp_path, "graph_lax", online=True) == \
        GOLDEN.read_bytes(), "online GAF diverged from the snapshot"


def test_sharded_gaf_matches_golden(tmp_path):
    """Sharded graph serving (repro.shard tile/backbone partitioning)
    must emit byte-identical GAF — positions, CIGARs, and node paths
    merge to the single-device winners."""
    assert _run_gaf(tmp_path, "graph_lax", shards=2) == \
        GOLDEN.read_bytes(), "GAF with --num-shards 2 diverged"


@pytest.mark.parametrize("shards,align_sharded,pipelined", [
    (2, True, False), (3, False, True), (2, True, True),
])
def test_device_merge_align_axes_match_golden(tmp_path, shards,
                                              align_sharded, pipelined):
    """The packed (distance, origin, tile) device merge plus the
    sharded/pipelined align axes must keep GAF bytes — positions,
    CIGARs, and node paths — identical to the single-device snapshot."""
    assert _run_gaf(tmp_path, "graph_lax", shards=shards,
                    align_sharded=align_sharded,
                    pipelined=pipelined) == GOLDEN.read_bytes(), \
        (f"GAF with --num-shards {shards} --align-sharded={align_sharded} "
         f"--pipelined={pipelined} diverged from the snapshot")


def test_gaf_rows_are_valid_gaf(tmp_path):
    """Every row: 12 tab columns + cg tag, path matches ([><]seg)+."""
    import re

    data = GOLDEN.read_text().strip().split("\n")
    assert len(data) == 10
    for line in data:
        cols = line.split("\t")
        assert len(cols) == 13
        assert re.fullmatch(r"([><][^\s><]+)+", cols[5])
        assert int(cols[6]) == int(cols[8]) - int(cols[7])  # plen == pend-pstart
        assert cols[12].startswith("cg:Z:")
