"""Deterministic fallback for the tiny ``hypothesis`` subset the suite uses.

When the real ``hypothesis`` package is installed (see pyproject.toml) it
is always preferred — ``conftest.py`` only installs this shim into
``sys.modules`` when the import fails, so environments without the
package (hermetic CI containers) still *run* the property tests instead
of erroring at collection.

Covered subset: ``@settings(max_examples=N, deadline=None)``,
``@given(st.data())``, ``data.draw(st.integers(lo, hi))``.  Draws are
seeded by example index, so runs are deterministic (no shrinking, no
database — this is a fallback, not a replacement).
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np


class _IntegersStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def _draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))  # hypothesis-inclusive


class _DataObject:
    def __init__(self, seed: int):
        self._rng = np.random.default_rng(seed)

    def draw(self, strategy, label=None):
        return strategy._draw(self._rng)


class _DataStrategy:
    def _example(self, i: int):
        return _DataObject(0xD15C0 + i)


def integers(min_value: int, max_value: int):
    return _IntegersStrategy(min_value, max_value)


def data():
    return _DataStrategy()


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(**fixtures):
            n = getattr(runner, "_max_examples", 20)
            for i in range(n):
                drawn = [s._example(i) if isinstance(s, _DataStrategy)
                         else s._draw(np.random.default_rng(i))
                         for s in strategies]
                fn(*drawn, **fixtures)

        # hide the drawn params from pytest's fixture resolution
        fix = [p for p in inspect.signature(fn).parameters.values()
               ][len(strategies):]
        runner.__signature__ = inspect.Signature(fix)
        del runner.__wrapped__  # keep pytest off the original signature
        return runner

    return deco


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` + ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.data = data
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
