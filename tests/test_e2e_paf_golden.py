"""Golden end-to-end regression: serve_genomics PAF output is byte-stable.

Runs the full service driver (simulate → index → engine → PAF) on a
fixed-seed read set and asserts the written PAF is byte-identical to the
snapshot in ``tests/data/serve_golden.paf`` — across the offline
WorkQueue drain and the ``--online`` Poisson path, and across the
``lax`` and ``pallas_dc*`` backends (interpret mode on CPU).  Any
divergence between backends, or any accidental change to mapping
results, shows up as a diff against one committed file.

Regenerate the snapshot (after an *intentional* output change) with:

    PYTHONPATH=src python -m repro.launch.serve_genomics \
        --ref-len 3000 --reads 10 --read-len 100 --batch 4 \
        --buckets 128 --align-backend lax --out tests/data/serve_golden.paf
"""
import pathlib

import pytest

from repro.launch import serve_genomics

GOLDEN = pathlib.Path(__file__).parent / "data" / "serve_golden.paf"
BASE_ARGS = [
    "--ref-len", "3000", "--reads", "10", "--read-len", "100",
    "--batch", "4", "--buckets", "128",
]


def _run_paf(tmp_path, backend: str, *, online: bool = False,
             shards: int = 1, align_sharded: bool = False,
             pipelined: bool = False) -> bytes:
    tag = (f"{backend}{'_online' if online else ''}_s{shards}"
           f"{'_as' if align_sharded else ''}{'_pl' if pipelined else ''}")
    out = tmp_path / f"{tag}.paf"
    argv = BASE_ARGS + ["--align-backend", backend, "--out", str(out)]
    if online:
        argv += ["--online", "--rate", "2000"]
    if shards != 1:
        argv += ["--num-shards", str(shards)]
    if align_sharded:
        argv += ["--align-sharded"]
    if pipelined:
        argv += ["--pipelined"]
    serve_genomics.main(argv)
    return out.read_bytes()


@pytest.mark.parametrize("backend", ["lax", "pallas_dc", "pallas_dc_v2"])
def test_offline_paf_matches_golden(tmp_path, backend):
    assert _run_paf(tmp_path, backend) == GOLDEN.read_bytes(), \
        f"offline PAF for backend {backend} diverged from the snapshot"


@pytest.mark.parametrize("backend", ["lax", "pallas_dc"])
def test_online_paf_matches_golden(tmp_path, backend):
    """The online Poisson path must emit the same PAF as the offline
    drain (same engine underneath) regardless of arrival timing."""
    assert _run_paf(tmp_path, backend, online=True) == GOLDEN.read_bytes(), \
        f"online PAF for backend {backend} diverged from the snapshot"


@pytest.mark.parametrize("shards", [2, 3])
def test_sharded_paf_matches_golden(tmp_path, shards):
    """Sharded serving (repro.shard scatter/merge) must emit the same
    bytes as the single-device path — the merge rule is shard-layout
    independent and shard windows are byte-identical in the halos."""
    assert _run_paf(tmp_path, "lax", shards=shards) == GOLDEN.read_bytes(), \
        f"PAF with --num-shards {shards} diverged from the snapshot"


def test_sharded_online_paf_matches_golden(tmp_path):
    """Sharding composes with the online Poisson admission path."""
    assert _run_paf(tmp_path, "lax", online=True, shards=2) == \
        GOLDEN.read_bytes(), "online sharded PAF diverged from the snapshot"


@pytest.mark.parametrize("shards,align_sharded,pipelined", [
    (2, True, False),   # mesh-split align, eager dispatch
    (2, False, True),   # double-buffered pipeline, full-batch align
    (3, True, True),    # both axes together
])
def test_device_merge_align_axes_match_golden(tmp_path, shards,
                                              align_sharded, pipelined):
    """The on-device packed-key merge plus the sharded/pipelined align
    stage must stay byte-identical to the single-device snapshot: both
    are pure re-schedulings of the same arithmetic."""
    assert _run_paf(tmp_path, "lax", shards=shards,
                    align_sharded=align_sharded,
                    pipelined=pipelined) == GOLDEN.read_bytes(), \
        (f"PAF with --num-shards {shards} --align-sharded={align_sharded} "
         f"--pipelined={pipelined} diverged from the snapshot")


def test_pipelined_online_paf_matches_golden(tmp_path):
    """The pipeline slot (batch i's align overlapping batch i+1's
    scatter) must not reorder or alter results under Poisson arrivals."""
    assert _run_paf(tmp_path, "lax", online=True, shards=2,
                    align_sharded=True, pipelined=True) == \
        GOLDEN.read_bytes(), "online pipelined PAF diverged from snapshot"
