"""Use case 3 (edit distance) + TB scoring configs + optimizer sanity."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import oracle
from repro.core.edit_distance import bitap_distance, genasm_distance
from repro.core.genasm import GenASMConfig
from repro.core.genasm_tb import cigar_counts, cigar_score
from repro.train import optimizer as opt_mod

from conftest import mutate_seq


def test_genasm_distance_windowed(rng):
    for _ in range(6):
        m = int(rng.integers(100, 300))
        a = rng.integers(0, 4, size=m).astype(np.int8)
        b = mutate_seq(a, 4, 2, 2, rng)
        abuf = np.full((320,), 4, np.int8); abuf[: len(a)] = a
        bbuf = np.full((448,), 4, np.int8); bbuf[: len(b)] = b
        d = int(genasm_distance(jnp.asarray(abuf), jnp.asarray(bbuf),
                                jnp.int32(len(b)), jnp.int32(len(a)),
                                p_cap=448))
        want = oracle.levenshtein_prefix(b, a)
        assert want <= d <= want + 3


def test_bitap_distance_short(rng):
    a = rng.integers(0, 4, size=40).astype(np.int8)
    b = mutate_seq(a, 2, 1, 0, rng)
    abuf = np.full((64,), 4, np.int8); abuf[: len(b)] = b
    bbuf = np.full((128,), 4, np.int8); bbuf[: len(a)] = a
    d = int(bitap_distance(jnp.asarray(abuf), jnp.asarray(bbuf), m_bits=64, k=10))
    assert d == min(oracle.levenshtein_prefix(b, a), 11)


def test_cigar_counts_and_score():
    ops = jnp.asarray(np.array([0, 0, 1, 2, 2, 3, 0, -1], np.int8))
    n = jnp.int32(7)
    counts = np.asarray(cigar_counts(ops, n))
    np.testing.assert_array_equal(counts, [3, 1, 2, 1])
    s = int(cigar_score(ops, n, match=2, subs=-4, gap_open=-4, gap_extend=-2))
    # 3M=6, 1X=-4, I-run: open+2·extend=-4-2·2... open counted once + extends
    assert s == 6 - 4 + (-4 - 2) + (-2) + (-4 - 2)


def test_adamw_converges_quadratic():
    cfg = opt_mod.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              total_steps=200, moment_dtype="float32")
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = opt_mod.init(cfg, params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, opt, _ = opt_mod.apply(cfg, params, opt, g)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_bf16_moments_shapes():
    cfg = opt_mod.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((8, 4))}
    opt = opt_mod.init(cfg, params)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    params2, opt2, m = opt_mod.apply(cfg, params, opt, {"w": jnp.ones((8, 4))})
    assert params2["w"].dtype == params["w"].dtype
    assert np.isfinite(float(m["grad_norm"]))
