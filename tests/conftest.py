import importlib.util
import pathlib

import numpy as np
import pytest

try:  # real hypothesis when available; deterministic fallback otherwise
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        pathlib.Path(__file__).parent / "_hypothesis_fallback.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def mutate_seq(seq, n_sub, n_ins, n_del, rng):
    from repro.align.inputs import mutate

    return mutate(seq, int(n_sub), int(n_ins), int(n_del), rng)
