import importlib.util
import pathlib

import numpy as np
import pytest

try:  # real hypothesis when available; deterministic fallback otherwise
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        pathlib.Path(__file__).parent / "_hypothesis_fallback.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def mutate_seq(seq, n_sub, n_ins, n_del, rng):
    s = list(seq)
    for _ in range(n_sub):
        i = rng.integers(0, len(s))
        s[i] = (s[i] + rng.integers(1, 4)) % 4
    for _ in range(n_ins):
        i = rng.integers(0, len(s) + 1)
        s.insert(i, int(rng.integers(0, 4)))
    for _ in range(n_del):
        i = rng.integers(0, len(s))
        del s[i]
    return np.array(s, np.int8)
