"""Distribution layer: sharded train step on a debug mesh, checkpoint
round-trip + elastic reshard, fault-tolerance utilities, grad compression."""
import os
import sys

import numpy as np
import pytest

# a dedicated subprocess-free debug device count for this module only
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import reduced
from repro.dist import sharding as shd
from repro.dist.fault import Heartbeat, WorkQueue
from repro.models import model_zoo
from repro.train import loop as train_loop

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4, reason="needs 4 host devices (XLA_FLAGS)")


def _mesh():
    return jax.make_mesh((2, 2), ("data", "model"))


def test_sharded_train_step_runs():
    mesh = _mesh()
    cfg = reduced(get_config("yi-6b"), n_heads=4, n_kv_heads=2, vocab=512)
    tcfg = train_loop.TrainConfig(microbatches=2)
    params, opt_state = train_loop.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    pspecs = shd.param_specs(params, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, pshard)
    oshard = {"step": NamedSharding(mesh, P()),
              "m": pshard, "v": pshard}
    opt_state = jax.device_put(opt_state, oshard)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=(4, 32))
    batch = {
        "tokens": jnp.asarray(toks, jnp.int32),
        "targets": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
        "mask": jnp.ones((4, 32), jnp.float32),
    }
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.batch_specs(batch, mesh),
                          is_leaf=lambda x: isinstance(x, P))
    batch = jax.device_put(batch, bshard)
    with mesh:
        step = jax.jit(train_loop.build_train_step(cfg, tcfg, mesh),
                       in_shardings=(pshard, oshard, bshard))
        params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2["step"]) == 1


def test_checkpoint_roundtrip_and_reshard(tmp_path):
    mesh = _mesh()
    tree = {"a": jnp.arange(16, dtype=jnp.float32).reshape(4, 4),
            "b": {"c": jnp.ones((8,), jnp.bfloat16)}}
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(5, tree, blocking=True)
    assert mgr.latest_step() == 5
    # restore resharded onto the mesh (elastic path)
    shardings = {"a": NamedSharding(mesh, P("data", "model")),
                 "b": {"c": NamedSharding(mesh, P("data"))}}
    out = mgr.restore(5, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["a"].sharding.spec == P("data", "model")


def test_checkpoint_gc_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.steps() == [2, 3]


def test_workqueue_reassigns_expired_leases():
    q = WorkQueue(3, lease_s=0.0)  # immediate expiry
    a = q.claim()
    b = q.claim()
    assert {a, b} <= {0, 1, 2}
    q.complete(a)
    # b's lease expires instantly; next claims must re-issue it eventually
    seen = set()
    for _ in range(6):
        c = q.claim()
        if c is not None:
            seen.add(c)
            q.complete(c)
    assert q.finished
    assert b in seen


def test_heartbeat_flags_straggler():
    import time

    hb = Heartbeat(factor=3.0)
    for _ in range(12):
        hb.beat()
        time.sleep(0.002)
    time.sleep(0.05)
    assert hb.beat() is True


def test_grad_compression_error_feedback():
    from repro.train.grad_compress import _dequantize, _quantize

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 0.02, size=4096), jnp.float32)
    q, scale, n = _quantize(x)
    y = _dequantize(q, scale, n)
    err = np.abs(np.asarray(y - x))
    bound = float(np.asarray(scale).max()) / 2 + 1e-6  # rounding ≤ scale/2
    assert err.max() <= bound
    # error feedback: residual carries the quantization error exactly
    resid = x - y
    q2, s2, _ = _quantize(x + resid)
    y2 = _dequantize(q2, s2, n)
    bound2 = float(np.asarray(s2).max()) / 2 + 1e-6
    assert np.abs(np.asarray(y2 - (x + resid))).max() <= bound2
