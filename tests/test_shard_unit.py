"""repro.shard: layout, partitioning, epoch vectors, failover, engine."""
import numpy as np
import pytest

from repro import shard
from repro.core import minimizer_index
from repro.core.genasm import GenASMConfig
from repro.genomics import encode, io, simulate
from repro.serve import EngineConfig, ResultCache, ServeEngine

W, K = 8, 12
CFG = GenASMConfig()
MAP_KW = dict(cfg=CFG, p_cap=128, filter_bits=128, filter_k=12,
              shard_candidates=4, backend="lax")


@pytest.fixture(scope="module")
def ref():
    return simulate.random_reference(12_000, seed=5)


@pytest.fixture(scope="module")
def epi(ref):
    return minimizer_index.build_epoched_index(ref, w=W, k=K)


@pytest.fixture(scope="module")
def reads(ref):
    return simulate.simulate_reads(ref, n_reads=16, read_len=100,
                                   profile=simulate.ILLUMINA, seed=6)


def test_plan_layout_bounds_and_slices():
    lay = shard.plan_layout(1000, 4, halo=100)
    assert lay.bounds == (0, 250, 500, 750, 1000)
    assert lay.core(1) == (250, 500)
    assert lay.slice_range(0) == (0, 350)  # left halo clipped at 0
    assert lay.slice_range(3) == (650, 1000)  # right halo clipped at L
    assert lay.shard_of(0) == 0 and lay.shard_of(499) == 1
    with pytest.raises(ValueError):
        shard.plan_layout(1000, 0)
    with pytest.raises(ValueError):
        shard.plan_layout(3, 8)  # empty core ranges


def test_partition_preserves_bytes_and_table(ref, epi):
    esi = shard.from_epoched(epi, 3)
    sharded = esi.index
    a = sharded.arrays
    g_hash = np.asarray(epi.index.hashes)
    g_pos = np.asarray(epi.index.positions)
    seen = 0
    for i in range(3):
        slo, shi = sharded.layout.slice_range(i)
        row = np.asarray(a.refs[i])
        assert (row[: shi - slo] == ref[slo:shi]).all()
        assert int(a.offsets[i]) == slo
        lo, hi = sharded.layout.core(i)
        m = (g_pos >= lo) & (g_pos < hi)
        got_h = np.asarray(a.hashes[i])[: m.sum()]
        got_p = np.asarray(a.positions[i])[: m.sum()]
        # global table rows, filtered by core ownership, order preserved
        assert (got_h == g_hash[m]).all() and (got_p == g_pos[m]).all()
        seen += int(m.sum())
    assert seen == len(g_pos)  # cores partition every entry exactly once


def test_required_halo_validation(ref, epi):
    esi = shard.from_epoched(epi, 2, halo=64)  # far too small
    with pytest.raises(ValueError, match="halo"):
        shard.validate_geometry(esi.index, p_cap=128, filter_bits=128,
                                filter_k=12, t_cap=128 + 2 * CFG.w)
    need = shard.required_halo(p_cap=128, filter_bits=128, filter_k=12,
                               t_cap=128 + 2 * CFG.w)
    ok = shard.from_epoched(epi, 2, halo=need)
    shard.validate_geometry(ok.index, p_cap=128, filter_bits=128,
                            filter_k=12, t_cap=128 + 2 * CFG.w)


def test_epoch_vector_tokens(ref, epi):
    esi = shard.from_epoched(epi, 2)
    _, t0 = esi.current()
    assert t0[1] == (0, 0)
    t1 = esi.refresh_shard(1)
    assert t1[1] == (0, 1) and t1 != t0
    t2 = esi.refresh(ref)
    assert t2[1] == (1, 2)
    assert len({t0, t1, t2}) == 3  # every refresh is a distinct cache key


def test_epoch_vector_prevents_scalar_collision(ref, epi):
    """Regression: keying the result cache on a scalar shard-local epoch
    aliases distinct shard states.  After refresh_shard(0) vs
    refresh_shard(1), both states have max(epochs) == sum(epochs) == 1 —
    a scalar key would serve state-A results for state-B lookups.  The
    (layout, epoch-vector) token keeps them distinct."""
    a = shard.from_epoched(epi, 2)
    b = shard.from_epoched(epi, 2)
    a.refresh_shard(0)
    b.refresh_shard(1)
    tok_a, tok_b = a.epoch_token(), b.epoch_token()
    assert sum(tok_a[1]) == sum(tok_b[1]) == 1  # scalar summaries collide
    assert max(tok_a[1]) == max(tok_b[1]) == 1
    assert tok_a != tok_b  # ...but the vector token does not
    cache = ResultCache(capacity=8)
    read = np.zeros(8, np.int8)
    cache.put(read, tok_a, "mapped-against-A")
    assert cache.get(read, tok_b) is None  # no cross-state hit
    assert cache.get(read, tok_a) == "mapped-against-A"


def test_refresh_shard_rematerializes_identically(ref, epi, reads):
    arr, lens = encode.batch_reads(list(reads.reads), 128)
    esi = shard.from_epoched(epi, 2)
    before = shard.map_batch_sharded(esi.index, arr, lens, **MAP_KW)
    esi.refresh_shard(0)
    after = shard.map_batch_sharded(esi.index, arr, lens, **MAP_KW)
    for f_b, f_a in zip(before, after):
        assert (np.asarray(f_b) == np.asarray(f_a)).all()


def test_failover_requeues_lost_shard(ref, epi, reads):
    arr, lens = encode.batch_reads(list(reads.reads), 128)
    esi = shard.from_epoched(epi, 3)
    clean = shard.map_batch_with_failover(esi, arr, lens, **MAP_KW)

    failures = []

    def lose_shard_once(i, attempt):
        if i == 1 and attempt == 1:
            failures.append(i)
            raise RuntimeError("simulated device loss")

    esi2 = shard.from_epoched(epi, 3)
    res = shard.map_batch_with_failover(esi2, arr, lens,
                                        fault_hook=lose_shard_once, **MAP_KW)
    assert failures == [1]  # the fault fired
    assert esi2.epochs == [0, 1, 0]  # lost shard re-materialized, epoch bumped
    for f_c, f_r in zip(clean, res):  # no read dropped, bytes unchanged
        assert (np.asarray(f_c) == np.asarray(f_r)).all()
    assert (res.position >= -1).all() and (res.position >= 0).sum() >= 12


def test_failover_gives_up_after_max_attempts(ref, epi, reads):
    arr, lens = encode.batch_reads(list(reads.reads[:4]), 128)
    esi = shard.from_epoched(epi, 2)

    def always_lose(i, attempt):
        if i == 0:
            raise RuntimeError("persistent loss")

    with pytest.raises(RuntimeError, match="failed 2 times"):
        shard.map_batch_with_failover(esi, arr, lens, max_attempts=2,
                                      fault_hook=always_lose, **MAP_KW)


def _paf_rows(res, lens, ref_len):
    rows = []
    for i in range(len(lens)):
        L = int(lens[i])
        rows.append({
            "qname": f"read{i}", "qlen": L, "qstart": 0, "qend": L,
            "strand": "+", "tname": "ref", "tlen": ref_len,
            "tstart": int(res.position[i]),
            "tend": int(res.position[i]) + L,
            "nmatch": L - int(res.distance[i]), "alnlen": L, "mapq": 60,
            "cigar": io.cigar_string(np.asarray(res.ops)[i],
                                     int(res.n_ops[i])),
        })
    return rows


def test_failover_align_chunk_requeues_in_pipelined_mode(ref, epi, reads,
                                                         tmp_path):
    """A shard lost *between merge and align* (the window the pipelined
    path opens) re-queues its align chunk; the re-assembled PAF bytes
    are identical to a clean full-batch run."""
    arr, lens = encode.batch_reads(list(reads.reads), 128)
    esi = shard.from_epoched(epi, 3)
    clean = shard.map_batch_with_failover(esi, arr, lens, **MAP_KW)

    failures = []

    def lose_between_merge_and_align(i, attempt):
        if i == 1 and attempt == 1:
            failures.append(i)
            raise RuntimeError("simulated device loss mid-pipeline")

    esi2 = shard.from_epoched(epi, 3)
    res = shard.map_batch_with_failover(
        esi2, arr, lens, pipelined=True,
        align_fault_hook=lose_between_merge_and_align, **MAP_KW)
    assert failures == [1]  # the fault fired after the device merge
    assert esi2.epochs == [0, 1, 0]  # lost shard re-materialized
    p_clean, p_fault = tmp_path / "clean.paf", tmp_path / "fault.paf"
    io.write_paf(p_clean, _paf_rows(clean, lens, len(ref)))
    io.write_paf(p_fault, _paf_rows(res, lens, len(ref)))
    assert p_clean.read_bytes() == p_fault.read_bytes()
    # and the failover driver's output equals the one-program device
    # merge path (same packed-key reduction, different launch structure)
    direct = shard.map_batch_sharded(esi.index, arr, lens, **MAP_KW)
    for f_c, f_d in zip(clean, direct):
        assert (np.asarray(f_c) == np.asarray(f_d)).all()


def test_failover_graph_faults_yield_identical_gaf(ref, reads, tmp_path):
    """Graph failover: a screen-phase loss AND an align-chunk loss in the
    same batch still yield byte-identical GAF output."""
    from repro.graph import index as graph_index

    variants = simulate.simulate_variants(ref, n_snp=20, n_ins=10,
                                          n_del=10, seed=7)
    gidx = graph_index.build_graph_index(ref, variants, w=W, k=K,
                                         window=128 + 2 * CFG.w)
    arr, lens = encode.batch_reads(list(reads.reads), 128)
    kw = dict(cfg=CFG, p_cap=128, filter_bits=128, filter_k=12,
              shard_candidates=4, backend="graph_lax")

    esi = shard.from_epoched_graph(gidx, 3)
    clean = shard.map_batch_with_failover_graph(esi, arr, lens, **kw)

    failures = []

    def lose_screen(i, attempt):
        if i == 0 and attempt == 1:
            failures.append(("screen", i))
            raise RuntimeError("simulated loss in screen")

    def lose_align_chunk(i, attempt):
        if i == 1 and attempt == 1:
            failures.append(("align", i))
            raise RuntimeError("simulated loss between merge and align")

    esi2 = shard.from_epoched_graph(gidx, 3)
    res = shard.map_batch_with_failover_graph(
        esi2, arr, lens, pipelined=True, fault_hook=lose_screen,
        align_fault_hook=lose_align_chunk, **kw)
    assert failures == [("screen", 0), ("align", 1)]
    assert esi2.epochs == [1, 1, 0]

    def gaf_rows(r):
        rows = []
        for i in range(len(lens)):
            L = int(lens[i])
            pstr, plen = io.gaf_path(np.asarray(r.path)[i])
            rows.append({
                "qname": f"read{i}", "qlen": L, "qstart": 0, "qend": L,
                "strand": "+", "path": pstr, "plen": plen, "pstart": 0,
                "pend": plen, "nmatch": L - int(r.distance[i]),
                "alnlen": int(r.n_ops[i]), "mapq": 60,
                "cigar": io.cigar_string(np.asarray(r.ops)[i],
                                         int(r.n_ops[i])),
            })
        return rows

    p_clean, p_fault = tmp_path / "clean.gaf", tmp_path / "fault.gaf"
    io.write_gaf(p_clean, gaf_rows(clean))
    io.write_gaf(p_fault, gaf_rows(res))
    assert p_clean.read_bytes() == p_fault.read_bytes()


def test_engine_pipelined_sharded_matches_single(epi, reads):
    """Device merge + mesh-split align + double-buffered flushes change
    dispatch structure only — results stay bit-identical."""
    base = dict(buckets=(128,), max_batch=4, filter_k=12,
                minimizer_w=W, minimizer_k=K, align_backend="lax")
    with ServeEngine(epi, EngineConfig(**base)) as eng1:
        r1 = eng1.map_all(list(reads.reads))
    with ServeEngine(epi, EngineConfig(num_shards=2, align_sharded=True,
                                       pipelined=True, **base)) as eng2:
        r2 = eng2.map_all(list(reads.reads))  # >=4 flushes: pending overlaps
        assert eng2.metrics.counter("batches_flushed").value >= 4
    for a, b in zip(r1, r2):
        assert (a.position, a.distance, a.n_ops) == \
            (b.position, b.distance, b.n_ops)
        assert (a.ops == b.ops).all()


def test_engine_sharded_matches_single(epi, reads):
    base = dict(buckets=(128,), max_batch=4, filter_k=12,
                minimizer_w=W, minimizer_k=K, align_backend="lax")
    with ServeEngine(epi, EngineConfig(**base)) as eng1:
        r1 = eng1.map_all(list(reads.reads))
    with ServeEngine(epi, EngineConfig(num_shards=2, **base)) as eng2:
        r2 = eng2.map_all(list(reads.reads))
        # one scatter + one align trace for the single bucket cap
        assert eng2.trace_counts == {(128, "scatter"): 1,
                                     (128, "align"): 1}
        # second pass is served from the result cache under the token key
        r2c = eng2.map_all(list(reads.reads))
        assert all(r.cached for r in r2c)
        assert eng2.trace_counts == {(128, "scatter"): 1,
                                     (128, "align"): 1}
    for a, b in zip(r1, r2):
        assert (a.position, a.distance, a.n_ops) == \
            (b.position, b.distance, b.n_ops)
        assert (a.ops == b.ops).all()


def test_engine_rejects_mismatched_shard_count(epi):
    esi = shard.from_epoched(epi, 3)
    cfg = EngineConfig(buckets=(128,), num_shards=2, filter_k=12,
                       minimizer_w=W, minimizer_k=K)
    with pytest.raises(ValueError, match="sharded 3 ways"):
        ServeEngine(esi, cfg)
