"""Graph-backend conformance suite (DESIGN.md §10).

Three anchors, mirroring the linear suite's tiers:

  * **linear-graph equivalence** — a pure-backbone graph pushed through
    ``graph_lax``/``graph_pallas`` must match the linear ``lax`` backend
    *bit for bit* on every ``AlignResult`` field (the graph DC/TB
    generalize the linear recurrences; a chain must collapse exactly);
  * **cross-backend agreement** — on real variant graphs the two graph
    backends agree bitwise (same TB over bitwise-equal DC stores), and
    the filter-pass distances agree between the pure-lax search and the
    Pallas kernel;
  * **oracle tiers** — anchored distances against the
    `graph_edit_distance_anchored` DP oracle: exact for substitution-only
    injections on spelled graph paths, oracle ≤ reported ≤ oracle + 3
    for mixed edits; every emitted path walks real graph edges and every
    M op matches its node base.

``REPRO_ALIGN_BACKEND`` (the CI matrix knob) narrows the graph backend
list; pinning a linear backend skips this suite (the linear suite
already runs it through the graph backends' chain packing).
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro import align
from repro.align import inputs
from repro.core import oracle
from repro.core.genasm import GenASMConfig
from repro.core.segram import graph as cgraph
from repro.graph import windowed
from repro.genomics import simulate

GRAPH_BACKENDS = ("graph_lax", "graph_pallas")
_env = os.environ.get("REPRO_ALIGN_BACKEND")
if _env:
    if _env in GRAPH_BACKENDS:
        GRAPH_BACKENDS = (_env,)
    else:
        pytest.skip(f"matrix pin {_env} is a linear backend; the linear "
                    f"conformance suite covers it", allow_module_level=True)

CFG = GenASMConfig()  # paper geometry: W=64, O=24, k=24
P_CAP, T_CAP = 128, 256
RESULT_FIELDS = ("distance", "ops", "n_ops", "text_consumed", "failed")


def _run(backend, texts, pats, p_lens, t_lens, *, cfg=CFG, p_cap=P_CAP,
         block_bt=4):
    return align.align_batch(
        jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
        jnp.asarray(t_lens), cfg=cfg, backend=backend, p_cap=p_cap,
        block_bt=block_bt)


def _variant_graph(seed, ref_len=400):
    rng = np.random.default_rng(seed)
    ref = simulate.random_reference(ref_len, seed=seed)
    variants = simulate.simulate_variants(
        ref, n_snp=6, n_ins=3, n_del=3, seed=seed + 1)
    return cgraph.build_graph(ref, variants), rng


def _graph_batch(seed, n_pairs=4, *, n_sub=0, n_ins=0, n_del=0):
    """Spelled-path patterns (with injected edits) over one variant graph."""
    g, rng = _variant_graph(seed)
    gtext = np.asarray(
        windowed.pack_graph_text(jnp.asarray(g.bases),
                                 jnp.asarray(g.succ_bits)))
    texts = np.zeros((n_pairs, T_CAP), np.uint32)
    pats = np.full((n_pairs, P_CAP), 4, np.int8)
    p_lens = np.zeros(n_pairs, np.int32)
    t_lens = np.zeros(n_pairs, np.int32)
    starts = []
    for i in range(n_pairs):
        start = int(rng.integers(0, g.n_nodes - T_CAP))
        m = int(rng.integers(40, 90))
        pat = simulate.spell_graph_path(g, start, m, rng)
        for _ in range(n_sub):
            j = int(rng.integers(0, len(pat)))
            pat[j] = (pat[j] + 1 + rng.integers(0, 3)) % 4
        for _ in range(n_ins):
            j = int(rng.integers(0, len(pat)))
            pat = np.insert(pat, j, rng.integers(0, 4))
        for _ in range(n_del):
            j = int(rng.integers(0, len(pat) - 1))
            pat = np.delete(pat, j)
        bases, succ = cgraph.extract_subgraph(g, start, T_CAP)
        texts[i] = np.asarray(windowed.pack_graph_text(
            jnp.asarray(bases), jnp.asarray(succ)))
        pats[i, :len(pat)] = pat
        p_lens[i] = len(pat)
        t_lens[i] = T_CAP
        starts.append(start)
    return g, texts, pats, p_lens, t_lens, starts


def _check_graph_alignment(g, start, pat, p_len, res, i):
    """Path follows succ edges, M bases match, edits == distance."""
    ops = np.asarray(res.ops[i])
    nodes = np.asarray(res.nodes[i])
    n_ops = int(res.n_ops[i])
    pi, edits, prev = 0, 0, None
    for s in range(n_ops):
        op, nd = int(ops[s]), int(nodes[s])
        if op in (0, 1, 3):  # consumes a node
            gn = start + nd
            if prev is not None:
                hop = gn - prev - 1
                assert 0 <= hop < cgraph.HOP_LIMIT, (i, s, prev, gn)
                assert (int(g.succ_bits[prev]) >> hop) & 1, \
                    f"pair {i}: step {s} jumps {prev}->{gn} off-graph"
            prev = gn
        if op == 0:
            assert g.bases[start + nd] == pat[pi], f"pair {i}: M mismatch"
            pi += 1
        elif op in (1, 2):
            pi += 1
            edits += 1
        elif op == 3:
            edits += 1
    assert pi == p_len, f"pair {i}: pattern not fully consumed"
    assert edits == int(res.distance[i]), f"pair {i}: edits != distance"


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_linear_graph_matches_lax_bitwise(backend, rng):
    """A chain-packed linear text through the graph backends equals the
    linear ``lax`` backend on every output field."""
    pairs = [inputs.mutated_pair(rng, int(rng.integers(16, 120)), n_sub=2,
                                 n_ins=1, n_del=1, t_extra=40)
             for _ in range(6)]
    texts, pats, p_lens, t_lens = inputs.padded_batch(pairs, P_CAP, 192)
    base = _run("lax", texts, pats, p_lens, t_lens)
    packed = np.asarray(windowed.pack_linear_text(jnp.asarray(texts)))
    for sent in (texts, packed):  # int8 auto-pack and explicit uint32
        got = _run(backend, sent, pats, p_lens, t_lens)
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(base, f)), np.asarray(getattr(got, f)),
                err_msg=f"{backend}.{f} diverges from lax "
                        f"(dtype {np.asarray(sent).dtype})")


def test_variant_graph_backends_bit_identical():
    """graph_lax and graph_pallas agree bitwise on variant graphs,
    including the node paths."""
    if len(GRAPH_BACKENDS) < 2:
        pytest.skip("matrix run pins a single backend")
    g, texts, pats, p_lens, t_lens, _ = _graph_batch(
        3, n_sub=2, n_ins=1, n_del=1)
    base = _run("graph_lax", texts, pats, p_lens, t_lens)
    got = _run("graph_pallas", texts, pats, p_lens, t_lens)
    for f in RESULT_FIELDS + ("nodes",):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, f)), np.asarray(getattr(got, f)),
            err_msg=f"graph_pallas.{f} diverges from graph_lax")


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_subs_only_anchored_distance_exact(backend):
    """Substitution-only injections on spelled paths: distance equals the
    anchored graph-DP oracle; alignments are internally consistent."""
    g, texts, pats, p_lens, t_lens, starts = _graph_batch(11, n_sub=3)
    res = _run(backend, texts, pats, p_lens, t_lens)
    dist = np.asarray(res.distance)
    for i, start in enumerate(starts):
        bases, succ = cgraph.extract_subgraph(g, start, T_CAP)
        sub = cgraph.GenomeGraph(bases, succ, np.zeros(T_CAP, np.int32),
                                 np.zeros(0, np.int32))
        want = oracle.graph_edit_distance_anchored(
            pats[i][: p_lens[i]], bases, cgraph.predecessors(sub), start=0)
        assert dist[i] == want, f"pair {i}: want {want} got {dist[i]}"
        _check_graph_alignment(g, start, pats[i][: p_lens[i]], p_lens[i],
                               res, i)


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_indel_mix_anchored_within_slack(backend):
    """Mixed edits: oracle ≤ reported ≤ oracle + 3 (the linear suite's
    §4.10.2 slack), alignment always consistent."""
    g, texts, pats, p_lens, t_lens, starts = _graph_batch(
        23, n_sub=2, n_ins=2, n_del=2)
    res = _run(backend, texts, pats, p_lens, t_lens)
    dist = np.asarray(res.distance)
    for i, start in enumerate(starts):
        bases, succ = cgraph.extract_subgraph(g, start, T_CAP)
        sub = cgraph.GenomeGraph(bases, succ, np.zeros(T_CAP, np.int32),
                                 np.zeros(0, np.int32))
        want = oracle.graph_edit_distance_anchored(
            pats[i][: p_lens[i]], bases, cgraph.predecessors(sub), start=0)
        assert dist[i] >= 0, f"pair {i} failed with only 6 edits"
        assert want <= dist[i] <= want + 3, \
            f"pair {i}: oracle {want} got {dist[i]}"
        _check_graph_alignment(g, start, pats[i][: p_lens[i]], p_lens[i],
                               res, i)


def test_filter_search_matches_kernel_bitwise(rng):
    """`windowed.bitalign_search` (the mapper's pure-lax filter) equals
    the Pallas BitAlign DC kernel's per-node distances bitwise."""
    from repro.kernels.bitalign import bitalign_dc_batch

    g, _ = _variant_graph(31)
    win = 160
    b = 8
    bases = np.zeros((b, win), np.int8)
    succ = np.zeros((b, win), np.uint32)
    pats = np.full((b, 64), 4, np.int8)
    p_lens = np.zeros(b, np.int32)
    for i in range(b):
        s = int(rng.integers(0, g.n_nodes - win))
        bases[i], succ[i] = cgraph.extract_subgraph(g, s, win)
        m = int(rng.integers(20, 60))
        pat = simulate.spell_graph_path(g, s + int(rng.integers(0, 30)), m,
                                        rng)
        pats[i, :len(pat)] = pat
        p_lens[i] = len(pat)
    d_lax = jnp.stack([
        windowed.bitalign_search(jnp.asarray(bases[i]), jnp.asarray(succ[i]),
                                 jnp.asarray(pats[i]), jnp.int32(p_lens[i]),
                                 m_bits=64, k=8)
        for i in range(b)])
    d_ker, _ = bitalign_dc_batch(
        jnp.asarray(bases), jnp.asarray(succ), jnp.asarray(pats),
        jnp.asarray(p_lens), m_bits=64, k=8, block_bt=8,
        interpret=align.needs_interpret())
    np.testing.assert_array_equal(np.asarray(d_lax), np.asarray(d_ker))


@pytest.mark.parametrize("backend", GRAPH_BACKENDS)
def test_emit_cigar_false_distances_match(backend):
    """Distances-only mode keeps the AlignResult contract: same distance
    and n_ops as the CIGAR mode, [B, 1] ops, no node path."""
    _, texts, pats, p_lens, t_lens, _ = _graph_batch(7, n_sub=2)
    full = _run(backend, texts, pats, p_lens, t_lens)
    slim = align.align_batch(
        jnp.asarray(texts), jnp.asarray(pats), jnp.asarray(p_lens),
        jnp.asarray(t_lens), cfg=CFG, backend=backend, p_cap=P_CAP,
        emit_cigar=False)
    assert slim.ops.shape == (texts.shape[0], 1)
    assert slim.nodes is None
    np.testing.assert_array_equal(np.asarray(slim.distance),
                                  np.asarray(full.distance))
    np.testing.assert_array_equal(np.asarray(slim.n_ops),
                                  np.asarray(full.n_ops))
