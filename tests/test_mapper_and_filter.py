"""Linear mapper end-to-end + pre-alignment filter accuracy."""
import numpy as np
import jax.numpy as jnp

from repro.core import filter as gfilter
from repro.core import mapper, minimizer_index, oracle
from repro.genomics import encode, simulate


def test_mapper_end_to_end():
    ref = simulate.random_reference(4000, seed=11)
    idx = minimizer_index.build_reference_index(ref, w=8, k=12)
    rs = simulate.simulate_reads(ref, n_reads=12, read_len=120,
                                 profile=simulate.ILLUMINA, seed=3)
    reads, lens = encode.batch_reads(rs.reads, 128)
    res = mapper.map_batch(idx, jnp.asarray(reads), jnp.asarray(lens),
                           p_cap=192, filter_bits=128, filter_k=16,
                           minimizer_w=8, minimizer_k=12)
    pos = np.asarray(res.position)
    ok = np.abs(pos - rs.true_pos) <= 16
    assert ok.sum() >= 10  # ≥80% correctly placed at 5% error rate
    # mapped reads have valid distances
    d = np.asarray(res.distance)
    assert np.all(d[pos >= 0] >= 0)


def test_filter_exactness():
    """GenASM-DC filter distance == oracle ⇒ zero false accept/reject."""
    rng = np.random.default_rng(5)
    k, m = 5, 100
    m_bits, n = 128, 128 + 2 * 5 + 16
    B = 32
    texts = np.full((B, n), 4, np.int8)
    reads = np.full((B, m_bits), 4, np.int8)
    truth = np.zeros(B, bool)
    for i in range(B):
        r = rng.integers(0, 4, size=m).astype(np.int8)
        if i % 2 == 0:
            t = r.copy()
            for _ in range(rng.integers(0, k + 1)):
                j = rng.integers(0, m)
                t[j] = (t[j] + 1) % 4
        else:
            t = rng.integers(0, 4, size=m + 2 * k).astype(np.int8)
        texts[i, : len(t)] = t
        reads[i, :m] = r
        truth[i] = oracle.levenshtein_prefix(r, t) <= k
    accept, dist = gfilter.filter_candidates(jnp.asarray(texts), jnp.asarray(reads),
                                             None, m_bits=m_bits, k=k)
    np.testing.assert_array_equal(np.asarray(accept), truth)
