"""GenASM core correctness: DC vs Levenshtein oracle, TB CIGAR validity."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import genasm, genasm_dc, oracle
from repro.core.genasm import GenASMConfig

from conftest import mutate_seq


def _align(pat, text, p_cap=256, cfg=GenASMConfig()):
    pbuf = np.full((p_cap,), 4, np.int8)
    pbuf[: len(pat)] = pat
    tbuf = np.full((p_cap,), 4, np.int8)
    tbuf[: min(len(text), p_cap)] = text[:p_cap]
    return genasm.align(jnp.asarray(tbuf), jnp.asarray(pbuf),
                        jnp.int32(len(pat)), jnp.int32(min(len(text), p_cap)),
                        cfg=cfg, p_cap=p_cap)


def test_exact_match_zero_distance(rng):
    ref = rng.integers(0, 4, size=120).astype(np.int8)
    res = _align(ref[:80], ref)
    assert int(res.distance) == 0
    assert int(res.n_ops) == 80
    assert np.all(np.asarray(res.ops)[:80] == 0)


def test_bitap_search_matches_oracle(rng):
    for _ in range(10):
        m = int(rng.integers(5, 38))
        text = rng.integers(0, 4, size=64).astype(np.int8)
        pat = mutate_seq(text[:m], rng.integers(0, 3), rng.integers(0, 2),
                         rng.integers(0, 2), rng)
        want = min(oracle.levenshtein_prefix(pat, text), 11)
        pbuf = np.full((64,), 4, np.int8)
        pbuf[: len(pat)] = pat
        tbuf = np.full((128,), 4, np.int8)
        tbuf[:64] = text
        d = genasm_dc.bitap_search(jnp.asarray(tbuf), jnp.asarray(pbuf),
                                   m_bits=64, k=10)
        assert int(np.asarray(d)[0]) == want


def test_windowed_align_distance_and_cigar(rng):
    """Windowed GenASM: distance within the paper's documented greedy-window
    slack of the oracle; CIGAR always consistent (§4.10.2)."""
    exact = 0
    for _ in range(15):
        m = int(rng.integers(30, 180))
        ref = rng.integers(0, 4, size=m + 50).astype(np.int8)
        pat = mutate_seq(ref[:m], rng.integers(0, 4), rng.integers(0, 3),
                         rng.integers(0, 3), rng)
        want = oracle.levenshtein_prefix(pat, ref)
        res = _align(pat, ref)
        got = int(res.distance)
        assert got >= 0, "alignment failed"
        err = oracle.check_cigar(np.asarray(res.ops), int(res.n_ops), pat, ref, got)
        assert err is None, err
        assert want <= got <= want + 3
        exact += got == want
    assert exact >= 12  # ≥80% exact, matching the paper's accuracy analysis


def test_align_batch_shapes(rng):
    pats = rng.integers(0, 4, size=(4, 128)).astype(np.int8)
    texts = rng.integers(0, 4, size=(4, 128)).astype(np.int8)
    res = genasm.align_batch(jnp.asarray(texts), jnp.asarray(pats),
                             jnp.full((4,), 100, np.int32),
                             jnp.full((4,), 128, np.int32))
    assert res.distance.shape == (4,)
    assert res.ops.ndim == 2


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_bitap_distance_exact(data):
    """Property: full-length Bitap == DP oracle for any random pair."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    m = data.draw(st.integers(4, 30))
    n = data.draw(st.integers(m, 60))
    pat = rng.integers(0, 4, size=m).astype(np.int8)
    text = rng.integers(0, 4, size=n).astype(np.int8)
    want = min(oracle.levenshtein_prefix(pat, text), 9)
    pbuf = np.full((32,), 4, np.int8)
    pbuf[:m] = pat
    tbuf = np.full((n + 32,), 4, np.int8)
    tbuf[:n] = text
    d = genasm_dc.bitap_search(jnp.asarray(tbuf), jnp.asarray(pbuf),
                               m_bits=32, k=8)
    assert int(np.asarray(d)[0]) == want


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_property_cigar_invariants(data):
    """Property: windowed GenASM CIGAR applies cleanly for any mutation mix."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    m = data.draw(st.integers(20, 120))
    ref = rng.integers(0, 4, size=m + 40).astype(np.int8)
    pat = mutate_seq(ref[:m], data.draw(st.integers(0, 3)),
                     data.draw(st.integers(0, 2)), data.draw(st.integers(0, 2)),
                     rng)
    res = _align(pat, ref)
    if int(res.distance) >= 0:
        err = oracle.check_cigar(np.asarray(res.ops), int(res.n_ops), pat, ref,
                                 int(res.distance))
        assert err is None, err


def test_store_r_parity_with_paper_store(rng):
    """v2 (R-only TB store) must reproduce v1 distances and valid CIGARs."""
    for _ in range(8):
        m = int(rng.integers(30, 160))
        ref_seq = rng.integers(0, 4, size=m + 50).astype(np.int8)
        pat = mutate_seq(ref_seq[:m], rng.integers(0, 4), rng.integers(0, 2),
                         rng.integers(0, 2), rng)
        r1 = _align(pat, ref_seq)
        r2 = _align(pat, ref_seq, cfg=GenASMConfig(store_r=True))
        assert int(r1.distance) == int(r2.distance)
        if int(r2.distance) >= 0:
            err = oracle.check_cigar(np.asarray(r2.ops), int(r2.n_ops), pat,
                                     ref_seq, int(r2.distance))
            assert err is None, err
