"""Differential + property suite for the packed-key device shard merge.

The PR-10 merge moves shard-winner selection from host Python
(`merge_host`, the three-line masked lexicographic rule) to a packed
monotone uint64 argmin on device (`repro.shard.merge`).  That is only
safe if (a) the packing is a strict order isomorphism with the
lexicographic sort tuple over its whole domain — boundary values
included — and (b) the device reduction picks bit-identical winners on
real stage outputs, engineered ties and all-dead columns included.
Both are proven here; `merge_host` survives in the executors purely as
the independently coded oracle for these tests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import shard
from repro.core import mapper as core_mapper
from repro.core import minimizer_index
from repro.core.genasm import GenASMConfig
from repro.core.mapper import POS_SENTINEL
from repro.genomics import encode, io, simulate
from repro.graph import index as graph_index
from repro.graph import mapper as graph_mapper
from repro.shard import merge as sm
from repro.shard.graph_mapper import ShardedGraphMapExecutor
from repro.shard.mapper import ShardedMapExecutor, ShardStageResult

I32_MAX = int(np.iinfo(np.int32).max)


def _arr(*vals):
    return np.asarray(vals, np.int32)


# --------------------------------------------------------- property: linear --
@settings(max_examples=80, deadline=None)
@given(st.data())
def test_linear_key_round_trip(data):
    d = data.draw(st.integers(0, I32_MAX))
    p = data.draw(st.integers(0, POS_SENTINEL))
    dd, pp = sm.unpack_linear_key(sm.pack_linear_key(_arr(d), _arr(p)))
    assert (int(dd[0]), int(pp[0])) == (d, p)


@settings(max_examples=120, deadline=None)
@given(st.data())
def test_linear_key_order_isomorphism(data):
    # strict isomorphism: <, ==, > of the packed keys must each match
    # the lexicographic tuple over the full non-negative int32 domain
    a = (data.draw(st.integers(0, I32_MAX)),
         data.draw(st.integers(0, POS_SENTINEL)))
    b = (data.draw(st.integers(0, I32_MAX)),
         data.draw(st.integers(0, POS_SENTINEL)))
    ka = sm.pack_linear_key(_arr(a[0]), _arr(a[1]))[0]
    kb = sm.pack_linear_key(_arr(b[0]), _arr(b[1]))[0]
    assert (ka < kb) == (a < b)
    assert (ka == kb) == (a == b)


def test_linear_key_boundary_values():
    # every pairing of the field extremes keeps strict order — the
    # cases a lost carry or field overlap would corrupt first
    ds = [0, 1, 13, I32_MAX - 1, I32_MAX]
    ps = [0, 1, POS_SENTINEL - 1, POS_SENTINEL]
    tuples = [(d, p) for d in ds for p in ps]
    keys = [int(sm.pack_linear_key(_arr(d), _arr(p))[0]) for d, p in tuples]
    order = sorted(range(len(tuples)), key=lambda i: tuples[i])
    korder = sorted(range(len(tuples)), key=lambda i: keys[i])
    assert order == korder
    assert len(set(keys)) == len(keys)  # injective on the grid


# ---------------------------------------------------------- property: graph --
def _graph_tile(data):
    # tile domain: real ids below the 21-bit clamp, or the sentinel
    if data.draw(st.integers(0, 4)) == 0:
        return POS_SENTINEL
    return data.draw(st.integers(0, sm.GRAPH_TILE_MAX - 1))


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_graph_key_round_trip(data):
    d = data.draw(st.integers(0, sm.GRAPH_D_MAX))
    o = data.draw(st.integers(0, POS_SENTINEL))
    t = _graph_tile(data)
    key = sm.pack_graph_key(_arr(d), _arr(o), _arr(t))
    dd, oo, tt = sm.unpack_graph_key(key)
    assert (int(dd[0]), int(oo[0]), int(tt[0])) == (d, o, t)


@settings(max_examples=120, deadline=None)
@given(st.data())
def test_graph_key_order_isomorphism(data):
    def tup(_):
        return (data.draw(st.integers(0, sm.GRAPH_D_MAX)),
                data.draw(st.integers(0, POS_SENTINEL)),
                _graph_tile(data))

    a, b = tup(0), tup(1)
    # the sentinel tile packs as the field max, which sorts after every
    # real tile id exactly like POS_SENTINEL does in the host tuple
    order_a = (a[0], a[1], min(a[2], sm.GRAPH_TILE_MAX))
    order_b = (b[0], b[1], min(b[2], sm.GRAPH_TILE_MAX))
    ka = sm.pack_graph_key(*[_arr(v) for v in a])[0]
    kb = sm.pack_graph_key(*[_arr(v) for v in b])[0]
    assert (ka < kb) == (order_a < order_b)
    assert (ka == kb) == (order_a == order_b)


def test_graph_key_boundary_values():
    ds = [0, 1, sm.GRAPH_D_MAX - 1, sm.GRAPH_D_MAX]
    os_ = [0, 1, POS_SENTINEL - 1, POS_SENTINEL]
    ts = [0, 1, sm.GRAPH_TILE_MAX - 1, POS_SENTINEL]
    tuples = [(d, o, t) for d in ds for o in os_ for t in ts]
    keys = [int(sm.pack_graph_key(_arr(d), _arr(o), _arr(t))[0])
            for d, o, t in tuples]
    order = sorted(range(len(tuples)), key=lambda i: tuples[i])
    korder = sorted(range(len(tuples)), key=lambda i: keys[i])
    assert order == korder
    assert len(set(keys)) == len(keys)


def test_graph_domain_check():
    sm.check_graph_domain(n_tiles=sm.GRAPH_TILE_MAX - 1, filter_k=100)
    with pytest.raises(ValueError, match="tile field"):
        sm.check_graph_domain(n_tiles=sm.GRAPH_TILE_MAX, filter_k=12)
    with pytest.raises(ValueError, match="distance field"):
        sm.check_graph_domain(n_tiles=64, filter_k=sm.GRAPH_D_MAX)


# ------------------------------------------- differential: synthetic stages --
FILTER_K = 12
T_CAP = 16


def _rand_linear_stage(s, b, rng):
    d = rng.integers(0, FILTER_K + 2, size=(s, b)).astype(np.int32)
    pos = rng.integers(0, 5000, size=(s, b)).astype(np.int32)
    # engineered cross-shard distance ties (positions break them) ...
    ties = rng.random(b) < 0.4
    d[:, ties] = d[0, ties]
    # ... and full-key ties, where the lowest shard must win
    full = rng.random(b) < 0.25
    d[:, full] = d[0, full]
    pos[:, full] = pos[0, full]
    # no-candidate rows: sentinel distance AND position together
    none = rng.random((s, b)) < 0.3
    d[none] = FILTER_K + 1
    pos[none] = POS_SENTINEL
    none[:, 0] = True  # one all-dead column: argmin must pick shard 0
    d[:, 0] = FILTER_K + 1
    pos[:, 0] = POS_SENTINEL
    text = rng.integers(0, 4, size=(s, b, T_CAP)).astype(np.int8)
    t_len = rng.integers(1, T_CAP + 1, size=(s, b)).astype(np.int32)
    return ShardStageResult(distance=d, position=pos, text=text,
                            t_len=t_len)


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_linear_device_merge_matches_host(num_shards):
    rng = np.random.default_rng(40 + num_shards)
    for trial in range(4):
        stage = _rand_linear_stage(num_shards, 24, rng)
        host = ShardedMapExecutor.merge_host(stage)
        with sm.x64_scope():
            dev = jax.jit(sm.merge_linear)(
                *[jnp.asarray(x) for x in stage])
        for h, d_ in zip(host, dev):
            np.testing.assert_array_equal(np.asarray(h), np.asarray(d_))


def _rand_graph_stage(s, b, rng):
    d = rng.integers(0, FILTER_K + 2, size=(s, b)).astype(np.int32)
    origin = rng.integers(0, 4000, size=(s, b)).astype(np.int32)
    tile = rng.integers(0, 2000, size=(s, b)).astype(np.int32)
    # cross-shard ties at every lexicographic level
    t1 = rng.random(b) < 0.4  # distance tie, origins decide
    d[:, t1] = d[0, t1]
    t2 = rng.random(b) < 0.3  # distance+origin tie, tiles decide
    d[:, t2] = d[0, t2]
    origin[:, t2] = origin[0, t2]
    t3 = rng.random(b) < 0.2  # full tie, lowest shard wins
    d[:, t3] = d[0, t3]
    origin[:, t3] = origin[0, t3]
    tile[:, t3] = tile[0, t3]
    # dead candidates carry sentinel origin AND tile together — the
    # shared `live` mask invariant the stage guarantees upstream
    dead = rng.random((s, b)) < 0.3
    d[dead] = FILTER_K + 1
    origin[dead] = POS_SENTINEL
    tile[dead] = POS_SENTINEL
    d[:, 0] = FILTER_K + 1  # one all-dead column
    origin[:, 0] = POS_SENTINEL
    tile[:, 0] = POS_SENTINEL
    return graph_mapper.CandidateStageResult(
        distance=d, origin=origin, tile=tile,
        gwin=rng.integers(0, 2 ** 16, size=(s, b, T_CAP)).astype(np.uint32),
        bwin=rng.integers(-1, 3000, size=(s, b, T_CAP)).astype(np.int32),
        t_len=rng.integers(1, T_CAP + 1, size=(s, b)).astype(np.int32),
        prefilter_ok=rng.integers(0, 2, size=(s, b)).astype(bool))


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_graph_device_merge_matches_host(num_shards):
    rng = np.random.default_rng(50 + num_shards)
    for trial in range(4):
        stage = _rand_graph_stage(num_shards, 24, rng)
        host = ShardedGraphMapExecutor.merge_host(stage)
        with sm.x64_scope():
            out = jax.jit(sm.merge_graph)(
                *[jnp.asarray(x) for x in stage])
        dev = graph_mapper.CandidateStageResult(*out[:7])
        for f in graph_mapper.CandidateStageResult._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(host, f)), np.asarray(getattr(dev, f)),
                err_msg=f"field {f}")


# ------------------------------------------ differential: real workloads ----
L = 6_000
P_CAP = 128
CFG = GenASMConfig()
KW = dict(p_cap=P_CAP, filter_bits=128, filter_k=12)


def _cigars(res):
    return [io.cigar_string(np.asarray(res.ops)[i], int(res.n_ops[i]))
            for i in range(len(res.n_ops))]


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_linear_workload_device_merge_end_to_end(num_shards):
    """Winners, positions, and CIGARs through the device merge equal the
    single-device mapper at every shard count."""
    ref = simulate.random_reference(L, seed=31)
    epi = minimizer_index.build_epoched_index(ref, w=8, k=12)
    esi = shard.from_epoched(epi, num_shards)
    rs = simulate.simulate_reads(ref, n_reads=8, read_len=100,
                                 seed=32 + num_shards)
    arr, lens = encode.batch_reads(rs.reads, P_CAP)

    single = core_mapper.map_batch(
        epi.index, jnp.asarray(arr), jnp.asarray(lens), cfg=CFG,
        max_candidates=4, backend="lax", minimizer_w=8, minimizer_k=12,
        **KW)
    sharded = shard.map_batch_sharded(
        esi.index, arr, lens, cfg=CFG, shard_candidates=4, backend="lax",
        **KW)
    assert (np.asarray(single.position) == sharded.position).all()
    assert (np.asarray(single.distance) == sharded.distance).all()
    assert _cigars(single) == _cigars(sharded)


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_graph_workload_device_merge_end_to_end(num_shards):
    ref = simulate.random_reference(L, seed=33)
    variants = simulate.simulate_variants(ref, n_snp=20, n_ins=10,
                                          n_del=10, seed=34)
    gidx = graph_index.build_graph_index(ref, variants, w=8, k=12,
                                         window=P_CAP + 2 * CFG.w)
    esi = shard.from_epoched_graph(gidx, num_shards)
    rs = simulate.simulate_reads(ref, n_reads=8, read_len=100,
                                 seed=35 + num_shards)
    arr, lens = encode.batch_reads(rs.reads, P_CAP)

    single = graph_mapper.map_batch_index(
        gidx, jnp.asarray(arr), jnp.asarray(lens), cfg=CFG,
        max_candidates=4, backend="graph_lax", minimizer_w=8,
        minimizer_k=12, **KW)
    sharded = shard.map_batch_sharded_graph(
        esi.index, arr, lens, cfg=CFG, shard_candidates=4,
        backend="graph_lax", **KW)
    assert (np.asarray(single.position) == sharded.position).all()
    assert (np.asarray(single.distance) == sharded.distance).all()
    assert _cigars(single) == _cigars(sharded)
    assert (np.asarray(single.path) == sharded.path).all()
